"""Serve a small LM with batched requests — the paper's §4 scenario live:
every decode step ends in a fused softmax+top-k over the full vocabulary.

    PYTHONPATH=src python examples/serve_topk.py
"""
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import engine

cfg = configs.get_smoke("smollm_360m")
params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))

BATCH, PROMPT, GEN = 8, 24, 48
prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0,
                             cfg.vocab_size)

prefill = jax.jit(lambda p, t: engine.prefill(p, t, cfg,
                                              max_len=PROMPT + GEN))
decode = jax.jit(
    lambda p, c, ln, t, r: engine.decode_step(p, c, ln, t, cfg, rng=r,
                                              top_k=5),
    donate_argnums=(1,))

t0 = time.monotonic()
last_hidden, caches, length = prefill(params, prompts)
logits = transformer.logits_last(params, last_hidden[:, None], cfg)
from repro.core import topk_sample
tok, probs = topk_sample(jax.random.PRNGKey(2), logits, 5)
jax.block_until_ready(tok)
print(f"prefill {BATCH}x{PROMPT} tokens: {(time.monotonic()-t0)*1e3:.1f} ms")
print(f"first sampled tokens: {tok.tolist()}")
print(f"their top-5 renormalized probs (req 0): "
      f"{jnp.round(probs[0], 3).tolist()}")

t0 = time.monotonic()
generated = [tok]
for i in range(GEN - 1):
    tok, caches, length = decode(params, caches, length, tok[:, None],
                                 jax.random.PRNGKey(10 + i))
    generated.append(tok)
jax.block_until_ready(tok)
dt = time.monotonic() - t0
seq = jnp.stack(generated, axis=1)
print(f"decoded {GEN-1} steps x {BATCH} reqs in {dt*1e3:.1f} ms "
      f"→ {(GEN-1)*BATCH/dt:.0f} tok/s (CPU)")
print("request 0 continuation:", seq[0].tolist())
