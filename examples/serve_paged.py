"""Paged KV-cache serving — block pool, prefix sharing, copy-on-write live.

Every prompt opens with the same 16-token "system prompt".  The first
request prefills it; every later overlapping request finds the prefix in the
block index, adopts the physical blocks (refcount++), copy-on-writes the
divergence block, and prefills only its own suffix.  Decode then walks each
sequence's block table — same token streams as the contiguous slot pool,
bit for bit, with the memory accounting printed to prove the sharing.

    PYTHONPATH=src python examples/serve_paged.py
"""
import jax

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import scheduler
from repro.serving.engine_api import Engine

cfg = configs.get_smoke("smollm_360m")
params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))

SLOTS, SLOT_LEN, BLOCK = 4, 64, 8
requests = scheduler.poisson_workload(
    12, rate_per_tick=3.0, prompt_lens=(4, 16), decode_lens=(2, 24),
    vocab=cfg.vocab_size, seed=0, shared_prefix=16)
print(f"{len(requests)} requests, all sharing a 16-token prompt prefix "
      f"(= {16 // BLOCK} full blocks at block_size={BLOCK})")

engine = Engine(
    params, cfg, num_slots=SLOTS, slot_len=SLOT_LEN, prefill_chunk=12,
    top_k=5, base_rng=jax.random.PRNGKey(42), paged=True, block_size=BLOCK)
report = engine.serve(requests)

pct = report.latency_percentiles((50, 95))
print(f"served {report.total_tokens} tokens in {report.wall_time:.2f}s "
      f"→ {report.tokens_per_s:.1f} tok/s "
      f"(occupancy {report.occupancy:.3f})")
p = report.paged
print(f"block pool: {p['num_blocks']}×{p['block_size']}, "
      f"min free {p['min_free_blocks']}, free at end {p['free_blocks']}")
print(f"blocks saved by sharing: {p['blocks_shared']}  "
      f"prefill tokens skipped: {p['tokens_reused']}  "
      f"copy-on-write copies: {p['cow_copies']}")
for r in sorted(report.results, key=lambda r: r.rid):
    print(f"  req {r.rid}: prompt {r.prompt_len:2d} → "
          f"{len(r.tokens):2d} tokens {r.tokens[:8]}"
          f"{'…' if len(r.tokens) > 8 else ''}")
