"""Enc-dec (whisper-style) serving: encode stub frame embeddings once, then
autoregressive decode with cached cross-attention K/V and fused top-k.

    PYTHONPATH=src python examples/serve_whisper.py
"""
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import encdec, layers as L
from repro.serving import engine

cfg = configs.get_smoke("whisper_small")
params, _ = L.split_params(encdec.init(jax.random.PRNGKey(0), cfg))

BATCH, GEN, MAX = 4, 24, 32
frames = jax.random.normal(jax.random.PRNGKey(1),
                           (BATCH, cfg.encoder_seq_len, cfg.d_model))
bos = jnp.zeros((BATCH, 1), jnp.int32)

t0 = time.monotonic()
prefill = jax.jit(lambda p, f, t: engine.encdec_prefill(p, f, t, cfg,
                                                        max_len=MAX))
last, caches, length = prefill(params, frames, bos)
jax.block_until_ready(last)
print(f"encode {BATCH}×{cfg.encoder_seq_len} frames + prime decoder: "
      f"{(time.monotonic()-t0)*1e3:.1f} ms")

decode = jax.jit(lambda p, c, ln, t, r: engine.encdec_decode_step(
    p, c, ln, t, cfg, rng=r, top_k=5), donate_argnums=(1,))
tok = bos[:, 0]
out = []
t0 = time.monotonic()
for i in range(GEN):
    tok, caches, length = decode(params, caches, length, tok[:, None],
                                 jax.random.PRNGKey(5 + i))
    out.append(tok)
jax.block_until_ready(tok)
dt = time.monotonic() - t0
print(f"decoded {GEN} steps × {BATCH} requests in {dt*1e3:.1f} ms "
      f"({GEN*BATCH/dt:.0f} tok/s on CPU)")
print("request 0 token ids:", jnp.stack(out, 1)[0].tolist())
