"""Continuous-batching serving — the slot-pool scheduler live.

Requests arrive staggered (Poisson); each one prefills in chunks between the
pool's decode steps, takes over a free KV slot, decodes at its own length in
the shared batch, and retires the moment it finishes — no drain, no refill.
Every decode step still ends in the paper's §4 scenario: vocab projection +
fused online-softmax top-k, now at full batch occupancy.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import jax

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import scheduler
from repro.serving.engine_api import Engine

cfg = configs.get_smoke("smollm_360m")
params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))

SLOTS, SLOT_LEN = 4, 64
requests = scheduler.poisson_workload(
    16, rate_per_tick=3.0, prompt_lens=(6, 24), decode_lens=(2, 36),
    vocab=cfg.vocab_size, seed=0)
print(f"{len(requests)} requests, prompts "
      f"{[len(r.prompt) for r in requests]}, "
      f"decode budgets {[r.max_new_tokens for r in requests]}")

engine = Engine(
    params, cfg, num_slots=SLOTS, slot_len=SLOT_LEN, prefill_chunk=12,
    top_k=5, base_rng=jax.random.PRNGKey(42))
report = engine.serve(requests)

pct = report.latency_percentiles((50, 95))
baseline = report.baseline_occupancy(SLOTS)
print(f"served {report.total_tokens} tokens in {report.wall_time:.2f}s "
      f"→ {report.tokens_per_s:.1f} tok/s")
print(f"per-token latency p50={pct['p50']*1e3:.1f}ms "
      f"p95={pct['p95']*1e3:.1f}ms")
print(f"occupancy {report.occupancy:.3f} vs drain-and-refill {baseline:.3f}")
for r in sorted(report.results, key=lambda r: r.rid):
    print(f"  req {r.rid}: prompt {r.prompt_len:2d} → "
          f"{len(r.tokens):2d} tokens {r.tokens[:8]}"
          f"{'…' if len(r.tokens) > 8 else ''}")
