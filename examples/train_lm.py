"""End-to-end training driver: a llama-style LM with every paper technique on
(online attention, chunked CE), fault-tolerant loop, checkpointing.

    PYTHONPATH=src python examples/train_lm.py               # ~15M params
    PYTHONPATH=src python examples/train_lm.py --full        # ~110M params

The --full config is the assignment's "~100M for a few hundred steps" driver
(sized for a real accelerator; the default is scaled so the demo finishes on
this 1-core CPU container while exercising the identical code path).
"""
import argparse

import jax

from repro.configs.base import ModelConfig, OptimizerConfig, RunConfig
from repro.data.synthetic import SyntheticConfig, SyntheticDataset
from repro.training import loop
from repro.training.train_step import init_state, make_train_step


def small_cfg() -> ModelConfig:
    return ModelConfig(
        name="demo-15m", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=1024,
        vocab_size=32768, max_seq_len=1024, vocab_chunks=8,
        attn_chunk=128, dtype="float32", tie_embeddings=True)


def full_cfg() -> ModelConfig:
    # ~110M params: 12L, d=768 — GPT-2-small-class with GQA + SwiGLU
    return ModelConfig(
        name="demo-110m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=50304, max_seq_len=2048, vocab_chunks=16,
        attn_chunk=512, dtype="bfloat16", tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = full_cfg() if args.full else small_cfg()
    run = RunConfig(
        model=cfg,
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=20,
                                  total_steps=args.steps),
        checkpoint_dir=args.ckpt, checkpoint_every=50, log_every=10)
    n = 0
    params, opt_state, _ = init_state(run, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M  "
          f"online_attn={cfg.use_online_attention} chunked_ce={cfg.use_chunked_ce}")

    ds = SyntheticDataset(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=0))
    step = jax.jit(make_train_step(run), donate_argnums=(0, 1))
    params, opt_state, hist = loop.run(
        run, steps=args.steps, train_step=step, params=params,
        opt_state=opt_state, dataset=ds)
    losses = [h["loss"] for h in hist]
    print(f"loss: {losses[0]:.4f} → {losses[-1]:.4f} over {len(losses)} steps")
    assert losses[-1] < losses[0], "training did not reduce the loss"


if __name__ == "__main__":
    main()
