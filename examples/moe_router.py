"""The MoE router IS the paper's Algorithm 4: fused softmax+top-k over the
expert dimension.  This example shows the router path of the qwen2-moe config
end to end: logits → fused top-k probs → capacity-bucketed dispatch.

    PYTHONPATH=src python examples/moe_router.py
"""
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import core
from repro.models import layers as L

cfg = configs.get_smoke("qwen2_moe_a2p7b")
mc = cfg.moe
print(f"router: {mc.num_experts} experts (padded to {mc.pad_experts_to}), "
      f"top-{mc.experts_per_token}, capacity factor {mc.capacity_factor}")

key = jax.random.PRNGKey(0)
moe_params = jax.tree.map(
    lambda p: p.value, L.moe_init(key, cfg), is_leaf=L.is_param)

B, T = 4, 64
x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))

# --- the router in isolation: Algorithm 4 at V = num_experts ---------------
logits = x.reshape(-1, cfg.d_model).astype(jnp.float32) @ moe_params["router"]
fused = core.softmax_topk(logits, mc.experts_per_token)
print("token 0 routed to experts", fused.indices[0].tolist(),
      "with probs", jnp.round(fused.values[0], 3).tolist())

# consistency with the unfused formulation:
unfused = core.safe_softmax_then_topk(logits, mc.experts_per_token)
assert jnp.allclose(fused.values, unfused.values, rtol=1e-5)
assert (fused.indices == unfused.indices).all()
print("fused == safe-softmax-then-topk ✓  (one pass instead of five)")

# --- the full MoE layer ------------------------------------------------------
y, aux = L.moe_apply(moe_params, x, cfg)
print(f"moe out shape {y.shape}; load-balance loss "
      f"{float(aux['moe_lb_loss']):.4f}; router z-loss "
      f"{float(aux['moe_z_loss']):.6f}")

# expert utilization
em = jax.nn.one_hot(fused.indices, mc.num_experts).sum((0, 1))
print("tokens per expert:", em.astype(int).tolist())
