"""Quickstart: the paper's primitives in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import core
from repro.kernels import ops

x = jax.random.normal(jax.random.PRNGKey(0), (4, 8192)) * 10

# 1. Online softmax (Algorithm 3): single-pass normalizer, same numerics as
#    the 3-pass safe softmax every framework uses.
y_online = core.online_softmax(x)
y_safe = core.safe_softmax(x)
print("online == safe softmax:",
      bool(jnp.allclose(y_online, y_safe, rtol=1e-5)))

# 2. The ⊕ operator (Eq. 4) lets ANY tiling compute the same normalizer —
#    this is what makes the parallel/distributed/Pallas versions possible.
m_a, d_a = core.online_normalizer(x[:, :4096])
m_b, d_b = core.online_normalizer(x[:, 4096:])
m, d = core.combine((m_a, d_a), (m_b, d_b))
m_ref, d_ref = core.online_normalizer(x)
print("⊕-merged tiles == whole vector:",
      bool(jnp.allclose(m, m_ref)) and bool(jnp.allclose(d, d_ref, rtol=1e-5)))

# 3. Fused Softmax+TopK (Algorithm 4): one pass over the vocabulary.
vals, idx, lse = ops.softmax_topk(x, 5)          # Pallas kernel (interpret on CPU)
print("top-5 probs:", jnp.round(vals[0], 4).tolist())
print("top-5 ids:  ", idx[0].tolist())

# 4. Online-softmax attention (the FlashAttention recurrence, pure JAX):
q = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 4, 32))
k = jax.random.normal(jax.random.PRNGKey(2), (1, 1024, 1, 32))
v = jax.random.normal(jax.random.PRNGKey(3), (1, 1024, 1, 32))
out = core.online_attention(q, k, v, causal=False, chunk_size=256)
ref = core.naive_attention(q, k, v, causal=False)
print("chunked attention == naive:", bool(jnp.allclose(out, ref, atol=2e-5)))

# 5. Chunked cross-entropy (§7 fusion): the [T, V] logit tensor never exists.
h = jax.random.normal(jax.random.PRNGKey(4), (256, 64))
w = jax.random.normal(jax.random.PRNGKey(5), (64, 50304)) * 0.02
labels = jax.random.randint(jax.random.PRNGKey(6), (256,), 0, 50304)
loss = core.chunked_cross_entropy(h, w, labels, num_chunks=16).mean()
full = core.full_cross_entropy(h, w, labels).mean()
print(f"chunked CE {float(loss):.4f} == full CE {float(full):.4f}")
