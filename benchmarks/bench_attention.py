"""Beyond-paper: online (chunked) attention vs naive attention — the paper's
⊕ recurrence is what makes the chunked form exact.  Forward and fwd+bwd, with
the naive path's materialized-score memory as the derived column.

Also recorded: the serving-prefill comparison — cached chunked prefill at
``q_offset > 0`` on the offset-aware Pallas flash kernel vs the chunked XLA
form (the two sides of the PR-3 dispatch routing decision).  On a host
without native Pallas lowering the kernel runs in interpret mode; the derived
column records which, so cross-machine diffs (``run.py report``) aren't read
as kernel regressions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import compat
from repro.core import naive_attention, online_attention

CASES = [
    # (B, T, Hq, Hkv, Dh, chunk)
    (2, 1024, 8, 2, 64, 256),
    (2, 2048, 8, 2, 64, 512),
    (1, 4096, 4, 1, 64, 512),
]
SMOKE_CASES = [(1, 256, 4, 2, 32, 64)]

# cached prefill: (B, chunk_t, S_cache, Hq, Hkv, Dh, q_offset)
PREFILL_CASES = [
    (4, 32, 2048, 8, 2, 64, 1024),
    (8, 64, 4096, 8, 2, 64, 2048),
]
PREFILL_SMOKE = [(2, 8, 128, 4, 2, 32, 64)]


def _prefill_rows(smoke: bool) -> list[tuple]:
    """Pallas (offset kernel) vs chunked XLA on the cached-prefill shape."""
    from repro.kernels import ops
    mode = "pallas" if compat.pallas_native() else "pallas-interpret"
    rows = []
    for b, t, s, hq, hkv, dh, off in (PREFILL_SMOKE if smoke
                                      else PREFILL_CASES):
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (b, t, hq, dh), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, dh), jnp.float32)
        qoff = jnp.full((b,), off, jnp.int32)
        vlen = qoff + t
        tag = f"attention/prefill_S={s}_t={t}_off={off}"
        pallas_f = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, causal=True, q_offset=qoff, kv_valid_len=vlen))
        xla_f = jax.jit(lambda q, k, v: online_attention(
            q, k, v, causal=True, q_offset=qoff, kv_valid_len=vlen,
            chunk_size=min(512, s)))
        rows.append((f"{tag}/pallas_fwd", time_fn(pallas_f, q, k, v), mode))
        rows.append((f"{tag}/xla_chunked_fwd", time_fn(xla_f, q, k, v),
                     "chunked-xla"))
    return rows


def run(smoke: bool = False) -> list[tuple]:
    rows = _prefill_rows(smoke)
    for b, t, hq, hkv, dh, chunk in (SMOKE_CASES if smoke else CASES):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (b, t, hq, dh), jnp.float32)
        k = jax.random.normal(ks[1], (b, t, hkv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (b, t, hkv, dh), jnp.float32)
        score_mb = b * hq * t * t * 4 / 2**20
        naive_f = jax.jit(lambda q, k, v: naive_attention(q, k, v, causal=True))
        online_f = jax.jit(lambda q, k, v: online_attention(
            q, k, v, causal=True, chunk_size=chunk))
        rows.append((f"attention/T={t}/naive_fwd", time_fn(naive_f, q, k, v),
                     f"score_matrix={score_mb:.0f}MB"))
        rows.append((f"attention/T={t}/online_fwd", time_fn(online_f, q, k, v),
                     f"score_matrix=chunked({chunk})"))
        ng = jax.jit(jax.grad(lambda q, k, v: naive_attention(
            q, k, v, causal=True).sum(), argnums=0))
        og = jax.jit(jax.grad(lambda q, k, v: online_attention(
            q, k, v, causal=True, chunk_size=chunk).sum(), argnums=0))
        rows.append((f"attention/T={t}/naive_fwdbwd", time_fn(ng, q, k, v), ""))
        rows.append((f"attention/T={t}/online_fwdbwd", time_fn(og, q, k, v),
                     ""))
    return rows


if __name__ == "__main__":
    emit(run())
