"""Paper Figures 3 & 4: Softmax+TopK — safe unfused vs safe fused vs online
fused (K=5), large and small batch.  ``derived`` = the paper's access model
(safe unfused 5/elem, safe fused 2/elem, online fused 1/elem → up to 5x)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import ACCESSES_PER_ELEMENT, safe_softmax, softmax_topk
from repro.core.topk_fusion import safe_softmax_then_topk

V_SWEEP = (1024, 4096, 16384, 65536)
BATCHES = {"large": 512, "small": 10}
SMOKE_V_SWEEP = (1024,)
SMOKE_BATCHES = {"small": 8}
K = 5


def _safe_fused(x, k):
    """Safe softmax with the top-k fused into the normalizer pass (2/elem):
    separate max pass, then a single pass producing d and the top-k."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    d = jnp.sum(e, axis=-1, keepdims=True)
    vals, idx = jax.lax.top_k(x, k)
    return jnp.exp(vals - m) / d, idx


VARIANTS = {
    "safe_unfused": lambda x: safe_softmax_then_topk(x, K)[:2],
    "safe_fused": lambda x: _safe_fused(x, K),
    "online_fused": lambda x: softmax_topk(x, K)[:2],
    "online_fused_blocked": lambda x: softmax_topk(x, K,
                                                   block=min(4096,
                                                             x.shape[-1]))[:2],
}

ACCESS = {"safe_unfused": 5, "safe_fused": 2, "online_fused": 1,
          "online_fused_blocked": 1}


def run(smoke: bool = False) -> list[tuple]:
    rows = []
    for regime, b in (SMOKE_BATCHES if smoke else BATCHES).items():
        for v in (SMOKE_V_SWEEP if smoke else V_SWEEP):
            x = jax.random.normal(jax.random.PRNGKey(1), (b, v), jnp.float32)
            base = None
            for name, fn in VARIANTS.items():
                us = time_fn(jax.jit(fn), x)
                if name == "safe_unfused":
                    base = us
                rows.append((f"softmax_topk/{regime}/V={v}/{name}", us,
                             f"pred_access_ratio={5 / ACCESS[name]:.1f}"))
            rows.append((f"softmax_topk/{regime}/V={v}/online_vs_unfused",
                         rows[-2][1], f"measured={base / rows[-2][1]:.2f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
