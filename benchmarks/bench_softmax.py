"""Paper Figures 1 & 2: Naive vs Safe vs Online softmax across vector sizes,
large-batch (training/batch-inference) and small-batch (online-inference).

Scaled for the CPU container: batch 512 stands in for the paper's 4000 (same
bandwidth-saturating regime relative to cache size); the V sweep covers the
paper's 1e2..1e5 range.  ``derived`` = paper's predicted access ratio
(safe=4/elem baseline; naive=online=3/elem → 1.33x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import ACCESSES_PER_ELEMENT, naive_softmax, online_softmax, safe_softmax

V_SWEEP = (256, 1024, 4096, 16384, 65536)
BATCHES = {"large": 512, "small": 10}
SMOKE_V_SWEEP = (256, 1024)
SMOKE_BATCHES = {"large": 32, "small": 4}

ALGOS = {
    "naive": naive_softmax,
    "safe": safe_softmax,
    "online": online_softmax,
}


def run(smoke: bool = False) -> list[tuple]:
    rows = []
    for regime, b in (SMOKE_BATCHES if smoke else BATCHES).items():
        for v in (SMOKE_V_SWEEP if smoke else V_SWEEP):
            x = jax.random.normal(jax.random.PRNGKey(0), (b, v), jnp.float32)
            base_us = None
            for name, fn in ALGOS.items():
                jf = jax.jit(fn)
                us = time_fn(jf, x)
                if name == "safe":
                    base_us = us
                ratio = (ACCESSES_PER_ELEMENT["safe_softmax"]
                         / ACCESSES_PER_ELEMENT[f"{name}_softmax"])
                rows.append((f"softmax/{regime}/V={v}/{name}", us,
                             f"pred_access_ratio={ratio:.2f}"))
            # measured speedup of online vs safe for this (regime, V)
            online_us = rows[-1][1]
            rows.append((f"softmax/{regime}/V={v}/online_vs_safe_speedup",
                         online_us, f"measured={base_us / online_us:.2f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
