"""Serving benchmark: the continuous-batching loop under staggered load.

Reports throughput (as µs per generated token), p50/p95 per-token latency,
and batch occupancy against the drain-and-refill bound — the serving-side
numbers the paper's §4 fusion is supposed to move.  Smoke mode runs a
seconds-long workload so tier-1 keeps the harness honest.

``paged=True`` serves the same workload through the paged KV cache
(``repro.serving.paged``): block-pool allocation, a shared prompt prefix so
the prefix index engages, and extra rows for the block accounting.  The
common row names are deliberately identical to the slot-pool run so
``run.py report slotpool.json paged.json`` diffs the two modes directly.

``priorities=True`` makes the workload mixed-priority (two classes, the
urgent one deadline-bearing) over a deliberately undersized block pool, and
adds SLO-attainment / p95-by-class / preemption rows; ``preempt=False``
serves the identical workload with preempt-and-swap disabled, so
``run.py report preempt_off.json preempt_on.json`` isolates what preemption
buys the urgent class.
"""
from __future__ import annotations

import jax


def run(smoke: bool = False, paged: bool = False, priorities: bool = False,
        preempt: bool = True) -> list:
    import repro.configs as configs
    from repro.models import layers as L, transformer
    from repro.serving import scheduler

    cfg = configs.get_smoke("smollm_360m")
    block_size = 8
    slo_ms = 60_000.0                  # generous CPU-CI deadline: the metric
    if smoke:                          # should move, not saturate at 0
        n_req, slots, slot_len, chunk = 6, 2, 40, 8
        prompt_lens, decode_lens, rate = (4, 12), (2, 8), 2.0
        shared_prefix = 8              # one full block at block_size=8
    else:
        n_req, slots, slot_len, chunk = 32, 8, 96, 16
        prompt_lens, decode_lens, rate = (8, 48), (4, 40), 3.0
        shared_prefix = 16
    paged_kw = dict(paged=True, block_size=block_size) if paged else {}
    if priorities and paged:
        # undersize the pool so urgent arrivals actually contend with
        # running low-priority decodes — the regime preemption exists for
        paged_kw["num_blocks"] = (slots + 1) * (slot_len // block_size) // 2
    paged_kw["preempt"] = preempt

    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    # priorities seed: urgent (priority-0) arrivals land AFTER low-priority
    # decodes occupy the pool — the contention preemption exists to resolve
    requests = scheduler.poisson_workload(
        n_req, rate_per_tick=rate, prompt_lens=prompt_lens,
        decode_lens=decode_lens, vocab=cfg.vocab_size,
        seed=6 if priorities else 0,
        shared_prefix=shared_prefix if paged else 0,
        priority_classes=2 if priorities else 1,
        slo_ms=slo_ms if priorities else None)

    # warmup: the compiled step functions are shared across scheduler
    # instances, and a prompt of 2*chunk-1 hits every prefill width the
    # binary chunk schedule can produce — so the timed run below measures
    # serving, not jit compilation
    import numpy as np
    warm = scheduler.ContinuousScheduler(
        params, cfg, num_slots=slots, slot_len=slot_len, prefill_chunk=chunk,
        top_k=5, base_rng=jax.random.PRNGKey(1), **paged_kw)
    warm_reqs = [scheduler.Request(rid=0, prompt=np.arange(2 * chunk - 1)
                                   % 100, max_new_tokens=2)]
    if priorities and preempt:
        # also warm the preempt-and-swap path (swap-in's block restore jits
        # once per pool shape): low-priority decodes filling every row, then
        # an urgent arrival that must swap one out
        warm_reqs = [
            scheduler.Request(rid=i, prompt=np.arange(2 * chunk - 1) % 100,
                              max_new_tokens=10, priority=1)
            for i in range(slots)
        ] + [scheduler.Request(rid=slots, prompt=np.arange(chunk) % 100,
                               max_new_tokens=2, arrival_tick=3, priority=0)]
    warm.run(warm_reqs)

    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=slots, slot_len=slot_len, prefill_chunk=chunk,
        top_k=5, base_rng=jax.random.PRNGKey(0), **paged_kw)
    report = sched.run(requests)

    pct = report.latency_percentiles((50, 95))
    baseline = report.baseline_occupancy(slots)
    tag = "smoke" if smoke else "full"
    rows = [
        (f"serving/{tag}/per_token", 1e6 / max(report.tokens_per_s, 1e-9),
         f"{report.tokens_per_s:.1f}tok/s"),
        (f"serving/{tag}/p50_latency", pct["p50"] * 1e6,
         f"n={report.total_tokens}"),
        (f"serving/{tag}/p95_latency", pct["p95"] * 1e6,
         f"n={report.total_tokens}"),
        (f"serving/{tag}/occupancy_pct", report.occupancy * 100.0,
         f"drain_refill={baseline * 100.0:.1f}"),
    ]
    if report.paged is not None:
        p = report.paged
        rows.append((f"serving/{tag}/blocks_shared", float(p["blocks_shared"]),
                     f"tokens_reused={p['tokens_reused']} "
                     f"cow={p['cow_copies']} "
                     f"min_free={p['min_free_blocks']}/{p['num_blocks']}"))
    if priorities:
        att = report.slo_attainment()
        bearing = sum(1 for r in report.results if r.slo_ms is not None)
        by_class = report.latency_percentiles_by_class((95,))
        rows.append((f"serving/{tag}/slo_attained_pct",
                     (att or 0.0) * 100.0,
                     f"slo_ms={slo_ms:.0f} n={bearing} "
                     f"preempt={'on' if preempt else 'off'}"))
        rows.append((f"serving/{tag}/p95_latency_hipri",
                     by_class.get(0, {}).get("p95", 0.0) * 1e6,
                     "priority=0"))
        if report.paged is not None:
            p = report.paged
            rows.append((f"serving/{tag}/preemptions",
                         float(report.preemptions),
                         f"swap_out={p['swapped_blocks_out']} "
                         f"swap_in={p['swapped_blocks_in']} "
                         f"reclaimed={p['reclaimed_blocks']}"))
    return rows
