"""Serving benchmark: the continuous-batching loop under staggered load.

Reports throughput (as µs per generated token), p50/p95 per-token latency,
and batch occupancy against the drain-and-refill bound — the serving-side
numbers the paper's §4 fusion is supposed to move.  Smoke mode runs a
seconds-long workload so tier-1 keeps the harness honest.

``paged=True`` serves the same workload through the paged KV cache
(``repro.serving.paged``): block-pool allocation, a shared prompt prefix so
the prefix index engages, and extra rows for the block accounting.  The
common row names are deliberately identical to the slot-pool run so
``run.py report slotpool.json paged.json`` diffs the two modes directly.

``priorities=True`` makes the workload mixed-priority (two classes, the
urgent one deadline-bearing) over a deliberately undersized block pool, and
adds SLO-attainment / p95-by-class / preemption rows; ``preempt=False``
serves the identical workload with preempt-and-swap disabled, so
``run.py report preempt_off.json preempt_on.json`` isolates what preemption
buys the urgent class.

``replicas=N`` serves a prefix-heavy workload (four prefix groups, every
request deadline-bearing) through ``repro.serving.router.ReplicaRouter``
over N paged engine replicas and adds ``tok_s_total`` /
``slo_attained_pct`` / ``prefix_hit_rate`` / ``backpressure_rejects`` rows.
The workload is IDENTICAL for every N (and for ``affinity=False``), so
``run.py report replicas1.json replicas4.json`` is the scaling diff and an
affinity-off run isolates what prefix routing buys.

``kv="int8"`` serves the identical workload with the quantized KV cache
(``kv_cache_dtype`` on the config → the ``dense_int8`` cache family):
int8 K/V pools beside bfloat16 scale pages, dequantized inside the paged
gather.  Row names stay identical to the fp run — ``run.py report fp.json
int8.json`` is the capacity/latency diff — and paged runs gain a
``pool_capacity`` row (bytes per cacheable token) so the report quantifies
what quantization buys in pool footprint.  The family is non-shareable, so
the shared-prefix stats read 0 by design; the workload stays the same for
comparability.

``arch=NAME`` serves a different smoke architecture through the same
harness: ``zamba2_1p2b`` / ``xlstm_125m`` exercise the fixed-state cache
family (one refcounted block per sequence; prompts snap to the state scan's
chunk quantum), ``whisper_small`` the enc-dec family (prompts become a small
pool of repeated audio clips so encoder-block sharing engages).  Non-default
archs are forced paged — the block accounting is the point — and emit
``serving/{tag}/{arch}/*`` rows so default-arch diffs stay comparable.

All modes drive the engine layer (``Engine`` / ``ReplicaRouter``) — the
grep-policy test pins that nothing here touches ``ContinuousScheduler``
directly.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks import common


def run(smoke: bool = False, paged: bool = False, priorities: bool = False,
        preempt: bool = True, replicas: int = 0,
        affinity: bool = True, obs: bool = False,
        arch: str = "smollm_360m", kv: str = "") -> list:
    import repro.configs as configs
    from repro.models import encdec, layers as L, transformer
    from repro.serving import cache_family, scheduler
    from repro.serving.engine_api import Engine
    from repro.serving.router import ReplicaRouter

    cfg = configs.get_smoke(arch)
    if kv:
        cfg = cfg.replace(kv_cache_dtype=kv)
    family = cache_family.resolve(cfg)
    if family.kind != "token" and (priorities or replicas or obs):
        raise SystemExit(f"--arch {arch} ({family.name}): only the plain "
                         "serving rows are benchmarked for non-dense "
                         "cache families")
    if family.requires_paged or family.kind == "state":
        paged = True               # the families this arch flag exists for
    block_size = 8
    slo_ms = 60_000.0                  # generous CPU-CI deadline: the metric
    if smoke:                          # should move, not saturate at 0
        n_req, slots, slot_len, chunk = 6, 2, 40, 8
        prompt_lens, decode_lens, rate = (4, 12), (2, 8), 2.0
        shared_prefix = 8              # one full block at block_size=8
    else:
        n_req, slots, slot_len, chunk = 32, 8, 96, 16
        prompt_lens, decode_lens, rate = (8, 48), (4, 40), 3.0
        shared_prefix = 16
    if replicas:
        paged = True                   # affinity is a paged-cache economy
    paged_kw = dict(paged=True, block_size=block_size) if paged else {}
    if priorities and paged and not replicas:
        # undersize the pool so urgent arrivals actually contend with
        # running low-priority decodes — the regime preemption exists for
        paged_kw["num_blocks"] = (slots + 1) * (slot_len // block_size) // 2
    paged_kw["preempt"] = preempt

    init_fn = encdec.init if family.kind == "encdec" else transformer.init
    params, _ = L.split_params(init_fn(jax.random.PRNGKey(0), cfg))
    if replicas:
        # prefix-heavy: four groups, each sharing its own system prompt —
        # the SAME workload for every replica count / routing policy, so
        # cross-run diffs measure the router, not the traffic
        per_group = 3 if smoke else 8
        requests = []
        for g in range(4):
            for r in scheduler.poisson_workload(
                    per_group, rate_per_tick=rate / 2,
                    prompt_lens=prompt_lens, decode_lens=decode_lens,
                    vocab=cfg.vocab_size, seed=10 + g,
                    shared_prefix=shared_prefix, slo_ms=slo_ms):
                requests.append(dataclasses.replace(
                    r, rid=g * per_group + r.rid))
        requests.sort(key=lambda r: (r.arrival_tick, r.rid))
    else:
        # priorities seed: urgent (priority-0) arrivals land AFTER
        # low-priority decodes occupy the pool — the contention preemption
        # exists to resolve
        requests = scheduler.poisson_workload(
            n_req, rate_per_tick=rate, prompt_lens=prompt_lens,
            decode_lens=decode_lens, vocab=cfg.vocab_size,
            seed=6 if priorities else 0,
            shared_prefix=shared_prefix if paged else 0,
            priority_classes=2 if priorities else 1,
            slo_ms=slo_ms if priorities else None)

    import numpy as np
    if family.kind == "encdec":
        # prompts are audio: a small pool of distinct clips, repeated, so
        # the encoder-block sharing the family exists for actually engages
        audio_rng = np.random.default_rng(2)
        audios = [audio_rng.integers(0, cfg.vocab_size, cfg.encoder_seq_len)
                  for _ in range(3)]
        requests = [dataclasses.replace(r, prompt=audios[r.rid % len(audios)])
                    for r in requests]
        # headroom so finished requests' encoder chains survive in the LRU
        # prefix cache until the repeat arrives — the sharing being measured
        nc = cfg.encoder_seq_len // block_size
        paged_kw["num_blocks"] = slots * (nc + 1) + len(audios) * nc
    elif family.kind == "state":
        # single-shot prefill goes through the chunked state scan: snap
        # prompt lengths onto the scan's quantum
        q = family.prompt_quantum()
        requests = [dataclasses.replace(
            r, prompt=np.resize(r.prompt, len(r.prompt)
                                if len(r.prompt) <= q
                                else max(q, len(r.prompt) // q * q)))
            for r in requests]

    # warmup: the compiled step functions are shared across scheduler
    # instances (and all router replicas), and a prompt of 2*chunk-1 hits
    # every prefill width the binary chunk schedule can produce — so the
    # timed run below measures serving, not jit compilation
    warm = Engine(
        params, cfg, num_slots=slots, slot_len=slot_len, prefill_chunk=chunk,
        top_k=5, base_rng=jax.random.PRNGKey(1), **paged_kw)
    if family.kind == "encdec":
        warm_reqs = [scheduler.Request(rid=0, prompt=audios[0],
                                       max_new_tokens=2)]
    elif family.kind == "state":
        warm_reqs = [scheduler.Request(
            rid=0, prompt=np.arange(family.prompt_quantum()) % 100,
            max_new_tokens=2)]
    elif family.single_shot_prefill:
        # single-shot prefill (quantized families) jits once per distinct
        # prompt length — the binary chunk schedule never engages — so warm
        # every length the workload will present
        warm_reqs = [scheduler.Request(rid=i, prompt=np.arange(n) % 100,
                                       max_new_tokens=2)
                     for i, n in enumerate(sorted({len(r.prompt)
                                                   for r in requests}))]
    else:
        warm_reqs = [scheduler.Request(rid=0, prompt=np.arange(2 * chunk - 1)
                                       % 100, max_new_tokens=2)]
    if priorities and preempt and not replicas:
        # also warm the preempt-and-swap path (swap-in's block restore jits
        # once per pool shape): low-priority decodes filling every row, then
        # an urgent arrival that must swap one out
        warm_reqs = [
            scheduler.Request(rid=i, prompt=np.arange(2 * chunk - 1) % 100,
                              max_new_tokens=10, priority=1)
            for i in range(slots)
        ] + [scheduler.Request(rid=slots, prompt=np.arange(chunk) % 100,
                               max_new_tokens=2, arrival_tick=3, priority=0)]
    warm.serve(warm_reqs)

    if replicas:
        router = ReplicaRouter(
            params, cfg, replicas=replicas, affinity=affinity,
            num_slots=slots, slot_len=slot_len, prefill_chunk=chunk,
            top_k=5, base_rng=jax.random.PRNGKey(0), **paged_kw)
        report = router.serve(requests)
    else:
        eng = Engine(
            params, cfg, num_slots=slots, slot_len=slot_len,
            prefill_chunk=chunk, top_k=5, base_rng=jax.random.PRNGKey(0),
            **paged_kw)
        report = eng.serve(requests)

    obs_row = None
    if obs and not replicas:
        # overhead measurement: the IDENTICAL workload on a fresh engine
        # (jits shared via lru_cache) with tracing + metrics armed, so the
        # per_token vs per_token_obs diff is the full observability cost
        import os
        import tempfile
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        def _serve_once(tracer):
            eng2 = Engine(
                params, cfg, num_slots=slots, slot_len=slot_len,
                prefill_chunk=chunk, top_k=5,
                base_rng=jax.random.PRNGKey(0), tracer=tracer, **paged_kw)
            return eng2.serve(requests)

        # interleaved fastest-half comparison: this shared-CPU box adds
        # ±5-8% of contention noise per serve, but the noise is strictly
        # additive (neighbours only ever slow a serve down), so the fastest
        # serves of each mode approach the uncontended cost.  The mean of
        # the fastest HALF (rather than the single min) keeps six samples
        # in the estimate, which a lone unlucky burst can't swing; strict
        # on/off interleaving with alternating order means both modes
        # sample the same calm windows.  A median of paired ratios — the
        # obvious alternative — inherits the full per-pair scatter and
        # needs ~10x the samples to say anything under 5%.
        def _serve_on():
            obs_metrics.enable()
            fd, trace_path = tempfile.mkstemp(suffix=".json")
            os.close(fd)
            tracer = obs_trace.Tracer(trace_path)
            rate = _serve_once(tracer).tokens_per_s
            tracer.close()
            n_events = tracer.total_events
            os.unlink(trace_path)
            obs_metrics.disable()
            return rate, n_events

        was_enabled = obs_metrics.enabled()
        obs_metrics.disable()
        on_rates, off_rates, events = [], [], 0
        for i in range(16):
            if i % 2 == 0:                 # alternate order within the
                rate_on, events = _serve_on()   # interleave as well
                off_rates.append(_serve_once(None).tokens_per_s)
            else:
                off_rates.append(_serve_once(None).tokens_per_s)
                rate_on, events = _serve_on()
            on_rates.append(rate_on)
        if was_enabled:
            obs_metrics.enable()
        fast_on = common.fastest_half_mean(on_rates, bigger_is_faster=True)
        fast_off = common.fastest_half_mean(off_rates, bigger_is_faster=True)
        overhead = (fast_off / max(fast_on, 1e-9) - 1.0) * 100.0
        obs_row = (1e6 / max(fast_on, 1e-9),
                   f"overhead={overhead:+.1f}% events={events}")

    pct = report.latency_percentiles((50, 95))
    baseline = report.baseline_occupancy(slots * max(replicas, 1))
    tag = "smoke" if smoke else "full"
    if arch != "smollm_360m":
        # default rows keep their pinned serving/{smoke,full}/* names so
        # existing report diffs keep working; other archs get their own
        tag = f"{tag}/{arch}"
    rows = [
        (f"serving/{tag}/per_token", 1e6 / max(report.tokens_per_s, 1e-9),
         f"{report.tokens_per_s:.1f}tok/s"),
        (f"serving/{tag}/p50_latency", pct["p50"] * 1e6,
         f"n={report.total_tokens}"),
        (f"serving/{tag}/p95_latency", pct["p95"] * 1e6,
         f"n={report.total_tokens}"),
        (f"serving/{tag}/occupancy_pct", report.occupancy * 100.0,
         f"drain_refill={baseline * 100.0:.1f}"),
    ]
    if obs_row is not None:
        rows.insert(1, (f"serving/{tag}/per_token_obs", *obs_row))
    if report.paged is not None:
        p = report.paged
        rows.append((f"serving/{tag}/blocks_shared", float(p["blocks_shared"]),
                     f"tokens_reused={p['tokens_reused']} "
                     f"cow={p['cow_copies']} "
                     f"min_free={p['min_free_blocks']}/{p['num_blocks']}"))
        if family.kind == "token":
            # pool footprint per cacheable token — the number quantized K/V
            # exists to move.  eval_shape so the row costs no allocation;
            # the family owns the layout, so scale pages are counted
            # without this harness knowing any dtype.  (Only token-kind
            # families page block_size tokens per block; state/enc-dec
            # blocks hold whole rows, so the unit would lie there.)
            pool_sds = jax.eval_shape(
                lambda: family.init_paged_cache(p["num_blocks"], block_size,
                                                slot_len))
            pool_bytes = sum(l.size * l.dtype.itemsize
                             for l in jax.tree_util.tree_leaves(pool_sds))
            pool_tokens = (p["num_blocks"] - 1) * block_size  # minus sentinel
            rows.append((f"serving/{tag}/pool_bytes_per_token",
                         pool_bytes / max(pool_tokens, 1),
                         f"kv={cfg.kv_cache_dtype or 'fp'} "
                         f"tok_per_kib="
                         f"{1024.0 * pool_tokens / pool_bytes:.2f} "
                         f"pool_kib={pool_bytes / 1024.0:.1f}"))
    if replicas:
        p = report.paged
        r = report.router
        prompt_tokens = sum(res.prompt_len for res in report.results)
        att = report.slo_attainment()
        bearing = sum(1 for res in report.results if res.slo_ms is not None)
        routing = "affinity" if r["affinity"] else "round_robin"
        rows.append((f"serving/{tag}/tok_s_total", report.tokens_per_s,
                     f"replicas={replicas} routing={routing}"))
        rows.append((f"serving/{tag}/slo_attained_pct",
                     (att or 0.0) * 100.0,
                     f"slo_ms={slo_ms:.0f} n={bearing}"))
        rows.append((f"serving/{tag}/prefix_hit_rate",
                     100.0 * p["tokens_reused"] / max(prompt_tokens, 1),
                     f"tokens_reused={p['tokens_reused']}"
                     f"/{prompt_tokens} routing={routing}"))
        rows.append((f"serving/{tag}/backpressure_rejects",
                     float(r["backpressure_rejects"]),
                     f"of {len(requests)} submitted"))
    if priorities and not replicas:
        att = report.slo_attainment()
        bearing = sum(1 for r in report.results if r.slo_ms is not None)
        by_class = report.latency_percentiles_by_class((95,))
        rows.append((f"serving/{tag}/slo_attained_pct",
                     (att or 0.0) * 100.0,
                     f"slo_ms={slo_ms:.0f} n={bearing} "
                     f"preempt={'on' if preempt else 'off'}"))
        rows.append((f"serving/{tag}/p95_latency_hipri",
                     by_class.get(0, {}).get("p95", 0.0) * 1e6,
                     "priority=0"))
        if report.paged is not None:
            p = report.paged
            rows.append((f"serving/{tag}/preemptions",
                         float(report.preemptions),
                         f"swap_out={p['swapped_blocks_out']} "
                         f"swap_in={p['swapped_blocks_in']} "
                         f"reclaimed={p['reclaimed_blocks']}"))
    return rows
