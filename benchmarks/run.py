"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  bench_softmax        → paper Fig. 1 & 2 (naive/safe/online × V × batch)
  bench_softmax_topk   → paper Fig. 3 & 4 (fused vs unfused, K=5)
  bench_topk_sweep     → paper §5.2 (K degradation)
  bench_attention      → beyond-paper (online attention)
  bench_chunked_ce     → beyond-paper (§7 fusion at the LM head)
  bench_serving        → beyond-paper (continuous batching: tok/s, p50/p95
                         per-token latency, occupancy vs drain-and-refill;
                         ``--paged`` serves through the paged KV cache and
                         adds block-sharing accounting; ``--replicas N``
                         routes over N engines with prefix affinity;
                         ``--kv int8`` serves through the quantized cache
                         family and adds a pool-capacity row)

``--smoke`` shrinks every sweep to a seconds-long sanity pass (tiny V/batch,
one case per module) — the tier-1 suite runs it so the harness itself can't
rot between full benchmark runs.  ``--json PATH`` additionally records the
rows plus the probed backend capabilities to a results file.

``report A.json B.json`` diffs two such result files into an
EXPERIMENTS.md-style markdown table (name | baseline | candidate | Δ%),
flagging rows present on only one side and any env mismatch — paste it into
EXPERIMENTS.md as the record of a before/after run.

``--history PATH`` (or ``REPRO_BENCH_HISTORY``) additionally appends every
``--json`` run as one record to the append-only JSONL history store
(``repro.obs.history``), keyed by row name + env fingerprint.

``check`` is the CI regression gate: it takes a candidate run (``--from
results.json``, or runs the named benches itself), compares each row
against the noise-aware baseline built from the last K same-env history
records (``repro.obs.regress``: median + fastest-half mean, per-row
relative thresholds), prints the verdict table, and exits nonzero iff any
row regressed.  ``--update-baseline`` records the candidate into history
(and exits 0) — how a fresh environment seeds its baseline and how an
accepted perf change becomes the new normal.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# runnable as `python benchmarks/run.py` from anywhere: put the repo root
# (for `benchmarks.*`) and src (for `repro.*`) on the path
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _load_results(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "rows" not in data:
        raise SystemExit(f"{path}: not a benchmarks/run.py --json results "
                         "file (no 'rows')")
    return data


def report(baseline_path: str, candidate_path: str, out=None) -> str:
    """Markdown diff of two ``--json`` result files (EXPERIMENTS.md-style)."""
    base = _load_results(baseline_path)
    cand = _load_results(candidate_path)
    b_rows = {r["name"]: r for r in base["rows"]}
    c_rows = {r["name"]: r for r in cand["rows"]}
    lines = [f"## Benchmark diff — {os.path.basename(baseline_path)} → "
             f"{os.path.basename(candidate_path)}", ""]
    env_keys = sorted(set(base.get("env", {})) | set(cand.get("env", {})))
    if env_keys:
        lines += ["| env | baseline | candidate |", "|---|---|---|"]
        for k in env_keys:
            bv = base.get("env", {}).get(k, "—")
            cv = cand.get("env", {}).get(k, "—")
            flag = "" if bv == cv else " ⚠"
            lines.append(f"| {k}{flag} | {bv} | {cv} |")
        lines.append("")
    lines += ["| name | baseline µs | candidate µs | Δ% | derived |",
              "|---|---:|---:|---:|---|"]
    for name in sorted(set(b_rows) & set(c_rows)):
        b, c = b_rows[name], c_rows[name]
        bu, cu = float(b["us_per_call"]), float(c["us_per_call"])
        delta = (cu - bu) / bu * 100.0 if bu else float("inf")
        derived = c.get("derived") or b.get("derived") or ""
        lines.append(f"| {name} | {bu:.2f} | {cu:.2f} | {delta:+.1f}% "
                     f"| {derived} |")
    only_b = sorted(set(b_rows) - set(c_rows))
    only_c = sorted(set(c_rows) - set(b_rows))
    if only_b:
        lines += ["", "Rows only in baseline: " + ", ".join(only_b)]
    if only_c:
        lines += ["", "Rows only in candidate: " + ", ".join(only_c)]
    text = "\n".join(lines) + "\n"
    if out:
        with open(out, "w") as f:
            f.write(text)
    return text


def _report_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="run.py report",
        description="Diff two --json result files into a markdown table.")
    ap.add_argument("baseline", help="results JSON of the 'before' run")
    ap.add_argument("candidate", help="results JSON of the 'after' run")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="also write the markdown to PATH")
    args = ap.parse_args(argv)
    sys.stdout.write(report(args.baseline, args.candidate, out=args.out))
    return 0


def _capability_env() -> dict:
    from repro import compat
    caps = compat.capabilities()
    return {"backend": caps.backend,
            "jax_version": caps.jax_version,
            "device_count": caps.device_count,
            "pallas_native": caps.pallas_native}


def _bench_mods() -> dict:
    from benchmarks import (
        bench_attention,
        bench_chunked_ce,
        bench_serving,
        bench_softmax,
        bench_softmax_topk,
        bench_topk_sweep,
    )
    return {
        "softmax": bench_softmax,
        "softmax_topk": bench_softmax_topk,
        "topk_sweep": bench_topk_sweep,
        "attention": bench_attention,
        "chunked_ce": bench_chunked_ce,
        "serving": bench_serving,
    }


def _collect_rows(benches, *, smoke: bool) -> list:
    """Run the named benches (default kwargs) and return their rows."""
    mods = _bench_mods()
    unknown = [b for b in benches if b not in mods]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; "
                         f"choose from {list(mods)}")
    rows = []
    for name in benches or list(mods):
        rows.extend(mods[name].run(smoke=smoke))
    return rows


def _check_main(argv) -> int:
    """``run.py check``: gate a candidate run against the history store."""
    from repro.obs import history, regress

    ap = argparse.ArgumentParser(
        prog="run.py check",
        description="Noise-aware regression gate: compare a candidate run "
                    "against the per-row baseline from the last K same-env "
                    "history records; exit 1 iff any row regressed.")
    ap.add_argument("benches", nargs="*", metavar="bench",
                    help="benches to run as the candidate (ignored with "
                         "--from)")
    ap.add_argument("--from", dest="from_json", metavar="RESULTS.json",
                    default=None,
                    help="use a recorded --json results file as the "
                         "candidate instead of running benches")
    ap.add_argument("--smoke", action="store_true",
                    help="run the candidate benches in smoke mode")
    ap.add_argument("--history", metavar="PATH", default=None,
                    help="history store (default: $REPRO_BENCH_HISTORY, "
                         f"then {history.DEFAULT_PATH})")
    ap.add_argument("--k", type=int, default=regress.DEFAULT_K,
                    help="baseline window: last K same-env records "
                         "(default %(default)s)")
    ap.add_argument("--min-records", type=int,
                    default=regress.DEFAULT_MIN_RECORDS,
                    help="records required before a row has a baseline "
                         "(default %(default)s; fewer → no-baseline, "
                         "never a failure)")
    ap.add_argument("--threshold", type=float, default=None, metavar="PCT",
                    help="override the per-row relative thresholds with one "
                         "global band, in percent (e.g. 30)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="append the candidate to the history store and "
                         "exit 0 — seeds a fresh env's baseline / accepts "
                         "a perf change as the new normal")
    args = ap.parse_args(argv)

    if args.from_json:
        data = _load_results(args.from_json)
        rows = data["rows"]
        env, smoke = data.get("env", {}), bool(data.get("smoke"))
        label = os.path.basename(args.from_json)
    else:
        rows = _collect_rows(args.benches, smoke=args.smoke)
        env, smoke = _capability_env(), bool(args.smoke)
        label = "check:" + ",".join(args.benches or ["all"])

    path = history.history_path(args.history, default=history.DEFAULT_PATH)
    store = history.HistoryStore(path)
    fp = history.fingerprint(env, smoke=smoke)
    threshold = args.threshold / 100.0 if args.threshold is not None else None
    verdicts = regress.check_rows(
        rows, store, env, smoke=smoke, k=args.k,
        min_records=args.min_records, threshold=threshold)
    sys.stdout.write(regress.render(verdicts, fp=fp))
    if store.skipped:
        print(f"(history: skipped {store.skipped} unparseable lines in "
              f"{path})", file=sys.stderr)
    if args.update_baseline:
        store.append(env, rows, smoke=smoke, label=label)
        print(f"baseline updated: recorded {len(list(rows))} rows → {path}")
        return 0
    return 1 if regress.regressions(verdicts) else 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "report":
        return _report_main(argv[1:])
    if argv and argv[0] == "check":
        return _check_main(argv[1:])
    from benchmarks.common import emit

    mods = _bench_mods()
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("benches", nargs="*", metavar="bench",
                    help=f"subset to run (default: all): {', '.join(mods)}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one case per module (CI sanity pass)")
    ap.add_argument("--paged", action="store_true",
                    help="serving bench uses the paged KV cache (block pool "
                         "+ prefix sharing); rows keep the slot-pool names "
                         "so `report` diffs the two modes directly")
    ap.add_argument("--priorities", action="store_true",
                    help="serving bench uses a mixed-priority workload and "
                         "adds SLO-attainment / p95-by-class / preemption "
                         "rows")
    ap.add_argument("--no-preempt", action="store_true",
                    help="serving bench disables preempt-and-swap (the "
                         "baseline `report` diffs a --priorities run "
                         "against)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serving bench routes a prefix-heavy workload over "
                         "N paged engine replicas (ReplicaRouter) and adds "
                         "tok_s_total / slo_attained_pct / prefix_hit_rate "
                         "/ backpressure_rejects rows; the workload is the "
                         "same for every N so `report` diffs replica counts")
    ap.add_argument("--no-affinity", action="store_true",
                    help="serving bench routes round-robin instead of by "
                         "prefix affinity (the baseline a --replicas run "
                         "diffs against)")
    ap.add_argument("--arch", metavar="NAME", default="smollm_360m",
                    help="serving bench architecture (smoke config name): "
                         "zamba2_1p2b / xlstm_125m page SSM/xLSTM state as "
                         "single fixed-size blocks, whisper_small pages the "
                         "encoder output as shared immutable blocks; "
                         "non-default archs emit serving/{tag}/{arch}/* "
                         "rows so default-row diffs stay comparable")
    ap.add_argument("--kv", metavar="DTYPE", default="",
                    help="serving bench stores K/V in this cache dtype "
                         "(e.g. int8 → the dense_int8 family: quantized "
                         "pools + scale pages, dequantized in the paged "
                         "gather); rows keep their fp names so `report` "
                         "diffs the two precisions, and paged runs add a "
                         "pool_bytes_per_token capacity row")
    ap.add_argument("--obs", action="store_true",
                    help="serving bench re-runs the identical workload with "
                         "tracing + metrics armed and adds a per_token_obs "
                         "row (overhead=%% vs the off run); with --json the "
                         "payload also records the metrics snapshot")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows + backend capabilities to PATH")
    ap.add_argument("--history", metavar="PATH", default=None,
                    help="append this run to the JSONL history store "
                         "(also honoured via $REPRO_BENCH_HISTORY); "
                         "requires --json semantics, so rows are recorded "
                         "even without a results file")
    args = ap.parse_args(argv)
    unknown = [b for b in args.benches if b not in mods]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choose from {list(mods)}")

    rows = []
    for name in args.benches or list(mods):
        kwargs = {}
        if name == "serving":
            if args.paged:
                kwargs["paged"] = True
            if args.priorities:
                kwargs["priorities"] = True
            if args.no_preempt:
                kwargs["preempt"] = False
            if args.replicas:
                kwargs["replicas"] = args.replicas
                kwargs["affinity"] = not args.no_affinity
            if args.obs:
                kwargs["obs"] = True
            if args.arch != "smollm_360m":
                kwargs["arch"] = args.arch
            if args.kv:
                kwargs["kv"] = args.kv
        rows.extend(mods[name].run(smoke=args.smoke, **kwargs))
    emit(rows)
    from repro.obs import history
    hist_path = history.history_path(args.history)
    if args.json or hist_path:
        env = _capability_env()
        row_dicts = [{"name": n, "us_per_call": round(us, 2), "derived": d}
                     for n, us, d in rows]
    if args.json:
        payload = {"smoke": bool(args.smoke), "env": env, "rows": row_dicts}
        if args.obs:
            from repro.obs import metrics as obs_metrics
            payload["metrics"] = obs_metrics.snapshot()
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
    if hist_path:
        history.HistoryStore(hist_path).append(
            env, row_dicts, smoke=bool(args.smoke),
            label="run:" + ",".join(args.benches or ["all"]))
        print(f"history: recorded {len(row_dicts)} rows → {hist_path}",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
