"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  bench_softmax        → paper Fig. 1 & 2 (naive/safe/online × V × batch)
  bench_softmax_topk   → paper Fig. 3 & 4 (fused vs unfused, K=5)
  bench_topk_sweep     → paper §5.2 (K degradation)
  bench_attention      → beyond-paper (online attention)
  bench_chunked_ce     → beyond-paper (§7 fusion at the LM head)
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        bench_attention,
        bench_chunked_ce,
        bench_softmax,
        bench_softmax_topk,
        bench_topk_sweep,
    )
    from benchmarks.common import emit

    mods = {
        "softmax": bench_softmax,
        "softmax_topk": bench_softmax_topk,
        "topk_sweep": bench_topk_sweep,
        "attention": bench_attention,
        "chunked_ce": bench_chunked_ce,
    }
    selected = sys.argv[1:] or list(mods)
    rows = []
    for name in selected:
        rows.extend(mods[name].run())
    emit(rows)


if __name__ == "__main__":
    main()
