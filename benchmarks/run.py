"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  bench_softmax        → paper Fig. 1 & 2 (naive/safe/online × V × batch)
  bench_softmax_topk   → paper Fig. 3 & 4 (fused vs unfused, K=5)
  bench_topk_sweep     → paper §5.2 (K degradation)
  bench_attention      → beyond-paper (online attention)
  bench_chunked_ce     → beyond-paper (§7 fusion at the LM head)
  bench_serving        → beyond-paper (continuous batching: tok/s, p50/p95
                         per-token latency, occupancy vs drain-and-refill)

``--smoke`` shrinks every sweep to a seconds-long sanity pass (tiny V/batch,
one case per module) — the tier-1 suite runs it so the harness itself can't
rot between full benchmark runs.  ``--json PATH`` additionally records the
rows plus the probed backend capabilities to a results file (the input format
the EXPERIMENTS.md results-diffing report will consume).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# runnable as `python benchmarks/run.py` from anywhere: put the repo root
# (for `benchmarks.*`) and src (for `repro.*`) on the path
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main(argv=None) -> int:
    from benchmarks import (
        bench_attention,
        bench_chunked_ce,
        bench_serving,
        bench_softmax,
        bench_softmax_topk,
        bench_topk_sweep,
    )
    from benchmarks.common import emit

    mods = {
        "softmax": bench_softmax,
        "softmax_topk": bench_softmax_topk,
        "topk_sweep": bench_topk_sweep,
        "attention": bench_attention,
        "chunked_ce": bench_chunked_ce,
        "serving": bench_serving,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("benches", nargs="*", metavar="bench",
                    help=f"subset to run (default: all): {', '.join(mods)}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one case per module (CI sanity pass)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows + backend capabilities to PATH")
    args = ap.parse_args(argv)
    unknown = [b for b in args.benches if b not in mods]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choose from {list(mods)}")

    rows = []
    for name in args.benches or list(mods):
        rows.extend(mods[name].run(smoke=args.smoke))
    emit(rows)
    if args.json:
        from repro import compat
        caps = compat.capabilities()
        payload = {
            "smoke": bool(args.smoke),
            "env": {"backend": caps.backend,
                    "jax_version": caps.jax_version,
                    "device_count": caps.device_count,
                    "pallas_native": caps.pallas_native},
            "rows": [{"name": n, "us_per_call": round(us, 2), "derived": d}
                     for n, us, d in rows],
        }
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
