"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  bench_softmax        → paper Fig. 1 & 2 (naive/safe/online × V × batch)
  bench_softmax_topk   → paper Fig. 3 & 4 (fused vs unfused, K=5)
  bench_topk_sweep     → paper §5.2 (K degradation)
  bench_attention      → beyond-paper (online attention)
  bench_chunked_ce     → beyond-paper (§7 fusion at the LM head)
  bench_serving        → beyond-paper (continuous batching: tok/s, p50/p95
                         per-token latency, occupancy vs drain-and-refill;
                         ``--paged`` serves through the paged KV cache and
                         adds block-sharing accounting; ``--replicas N``
                         routes over N engines with prefix affinity)

``--smoke`` shrinks every sweep to a seconds-long sanity pass (tiny V/batch,
one case per module) — the tier-1 suite runs it so the harness itself can't
rot between full benchmark runs.  ``--json PATH`` additionally records the
rows plus the probed backend capabilities to a results file.

``report A.json B.json`` diffs two such result files into an
EXPERIMENTS.md-style markdown table (name | baseline | candidate | Δ%),
flagging rows present on only one side and any env mismatch — paste it into
EXPERIMENTS.md as the record of a before/after run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# runnable as `python benchmarks/run.py` from anywhere: put the repo root
# (for `benchmarks.*`) and src (for `repro.*`) on the path
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _load_results(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "rows" not in data:
        raise SystemExit(f"{path}: not a benchmarks/run.py --json results "
                         "file (no 'rows')")
    return data


def report(baseline_path: str, candidate_path: str, out=None) -> str:
    """Markdown diff of two ``--json`` result files (EXPERIMENTS.md-style)."""
    base = _load_results(baseline_path)
    cand = _load_results(candidate_path)
    b_rows = {r["name"]: r for r in base["rows"]}
    c_rows = {r["name"]: r for r in cand["rows"]}
    lines = [f"## Benchmark diff — {os.path.basename(baseline_path)} → "
             f"{os.path.basename(candidate_path)}", ""]
    env_keys = sorted(set(base.get("env", {})) | set(cand.get("env", {})))
    if env_keys:
        lines += ["| env | baseline | candidate |", "|---|---|---|"]
        for k in env_keys:
            bv = base.get("env", {}).get(k, "—")
            cv = cand.get("env", {}).get(k, "—")
            flag = "" if bv == cv else " ⚠"
            lines.append(f"| {k}{flag} | {bv} | {cv} |")
        lines.append("")
    lines += ["| name | baseline µs | candidate µs | Δ% | derived |",
              "|---|---:|---:|---:|---|"]
    for name in sorted(set(b_rows) & set(c_rows)):
        b, c = b_rows[name], c_rows[name]
        bu, cu = float(b["us_per_call"]), float(c["us_per_call"])
        delta = (cu - bu) / bu * 100.0 if bu else float("inf")
        derived = c.get("derived") or b.get("derived") or ""
        lines.append(f"| {name} | {bu:.2f} | {cu:.2f} | {delta:+.1f}% "
                     f"| {derived} |")
    only_b = sorted(set(b_rows) - set(c_rows))
    only_c = sorted(set(c_rows) - set(b_rows))
    if only_b:
        lines += ["", "Rows only in baseline: " + ", ".join(only_b)]
    if only_c:
        lines += ["", "Rows only in candidate: " + ", ".join(only_c)]
    text = "\n".join(lines) + "\n"
    if out:
        with open(out, "w") as f:
            f.write(text)
    return text


def _report_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="run.py report",
        description="Diff two --json result files into a markdown table.")
    ap.add_argument("baseline", help="results JSON of the 'before' run")
    ap.add_argument("candidate", help="results JSON of the 'after' run")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="also write the markdown to PATH")
    args = ap.parse_args(argv)
    sys.stdout.write(report(args.baseline, args.candidate, out=args.out))
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "report":
        return _report_main(argv[1:])
    from benchmarks import (
        bench_attention,
        bench_chunked_ce,
        bench_serving,
        bench_softmax,
        bench_softmax_topk,
        bench_topk_sweep,
    )
    from benchmarks.common import emit

    mods = {
        "softmax": bench_softmax,
        "softmax_topk": bench_softmax_topk,
        "topk_sweep": bench_topk_sweep,
        "attention": bench_attention,
        "chunked_ce": bench_chunked_ce,
        "serving": bench_serving,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("benches", nargs="*", metavar="bench",
                    help=f"subset to run (default: all): {', '.join(mods)}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one case per module (CI sanity pass)")
    ap.add_argument("--paged", action="store_true",
                    help="serving bench uses the paged KV cache (block pool "
                         "+ prefix sharing); rows keep the slot-pool names "
                         "so `report` diffs the two modes directly")
    ap.add_argument("--priorities", action="store_true",
                    help="serving bench uses a mixed-priority workload and "
                         "adds SLO-attainment / p95-by-class / preemption "
                         "rows")
    ap.add_argument("--no-preempt", action="store_true",
                    help="serving bench disables preempt-and-swap (the "
                         "baseline `report` diffs a --priorities run "
                         "against)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serving bench routes a prefix-heavy workload over "
                         "N paged engine replicas (ReplicaRouter) and adds "
                         "tok_s_total / slo_attained_pct / prefix_hit_rate "
                         "/ backpressure_rejects rows; the workload is the "
                         "same for every N so `report` diffs replica counts")
    ap.add_argument("--no-affinity", action="store_true",
                    help="serving bench routes round-robin instead of by "
                         "prefix affinity (the baseline a --replicas run "
                         "diffs against)")
    ap.add_argument("--arch", metavar="NAME", default="smollm_360m",
                    help="serving bench architecture (smoke config name): "
                         "zamba2_1p2b / xlstm_125m page SSM/xLSTM state as "
                         "single fixed-size blocks, whisper_small pages the "
                         "encoder output as shared immutable blocks; "
                         "non-default archs emit serving/{tag}/{arch}/* "
                         "rows so default-row diffs stay comparable")
    ap.add_argument("--obs", action="store_true",
                    help="serving bench re-runs the identical workload with "
                         "tracing + metrics armed and adds a per_token_obs "
                         "row (overhead=%% vs the off run); with --json the "
                         "payload also records the metrics snapshot")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows + backend capabilities to PATH")
    args = ap.parse_args(argv)
    unknown = [b for b in args.benches if b not in mods]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choose from {list(mods)}")

    rows = []
    for name in args.benches or list(mods):
        kwargs = {}
        if name == "serving":
            if args.paged:
                kwargs["paged"] = True
            if args.priorities:
                kwargs["priorities"] = True
            if args.no_preempt:
                kwargs["preempt"] = False
            if args.replicas:
                kwargs["replicas"] = args.replicas
                kwargs["affinity"] = not args.no_affinity
            if args.obs:
                kwargs["obs"] = True
            if args.arch != "smollm_360m":
                kwargs["arch"] = args.arch
        rows.extend(mods[name].run(smoke=args.smoke, **kwargs))
    emit(rows)
    if args.json:
        from repro import compat
        caps = compat.capabilities()
        payload = {
            "smoke": bool(args.smoke),
            "env": {"backend": caps.backend,
                    "jax_version": caps.jax_version,
                    "device_count": caps.device_count,
                    "pallas_native": caps.pallas_native},
            "rows": [{"name": n, "us_per_call": round(us, 2), "derived": d}
                     for n, us, d in rows],
        }
        if args.obs:
            from repro.obs import metrics as obs_metrics
            payload["metrics"] = obs_metrics.snapshot()
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
