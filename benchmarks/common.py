"""Shared benchmark utilities: wall-clock timing of jitted callables on CPU.

The paper's GPU numbers measure HBM-bandwidth effects; on this CPU container
the same access-count reductions manifest through the cache hierarchy, so we
report wall time *and* the paper's analytic memory-access model side by side
(the `derived` column = predicted access ratio vs the safe-softmax baseline).
"""
from __future__ import annotations

import time
from typing import Callable

import jax

# The regression sentry and the --obs overhead harness must agree on what
# "the uncontended cost" means, so both use the same estimator: contention
# noise on a shared box is strictly additive, making the mean of the
# fastest half of a sample window a robust stand-in for the clean figure.
from repro.obs.regress import fastest_half_mean  # noqa: F401  (re-export)


def time_fn(fn: Callable, *args, warmup: int = 2, reps: int = 5) -> float:
    """Median wall-time of ``fn(*args)`` in microseconds (jit + blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: list[tuple]) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
