"""Beyond-paper: vocab-chunked online cross-entropy vs full-logit CE
(paper §7 "fuse with the preceding layer").  ``derived`` = bytes of the
[T, V] logit tensor that the chunked form never materializes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import chunked_cross_entropy, full_cross_entropy

CASES = [
    # (T, D, V, chunks)
    (2048, 512, 32768, 16),
    (2048, 512, 65536, 16),
    (8192, 256, 65536, 16),
]
SMOKE_CASES = [(256, 128, 4096, 8)]


def run(smoke: bool = False) -> list[tuple]:
    rows = []
    for t, d, v, chunks in (SMOKE_CASES if smoke else CASES):
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        h = jax.random.normal(ks[0], (t, d), jnp.float32)
        w = jax.random.normal(ks[1], (d, v), jnp.float32) * 0.02
        labels = jax.random.randint(ks[2], (t,), 0, v)
        logit_mb = t * v * 4 / 2**20
        full_g = jax.jit(jax.grad(lambda h, w: full_cross_entropy(
            h, w, labels).mean(), argnums=(0, 1)))
        chunk_g = jax.jit(jax.grad(lambda h, w: chunked_cross_entropy(
            h, w, labels, num_chunks=chunks).mean(), argnums=(0, 1)))
        rows.append((f"chunked_ce/T={t}_V={v}/full_fwdbwd",
                     time_fn(full_g, h, w), f"logits={logit_mb:.0f}MB"))
        rows.append((f"chunked_ce/T={t}_V={v}/chunked_fwdbwd",
                     time_fn(chunk_g, h, w),
                     f"logits={logit_mb / chunks:.0f}MB_transient"))
    return rows


if __name__ == "__main__":
    emit(run())
