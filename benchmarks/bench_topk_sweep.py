"""Paper §5.2 K-sweep: the fused-top-k advantage degrades as K grows
(paper: 5x at K=5 → 3.5x at K=10 → 2x at K=15 → 1.4x at K=30)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import softmax_topk
from repro.core.topk_fusion import safe_softmax_then_topk

V, B = 16384, 256
KS = (5, 10, 15, 30, 64)
SMOKE_V, SMOKE_B, SMOKE_KS = 2048, 16, (5,)


def run(smoke: bool = False) -> list[tuple]:
    rows = []
    v, b = (SMOKE_V, SMOKE_B) if smoke else (V, B)
    x = jax.random.normal(jax.random.PRNGKey(2), (b, v), jnp.float32)
    for k in (SMOKE_KS if smoke else KS):
        unfused = time_fn(jax.jit(lambda x, k=k:
                                  safe_softmax_then_topk(x, k)[:2]), x)
        fused = time_fn(jax.jit(lambda x, k=k: softmax_topk(x, k)[:2]), x)
        rows.append((f"topk_sweep/K={k}/unfused", unfused, ""))
        rows.append((f"topk_sweep/K={k}/online_fused", fused,
                     f"measured={unfused / fused:.2f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
