"""Ambient sharding context for model code.

Model layers are mesh-agnostic; the launcher installs a ``ShardContext`` so
attention can (a) apply sequence-parallel sharding constraints, (b) expand
replicated KV heads for head-sharded GQA, and (c) route decode attention
through the shard_map ⊕-merge path.  ``None`` context (unit tests, smoke
tests) means single-device semantics everywhere.
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple, Optional

from jax.sharding import Mesh

from repro.configs.base import ParallelConfig


class ShardContext(NamedTuple):
    mesh: Mesh
    par: ParallelConfig
    # mesh axes the decode KV cache's sequence dim is sharded over
    cache_seq_axes: tuple = ("model",)
    # mesh axes the batch dim is sharded over (() = replicated, e.g. batch 1)
    batch_axes: tuple = ("data",)


_CURRENT: Optional[ShardContext] = None


def get() -> Optional[ShardContext]:
    return _CURRENT


@contextlib.contextmanager
def use(ctx: Optional[ShardContext]):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = ctx
    try:
        yield
    finally:
        _CURRENT = prev
