"""Distributed decode attention and top-k: the paper's §3.1 parallel ⊕ as
cross-chip collectives.

``sharded_decode_attention``: the KV cache's sequence dim is sharded over
mesh axes; every shard runs the *local* online-softmax attention over its
cache slice (one pass, Algorithm 3), producing partial ``(m, d, o)``.  The
global result is the ⊕ of the partials:

    m* = pmax(m)            d* = psum(d · e^{m−m*})
    o* = psum(o · d · e^{m−m*}) / d*

Three tiny collectives ([B,H]-shaped, not [B,S]-shaped) replace any gather of
the cache — this is the paper's associative operator doing the work of a
distributed softmax.

``sharded_topk_sample``: same trick for the LM head (paper Algorithm 4,
distributed): each vocab shard computes its local fused softmax+top-k, then
only the 2·K-per-shard candidate set and the [B]-shaped (m, d) statistics
cross the wire.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core.attention import _chunked_fwd_impl

NEG_INF = float("-inf")


def _merge_scale(m_local, m_global):
    return jnp.exp(jnp.where(m_local == m_global, 0.0, m_local - m_global))


def sharded_decode_attention(q, k_cache, v_cache, kv_valid_len, *, mesh: Mesh,
                             seq_axes: tuple, batch_axes: tuple,
                             chunk_size: int, scale: float,
                             k_scale=None, v_scale=None):
    """q [B,1,Hq,Dk]; caches [B,S,Hkv,*] with S sharded over ``seq_axes``.

    Returns [B,1,Hq,Dv].  Works for GQA, for MLA's latent cache
    (Hkv=1, Dv=kv_lora_rank), and for int8 caches (``k_scale``/``v_scale``
    [B,S,Hkv] dequantization factors, applied chunk-wise after the HBM read).
    """
    ba = tuple(batch_axes)
    sa = tuple(seq_axes)
    quant = k_scale is not None

    def local(q_l, k_l, v_l, vlen_l, *scales):
        # global position of this shard's cache slice
        idx = jnp.zeros((), jnp.int32)
        for a in sa:   # row-major over seq axes
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        s_local = k_l.shape[1]
        start = idx * s_local
        vl_local = jnp.clip(vlen_l - start, 0, s_local)
        ks_l, vs_l = scales if quant else (None, None)
        out, lse = _chunked_fwd_impl(
            q_l, k_l, v_l, jnp.asarray(0, jnp.int32), vl_local,
            False, min(chunk_size, s_local), scale,
            k_scale=ks_l, v_scale=vs_l)
        # lse = m + log d (−inf where the shard had no valid keys)
        m_l = lse                                    # [B,Hkv,G,1]
        m_g = jax.lax.pmax(m_l, sa)
        w = _merge_scale(m_l, m_g)                   # d·e^{m−m*} ∝ e^{lse−m*}
        w = jnp.where(jnp.isneginf(m_l), 0.0, w)
        d_g = jax.lax.psum(w, sa)
        b, _, hq, dv = out.shape
        w_o = jnp.moveaxis(w, -1, 1).reshape(b, 1, hq, 1)
        o_g = jax.lax.psum(out.astype(jnp.float32) * w_o, sa)
        return (o_g / jnp.maximum(d_g, 1e-30).reshape(b, 1, hq, 1)
                ).astype(q_l.dtype)

    qspec = P(ba, None, None, None)
    cspec = P(ba, sa, None, None)
    if quant:
        sspec = P(ba, sa, None)
        return shard_map(local, mesh=mesh,
                         in_specs=(qspec, cspec, cspec, P(ba), sspec, sspec),
                         out_specs=qspec, check_vma=False)(
            q, k_cache, v_cache, kv_valid_len, k_scale, v_scale)
    return shard_map(local, mesh=mesh,
                     in_specs=(qspec, cspec, cspec, P(ba)),
                     out_specs=qspec, check_vma=False)(
        q, k_cache, v_cache, kv_valid_len)


def sharded_topk_sample(rng, logits, k: int, *, mesh: Mesh,
                        batch_axes: tuple, vocab_axis: str = "model",
                        temperature: float = 1.0):
    """Fused softmax+top-k+sample over a vocab-sharded logits tensor.

    Per shard: local (m, d) + local top-k (one pass).  Cross-shard: ⊕ on the
    [B] statistics + an all_gather of K candidates per shard.
    """
    from repro.core.online_softmax import online_normalizer

    ba = tuple(batch_axes)
    n_shards = mesh.shape[vocab_axis]

    def local(rng_l, x_l):
        v_local = x_l.shape[-1]
        idx0 = jax.lax.axis_index(vocab_axis) * v_local
        xf = x_l.astype(jnp.float32)
        if temperature != 1.0:
            xf = xf / temperature
        m_l, d_l = online_normalizer(xf, axis=-1)
        vals_l, idx_l = jax.lax.top_k(xf, k)
        idx_l = idx_l + idx0
        # ⊕ across vocab shards
        m_g = jax.lax.pmax(m_l, vocab_axis)
        d_g = jax.lax.psum(d_l * _merge_scale(m_l, m_g), vocab_axis)
        cand_v = jax.lax.all_gather(vals_l, vocab_axis, axis=-1, tiled=True)
        cand_i = jax.lax.all_gather(idx_l, vocab_axis, axis=-1, tiled=True)
        top_v, sel = jax.lax.top_k(cand_v, k)
        top_i = jnp.take_along_axis(cand_i, sel, axis=-1)
        probs = jnp.exp(top_v - m_g[..., None]) / d_g[..., None]
        g = jax.random.gumbel(rng_l, probs.shape, dtype=jnp.float32)
        choice = jnp.argmax(jnp.log(jnp.maximum(probs, 1e-30)) + g, axis=-1)
        tok = jnp.take_along_axis(top_i, choice[..., None], axis=-1)[..., 0]
        return tok, probs

    return shard_map(local, mesh=mesh,
                     in_specs=(P(), P(ba, vocab_axis)),
                     out_specs=(P(ba), P(ba, None)),
                     check_vma=False)(rng, logits)
