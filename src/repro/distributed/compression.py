"""Gradient compression for the data-parallel all-reduce.

Two mechanisms, composable with the training step:

1. **bf16 reduction** (default on): gradients are cast to bfloat16 at the
   autodiff boundary, so the XLA-inserted data-parallel all-reduce moves half
   the bytes.  Verified in the dry-run HLO (§Perf) — the all-reduce operands
   are bf16.

2. **int8 error-feedback compression** (opt-in): per-tensor scale quantization
   with a persistent error accumulator (Seide et al. 1-bit-SGD style
   feedback).  The quantize→transport→dequantize round trip is exact about
   the wire format; on a real multi-host deployment the transport is an
   ``all_gather`` of int8 shards (``shard_map``) followed by a local
   dequantized reduction — ``int8_allreduce`` below implements exactly that
   and is exercised by the multi-device tests.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro import compat

PyTree = Any


def cast_grads(grads: PyTree, dtype: str) -> PyTree:
    if dtype in ("float32", "fp32", None):
        return grads
    dt = jnp.dtype(dtype)
    return compat.tree_map(lambda g: g.astype(dt), grads)


# ---------------------------------------------------------------------------
# int8 error-feedback quantization.
# ---------------------------------------------------------------------------
def ef_init(params: PyTree) -> PyTree:
    """Zero error-feedback residuals shaped like the grads."""
    return compat.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: PyTree, errors: PyTree):
    """Quantize (grad + carried error); return (q, scales, new_errors)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        deq = _dequantize(q, scale)
        return (q, scale), x - deq

    out = compat.tree_map(one, grads, errors)
    qs = compat.tree_map(lambda t: t[0][0], out,
                      is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                      and isinstance(t[0], tuple))
    scales = compat.tree_map(lambda t: t[0][1], out,
                          is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                          and isinstance(t[0], tuple))
    new_err = compat.tree_map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                           and isinstance(t[0], tuple))
    return qs, scales, new_err


def ef_decompress(qs: PyTree, scales: PyTree) -> PyTree:
    return compat.tree_map(_dequantize, qs, scales)


def ef_roundtrip(grads: PyTree, errors: PyTree):
    """Simulated compress→transport→decompress with error feedback.

    Returns (dequantized grads, new error state)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        deq = _dequantize(q, scale)
        return deq, x - deq

    pairs = compat.tree_map(one, grads, errors)
    deq = compat.tree_map(lambda t: t[0], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    err = compat.tree_map(lambda t: t[1], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    return deq, err


# ---------------------------------------------------------------------------
# Real int8 all-reduce over a mesh axis (shard_map collective).
# ---------------------------------------------------------------------------
def int8_allreduce(x: jax.Array, mesh, axis: str) -> jax.Array:
    """Mean-reduce ``x`` (replicated layout) across ``axis`` with int8 wire
    format: quantize locally, all_gather int8 + scales, dequantize, average."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    def local(xl):
        q, scale = _quantize(xl)
        qs = jax.lax.all_gather(q, axis)              # [n, ...] int8 on wire
        ss = jax.lax.all_gather(scale, axis)
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * xl.ndim)
        return jnp.mean(deq, axis=0)

    specs = P(*([None] * x.ndim))
    return shard_map(local, mesh=mesh, in_specs=(specs,), out_specs=specs,
                     check_vma=False)(x)
