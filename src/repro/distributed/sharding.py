"""Logical-axis → mesh-axis rules and sharding derivation.

Model code annotates every parameter with *logical* axes ("embed", "ffn",
"heads", "vocab", "expert", "inner", …).  This module turns them into
``NamedSharding``s for a concrete mesh, choosing per-architecture fallbacks:

* ``heads``/``kv_heads`` map to the model axis only when the head count
  divides it; otherwise attention weights replicate and attention runs
  *sequence-parallel* (context parallelism): q sharded on T over the model
  axis, K/V gathered — valid for ANY head count (DESIGN.md §4).
* ``expert`` maps to the model axis when (padded) expert count divides it
  ("expert" shard_mode), else experts replicate and ``expert_ffn`` shards
  (TP inside each expert, "ffn" mode).
* ``vocab`` always shards over model (configs pad vocab to multiples of 256),
  which makes the chunked online cross-entropy's ⊕ merge a cross-device
  collective — the distributed form of the paper's Algorithm 3.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

if TYPE_CHECKING:   # annotation-only: the runtime class resolves via compat
    from jax.sharding import NamedSharding

from repro import compat
from repro.configs.base import ModelConfig, ParallelConfig

PyTree = Any


def _model_size(mesh: Mesh, model_axis: str) -> int:
    return mesh.shape[model_axis]


def derive_parallel(cfg: ModelConfig, mesh: Mesh,
                    base: Optional[ParallelConfig] = None) -> ParallelConfig:
    """Pick attention/MoE sharding modes that are valid for this arch+mesh."""
    base = base or ParallelConfig(
        data_axes=tuple(a for a in mesh.axis_names if a != "model"))
    mp = _model_size(mesh, base.model_axis)
    heads_ok = (cfg.num_heads % mp == 0)
    attn_mode = "heads" if heads_ok else "sequence"
    return ParallelConfig(
        data_axes=base.data_axes, model_axis=base.model_axis,
        attn_mode=attn_mode, seq_sharded_norms=base.seq_sharded_norms,
        grad_reduce_dtype=base.grad_reduce_dtype,
        microbatches=base.microbatches)


def axis_rules(cfg: ModelConfig, par: ParallelConfig, mesh: Mesh) -> dict:
    mp = _model_size(mesh, par.model_axis)
    m = par.model_axis
    heads = m if (par.attn_mode == "heads") else None
    kv_heads = m if (par.attn_mode == "heads"
                     and cfg.num_kv_heads % mp == 0) else None
    expert = None
    expert_ffn = None
    if cfg.moe is not None:
        e_pad = cfg.moe.pad_experts_to or cfg.moe.num_experts
        if cfg.moe.shard_mode == "expert" and e_pad % mp == 0:
            expert = m
        else:
            expert_ffn = m
    inner = m  # SSM/xLSTM inner channel dim (configs keep it divisible)
    if cfg.xlstm is not None and (cfg.xlstm.expand * cfg.d_model) % mp != 0:
        inner = None
    if cfg.ssm is not None and (cfg.ssm.expand * cfg.d_model) % mp != 0:
        inner = None
    inner_heads = None  # per-head SSM params are tiny; replicate
    hd = cfg.resolved_head_dim
    qkv_out = m if (cfg.num_heads * hd) % mp == 0 else None
    # kv projections: shardable when sequence-parallel (activations resharded)
    # or when kv heads divide; replicated otherwise (GQA kv-expand path).
    if par.attn_mode == "sequence":
        kv_out = m if (cfg.num_kv_heads * hd) % mp == 0 else None
    else:
        kv_out = m if cfg.num_kv_heads % mp == 0 else None
    sc = cfg.ssm
    if sc is not None and (sc.expand * cfg.d_model // sc.head_dim) % mp == 0:
        inner_heads = m       # SSM heads/states shard with the inner dim
    return {
        "embed": None,
        "ffn": m if cfg.d_ff % mp == 0 or cfg.d_ff == 0 else None,
        "vocab": m if cfg.vocab_size % mp == 0 else None,
        "heads": heads,
        "kv_heads": kv_heads,
        "qkv_out": qkv_out,
        "kv_out": kv_out,
        "expert": expert,
        "expert_ffn": expert_ffn,
        "inner": inner,
        "inner_heads": inner_heads,
        "layers": None,
        None: None,
    }


def param_sharding(axes_tree: PyTree, cfg: ModelConfig, par: ParallelConfig,
                   mesh: Mesh) -> PyTree:
    """Map each param's logical axes to a NamedSharding."""
    rules = axis_rules(cfg, par, mesh)

    def to_sharding(axes: tuple) -> NamedSharding:
        spec = tuple(rules.get(a) for a in axes)
        return compat.named_sharding(mesh, P(*spec))

    return compat.tree_map(to_sharding, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def add_data_axis(spec: P, shape: tuple, mesh: Mesh, par: ParallelConfig,
                  *, min_bytes: int = 1 << 20, bytes_per_elem: int = 4) -> P:
    """ZeRO/FSDP-style extra sharding: place the data axes on the first free
    dim divisible by the data-parallel degree.  Used for optimizer states
    (always) and params (``fsdp`` flag) — turns O(params) memory into
    O(params / (model × data))."""
    n = int(np.prod([mesh.shape[a] for a in par.data_axes]))
    if n == 1:
        return spec
    size = int(np.prod(shape)) * bytes_per_elem
    if size < min_bytes:
        return spec
    # already data-sharded (e.g. FSDP params feeding optimizer sharding)
    used = {a for part in spec if part
            for a in (part if isinstance(part, tuple) else (part,))}
    if used & set(par.data_axes):
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, dim in enumerate(shape):
        if parts[i] is None and dim % n == 0:
            parts[i] = par.data_axes
            return P(*parts)
    return spec


def optimizer_sharding(p_sh: PyTree, like: PyTree, mesh: Mesh,
                       par: ParallelConfig) -> PyTree:
    """Shardings for fp32 optimizer moments: param sharding + data axis."""
    def one(sh: NamedSharding, leaf) -> NamedSharding:
        spec = add_data_axis(sh.spec, tuple(leaf.shape), mesh, par)
        return compat.named_sharding(mesh, spec)
    return compat.tree_map(one, p_sh, like)


def fsdp_param_sharding(p_sh: PyTree, like: PyTree, mesh: Mesh,
                        par: ParallelConfig,
                        *, min_bytes: int = 8 << 20) -> PyTree:
    """Fully-sharded params (weights gathered per layer at use — the
    scan-over-layers structure makes XLA stream them)."""
    def one(sh: NamedSharding, leaf) -> NamedSharding:
        spec = add_data_axis(sh.spec, tuple(leaf.shape), mesh, par,
                             min_bytes=min_bytes, bytes_per_elem=2)
        return compat.named_sharding(mesh, spec)
    return compat.tree_map(one, p_sh, like)


def batch_spec(par: ParallelConfig) -> P:
    """Batch dim sharded over all data axes (pod × data)."""
    return P(par.data_axes)


def batch_sharding(tree_example: PyTree, par: ParallelConfig,
                   mesh: Mesh) -> PyTree:
    """Shard dim 0 of every batch leaf over the data axes."""
    def sh(x):
        ndim = x.ndim if hasattr(x, "ndim") else len(x.shape)
        return compat.named_sharding(mesh, P(par.data_axes, *([None] * (ndim - 1))))
    return compat.tree_map(sh, tree_example)


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint if x's shape is compatible, else no-op."""
    try:
        return jax.lax.with_sharding_constraint(x, compat.named_sharding(mesh, spec))
    except (ValueError, TypeError):
        return x


def replicated(mesh: Mesh) -> NamedSharding:
    return compat.named_sharding(mesh, P())
