"""Trip-count-aware cost model over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE
(verified: a 10-iteration scan of a matmul reports 1 matmul of flops).  Every
layer stack, attention chunk loop, and vocab-chunk loop in this codebase is a
``lax.scan``, so the built-in numbers undercount by 1–3 orders of magnitude.

This module re-derives flops / bytes / collective bytes by walking the
post-optimization HLO with loop multipliers taken from each while op's
``backend_config={"known_trip_count":{"n":...}}`` (emitted by XLA for
counted loops; default 1 when absent).

Accounting rules:
* flops — ``dot`` ops: 2 × |result| × |contracted dims| (from the lhs shape
  and ``lhs_contracting_dims``); dots inside fusion computations are found by
  recursing into ``calls=``.
* bytes — Σ (operand + result bytes) of top-level compute ops (fusions count
  their boundary, not their interior — post-fusion HLO makes this the right
  HBM-traffic proxy).  Pure-metadata ops (tuple, gte, parameter, bitcast,
  reshape, constant) are free.
* collectives — operand bytes of all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute, × enclosing loop multipliers.

All numbers are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "reshape", "after-all", "opt-barrier", "iota"}

# Ops whose operand/result sizes count as HBM traffic.  Raw elementwise ops
# (add/mul/convert/...) are EXCLUDED: the CPU backend leaves them unfused at
# top level, but the TPU target fuses them into neighbors, so counting them
# would overstate the memory term by ~10x (documented in DESIGN.md).  Fusion
# boundaries, matmuls, data movement, and reductions are the traffic that
# survives fusion on TPU.
_TRAFFIC_OPS = {"dot", "fusion", "copy", "convolution", "dynamic-slice",
                "dynamic-update-slice", "gather", "scatter", "reduce",
                "reduce-window", "sort", "transpose", "pad", "concatenate",
                "slice", "rng-bit-generator", "cholesky",
                "triangular-solve"} | set(_COLLECTIVES)

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"^\s*([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"calls=%([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = byts = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _first_shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Instr:
    name: str
    shape: str
    opcode: str
    rest: str


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    collective_by_dtype: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for d, src in (
            (self.collective_by_kind, other.collective_by_kind),
            (self.collective_by_dtype, other.collective_by_dtype),
            (self.collective_counts, other.collective_counts),
        ):
            for k, v in src.items():
                d[k] = d.get(k, 0) + v * mult


def _parse_instr(line: str) -> _Instr | None:
    """Parse '%name = SHAPE opcode(args...), attrs'.  SHAPE may be a tuple
    with nested parens and /*index=N*/ comments — scan with a depth counter."""
    m = _INSTR_HEAD.match(line)
    if not m:
        return None
    name, rest = m.groups()
    rest = rest.lstrip()
    if rest.startswith("("):        # tuple shape: find the matching paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape, tail = rest[:i + 1], rest[i + 1:]
                    break
        else:
            return None
    else:                            # simple shape: first whitespace token
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp:]
    mo = _OPCODE.match(tail)
    if not mo:
        return None
    opcode = mo.group(1)
    args = tail[mo.end():]
    return _Instr(name, shape, opcode, args)


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    for line in text.splitlines():
        stripped = line.strip()
        hdr = _COMP_HDR.match(stripped)
        if hdr and stripped.endswith("{"):
            cur = comps.setdefault(hdr.group(2), [])
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        instr = _parse_instr(line)
        if instr:
            cur.append(instr)
    return comps


def _operand_names(args: str) -> list[str]:
    """Operand %refs of an instruction, up to the closing paren of the call."""
    depth = 1
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    return re.findall(r"%([\w.\-]+)", args)


def _param_slice_bytes(fcomp: list[_Instr]) -> dict[int, int]:
    """For a fusion computation: param index → bytes actually touched, for
    params that are only sliced (dynamic-slice) or updated in place
    (root dynamic-update-slice).  This is what makes scan-over-layers param
    stacks and KV-cache updates cost O(slice), not O(stack) × trip_count."""
    param_idx: dict[str, int] = {}
    unary_src: dict[str, str] = {}        # name -> single operand (pass-through)
    for ins in fcomp:
        if ins.opcode == "parameter":
            m = re.match(r"(\d+)", ins.rest)
            if m:
                param_idx[ins.name] = int(m.group(1))
        elif ins.opcode in ("convert", "bitcast", "copy", "reshape",
                            "transpose", "broadcast"):
            ops = _operand_names(ins.rest)
            if len(ops) == 1:
                unary_src[ins.name] = ops[0]

    def to_param(name: str):
        seen = 0
        while name in unary_src and seen < 8:
            name = unary_src[name]
            seen += 1
        return param_idx.get(name)

    touched: dict[int, int] = {}
    for ins in fcomp:
        ops = _operand_names(ins.rest)
        if ins.opcode == "dynamic-slice" and ops:
            i = to_param(ops[0])
            if i is not None:
                _, b = _shape_elems_bytes(ins.shape)
                touched[i] = max(touched.get(i, 0), b)
        if ins.opcode == "dynamic-update-slice" and ops:
            i = to_param(ops[0])
            if i is not None and len(ops) > 1:
                upd_shape = next((x.shape for x in fcomp
                                  if x.name == ops[1]), None)
                if upd_shape:
                    _, b = _shape_elems_bytes(upd_shape)
                    touched[i] = max(touched.get(i, 0), b)
    return touched


_UNARY_PASS = ("convert", "bitcast", "copy", "reshape", "transpose")


def _root_is_dus(fcomp: list[_Instr]) -> bool:
    """True if the fusion's root is a dynamic-update-slice, possibly wrapped
    in dtype converts/bitcasts (the XLA:CPU bf16→f32 legalization pattern)."""
    if not fcomp:
        return False
    by_name = {i.name: i for i in fcomp}
    cur = fcomp[-1]
    for _ in range(8):
        if cur.opcode == "dynamic-update-slice":
            return True
        if cur.opcode not in _UNARY_PASS:
            return False
        ops = _operand_names(cur.rest)
        if len(ops) != 1 or ops[0] not in by_name:
            return False
        cur = by_name[ops[0]]
    return False


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(instr.shape)
    ops = re.findall(r"%([\w.\-]+)", instr.rest.split("),")[0])
    if not ops:
        return 0.0
    lhs_shape = _first_shape_dims(shapes.get(ops[0], ""))
    mc = _LHS_CONTRACT.search(instr.rest)
    contract = 1
    if mc and lhs_shape:
        for i in (int(x) for x in mc.group(1).split(",") if x):
            if i < len(lhs_shape):
                contract *= lhs_shape[i]
    return 2.0 * res_elems * contract


def _analyze_comp(name: str, comps: dict, cache: dict) -> Costs:
    if name in cache:
        return cache[name]
    cache[name] = Costs()            # guard against cycles
    total = Costs()
    shapes = {i.name: i.shape for i in comps.get(name, [])}
    for instr in comps.get(name, []):
        op = instr.opcode
        if op == "while":
            m = _COND_BODY.search(instr.rest)
            trip = 1
            mt = _TRIP.search(instr.rest)
            if mt:
                trip = int(mt.group(1))
            if m:
                total.add(_analyze_comp(m.group(2), comps, cache), trip)
                total.add(_analyze_comp(m.group(1), comps, cache), trip)
            continue
        if op in ("call", "conditional", "async-start"):
            for callee in _CALLS.findall(instr.rest):
                total.add(_analyze_comp(callee, comps, cache))
            # conditional: branch_computations list
            for callee in re.findall(r"branch_computations=\{([^}]*)\}",
                                     instr.rest):
                for c in re.findall(r"%([\w.\-]+)", callee):
                    total.add(_analyze_comp(c, comps, cache))
            continue       # tuple plumbing of the call itself is free
        if op in _FREE_OPS:
            continue
        # ---- bytes: operands + result of traffic-relevant ops ---------------
        _, res_bytes = _shape_elems_bytes(instr.shape)
        opnd_names = _operand_names(instr.rest)
        opnd_bytes = []
        for opnd in opnd_names:
            if opnd in shapes:
                _, b = _shape_elems_bytes(shapes[opnd])
                opnd_bytes.append(b)
            else:
                opnd_bytes.append(0)
        arg_bytes = sum(opnd_bytes)
        if op in _TRAFFIC_OPS:
            if op == "dynamic-slice":
                total.bytes += 2 * res_bytes          # read slice, write out
            elif op == "dynamic-update-slice":
                upd = opnd_bytes[1] if len(opnd_bytes) > 1 else res_bytes
                total.bytes += 2 * upd                # in-place window update
            elif op == "fusion":
                callee = _CALLS.findall(instr.rest)
                fcomp = comps.get(callee[0], []) if callee else []
                touched = _param_slice_bytes(fcomp)
                charged = sum(touched.get(i, b)
                              for i, b in enumerate(opnd_bytes))
                # root in-place dus (possibly behind converts/bitcasts) ⇒
                # result traffic is the window, not the whole aliased buffer
                if touched and _root_is_dus(fcomp):
                    res_bytes = min(res_bytes, max(touched.values()))
                total.bytes += charged + res_bytes
            else:
                total.bytes += res_bytes + arg_bytes
        # ---- flops ---------------------------------------------------------
        if op == "dot":
            total.flops += _dot_flops(instr, shapes)
        elif op == "fusion":
            for callee in _CALLS.findall(instr.rest):
                sub = _analyze_comp(callee, comps, cache)
                total.flops += sub.flops                 # dots inside fusions
                total.collective_bytes += sub.collective_bytes
        # ---- collectives ----------------------------------------------------
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind and not op.endswith("-done"):
            cb = arg_bytes if arg_bytes else res_bytes
            total.collective_bytes += cb
            total.collective_by_kind[kind] = \
                total.collective_by_kind.get(kind, 0) + cb
            total.collective_counts[kind] = \
                total.collective_counts.get(kind, 0) + 1
            mdt = _SHAPE.search(instr.shape)
            if mdt and mdt.group(1) in _DTYPE_BYTES:
                total.collective_by_dtype[mdt.group(1)] = \
                    total.collective_by_dtype.get(mdt.group(1), 0) + cb
    cache[name] = total
    return total


def analyze_hlo(text: str) -> Costs:
    comps = _parse_computations(text)
    entry = None
    for line in text.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HDR.match(s)
            if m:
                entry = m.group(2)
                break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    # fusion computations contribute via their callers; only analyze entry
    return _analyze_comp(entry, comps, {})
