"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes   / (chips × HBM_bw)
    collective term = coll_bytes  / (chips × link_bw)

``compiled.cost_analysis()`` on an SPMD module reports **per-device** flops
and bytes (verified empirically: a 4-way-sharded matmul reports full/4), so
per-device value ÷ per-chip peak IS the spec's global/(chips×peak) — the two
readings coincide.  Collective bytes are likewise parsed from the per-device
HLO: we build a %name→shape table and sum *operand* sizes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (three terms in seconds; the dominant one is the step-time floor).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

from repro import compat

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]*?)\s+"
    r"([\w\-]+)\(([^)]*)\)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)
    bytes_by_dtype: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in a (per-device) HLO dump."""
    shapes: dict[str, str] = {}
    defs: list[tuple[str, str, str]] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape, opcode, args = m.groups()
        shapes[name] = shape
        if any(opcode.startswith(c) for c in _COLLECTIVES):
            kind = next(c for c in _COLLECTIVES if opcode.startswith(c))
            defs.append((kind, args, shape))
    stats = CollectiveStats()
    for kind, args, result_shape in defs:
        operand_bytes = 0
        for op in re.findall(r"%[\w.\-]+", args):
            if op in shapes:
                operand_bytes += _shape_bytes(shapes[op])
        if operand_bytes == 0:       # fallback: use the result shape
            operand_bytes = _shape_bytes(result_shape)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) \
            + operand_bytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
        for dt, _ in _SHAPE_RE.findall(result_shape):
            if dt in _DTYPE_BYTES:
                stats.bytes_by_dtype[dt] = stats.bytes_by_dtype.get(dt, 0) \
                    + operand_bytes
                break
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float               # 6·N·D train / 2·N_active·D serve
    useful_flops_ratio: float        # model_flops / (hlo_flops × chips)
    collectives: dict = field(default_factory=dict)
    memory: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return asdict(self)


def analyze(compiled, *, arch: str, shape: str, mesh_desc: str, chips: int,
            model_flops: float) -> Roofline:
    """Roofline terms from the trip-count-aware HLO walk (hlo_cost).

    ``compiled.cost_analysis()`` counts while bodies once (scan-heavy code
    undercounts by orders of magnitude — see hlo_cost docstring), so it is
    recorded only as ``raw_cost_analysis`` for reference.
    """
    from repro.roofline.hlo_cost import analyze_hlo
    # normalized dict on every JAX version (0.4.x returns a 1-elem list)
    ca = compat.cost_analysis(compiled)
    text = compiled.as_text()
    hc = analyze_hlo(text)
    flops = float(hc.flops)
    byts = float(hc.bytes)
    colls = CollectiveStats(bytes_by_kind=dict(hc.collective_by_kind),
                            count_by_kind=dict(hc.collective_counts),
                            bytes_by_dtype=dict(hc.collective_by_dtype))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = colls.total_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    ma = compat.memory_analysis(compiled)
    if ma is not None:
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "total_bytes": int(ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
        }
    else:                                   # backend without memory_analysis
        mem = {}
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops_per_device=flops, hlo_bytes_per_device=byts,
        collective_bytes_per_device=float(colls.total_bytes),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=max(terms, key=terms.get),
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / (flops * chips)
                            if flops else 0.0),
        collectives={"by_kind": colls.bytes_by_kind,
                     "counts": colls.count_by_kind,
                     "by_dtype": colls.bytes_by_dtype,
                     # XLA:CPU legalizes bf16→f32 everywhere (no bf16 ALUs),
                     # so byte counts are ~2x the TPU-native lowering for
                     # bf16 data.  The adjusted terms halve memory/collective
                     # as the documented TPU-native estimate (EXPERIMENTS.md).
                     "bf16_adjusted": {"memory_s": memory_s / 2,
                                       "collective_s": collective_s / 2},
                     "raw_cost_analysis": {
                         "flops": float(ca.get("flops", 0.0)),
                         "bytes_accessed": float(ca.get("bytes accessed",
                                                        0.0))}},
        memory=mem,
    )
