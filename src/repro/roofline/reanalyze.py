"""Recompute roofline terms from saved HLO dumps without recompiling.

``python -m repro.roofline.reanalyze results/dryrun`` rereads every
``results/dryrun/hlo/<tag>.hlo.gz`` and rewrites the flops/bytes/collective
fields of the matching JSON record.  This is what makes the §Perf hypothesis
loop cheap: parser/model improvements re-score all 80 cells in seconds.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro.roofline.analysis import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.roofline.hlo_cost import analyze_hlo


def reanalyze_record(rec: dict, hlo_text: str) -> dict:
    hc = analyze_hlo(hlo_text)
    flops, byts, coll = float(hc.flops), float(hc.bytes), float(hc.collective_bytes)
    rec["hlo_flops_per_device"] = flops
    rec["hlo_bytes_per_device"] = byts
    rec["collective_bytes_per_device"] = coll
    rec["compute_s"] = flops / PEAK_FLOPS
    rec["memory_s"] = byts / HBM_BW
    rec["collective_s"] = coll / LINK_BW
    terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
             "collective": rec["collective_s"]}
    rec["dominant"] = max(terms, key=terms.get)
    if flops:
        rec["useful_flops_ratio"] = rec["model_flops"] / (flops * rec["chips"])
    rec.setdefault("collectives", {})
    rec["collectives"]["by_kind"] = dict(hc.collective_by_kind)
    rec["collectives"]["counts"] = dict(hc.collective_counts)
    rec["collectives"]["by_dtype"] = dict(hc.collective_by_dtype)
    rec["collectives"]["bf16_adjusted"] = {
        "memory_s": rec["memory_s"] / 2,
        "collective_s": rec["collective_s"] / 2}
    return rec


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    n = 0
    for hlo in glob.glob(os.path.join(out, "hlo", "*.hlo.gz")):
        tag = os.path.basename(hlo)[:-len(".hlo.gz")]
        jpath = os.path.join(out, tag + ".json")
        if not os.path.exists(jpath):
            continue
        rec = json.load(open(jpath))
        if rec.get("status") != "ok":
            continue
        with gzip.open(hlo, "rt") as f:
            text = f.read()
        rec = reanalyze_record(rec, text)
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
