"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

ARCH_ORDER = [
    "mistral-nemo-12b", "minicpm3-4b", "smollm-360m", "deepseek-coder-33b",
    "xlstm-125m", "zamba2-1.2b", "llama4-scout-17b-a16e", "qwen2-moe-a2.7b",
    "llava-next-34b", "whisper-small",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str) -> dict:
    recs = {}
    for path in glob.glob(os.path.join(results_dir, "*.json")):
        r = json.load(open(path))
        mesh = "multipod" if path.endswith("__multipod.json") else "pod"
        recs[(r["arch"], r["shape"], mesh)] = r
    return recs


def _ms(x):
    return f"{x * 1e3:.1f}"


def roofline_table(recs: dict, mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | attn | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | skipped | "
                             f"— | — | — |")
                continue
            dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
            # roofline fraction: ideal (compute-only at peak on MODEL_FLOPS)
            # time over the dominant-term time
            ideal = r["model_flops"] / (r["chips"] * 197e12)
            frac = ideal / dom if dom else 0.0
            lines.append(
                f"| {arch} | {shape} | {r.get('attn_mode','?')} | "
                f"{_ms(r['compute_s'])} | {_ms(r['memory_s'])} | "
                f"{_ms(r['collective_s'])} | **{r['dominant']}** | "
                f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} | "
                f"{frac:.3f} |")
    return "\n".join(lines)


def dryrun_table(recs: dict, mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | status | params | bytes/device (GiB) | "
        "HLO GFLOPs/dev | coll bytes/dev | top collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skipped | | | | | "
                             f"{r['reason'][:60]} |")
                continue
            # memory may be {} for backends without memory_analysis support
            mem = r.get("memory", {}).get("total_bytes", 0) / 2**30
            by_kind = r["collectives"]["by_kind"]
            top = ", ".join(f"{k}={v:.1e}" for k, v in
                            sorted(by_kind.items(), key=lambda kv: -kv[1])[:3])
            lines.append(
                f"| {arch} | {shape} | ok | {r['n_params']/1e9:.2f}B | "
                f"{mem:.2f} | {r['hlo_flops_per_device']/1e9:.0f} | "
                f"{r['collective_bytes_per_device']:.2e} | {top} |")
    return "\n".join(lines)


def main():
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print("## Single-pod (16×16) roofline\n")
    print(roofline_table(recs, "pod"))
    print("\n## Multi-pod (2×16×16) dry-run\n")
    print(dryrun_table(recs, "multipod"))


if __name__ == "__main__":
    main()
