"""Softmax + TopK fusion (Algorithm 4 of the paper), TPU-adapted.

The paper's CUDA version keeps a per-thread running top-K via insertion sort
(Alg. 4 lines 10-15).  Scalar insertion has no efficient TPU analogue, so the
TPU-native form processes the vector in tiles: each tile contributes its local
``lax.top_k`` candidates plus its local ``(m, d)`` statistics, and both are
⊕-merged across tiles.  The single-pass property — one read of x, never
materializing softmax(x) — is preserved exactly; only the running-top-k data
structure changed (documented in DESIGN.md §2).

The same routine doubles as the MoE router (softmax over experts + top-k
dispatch probabilities), which is Algorithm 4 at V = num_experts.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.online_softmax import _rescale, online_normalizer

Array = jax.Array


class SoftmaxTopK(NamedTuple):
    """Result of the fused computation (paper Eq. (5) applied to softmax(x))."""
    values: Array      # top-k softmax probabilities, descending
    indices: Array     # their indices in x
    logsumexp: Array   # m + log d — the paper's (m_V, d_V) in log form


def softmax_topk(x: Array, k: int, *, block: int | None = None) -> SoftmaxTopK:
    """Fused softmax+top-k over the last axis: one pass over ``x``.

    ``block`` selects the tile width of the single pass (defaults to the whole
    axis, which lets XLA fuse max/sum/top_k into one sweep; explicit blocking
    mirrors the Pallas kernel's HBM→VMEM tiling and is what the serving path
    uses for very large vocabularies).
    """
    x = jnp.asarray(x)
    v = x.shape[-1]
    k = min(k, v)
    if block is None or block >= v:
        m, d = online_normalizer(x, axis=-1)
        vals, idx = jax.lax.top_k(x, k)
        probs = jnp.exp(vals.astype(m.dtype) - m[..., None]) / d[..., None]
        return SoftmaxTopK(probs.astype(x.dtype), idx, m + jnp.log(d))

    if v % block != 0:
        pad = block - v % block
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                    constant_values=float("-inf"))
        v = x.shape[-1]
    n_blocks = v // block

    def tile(carry, j):
        m_run, d_run, u_run, p_run = carry
        xb = jax.lax.dynamic_slice_in_dim(x, j * block, block, axis=-1)
        xb_f = xb.astype(m_run.dtype)
        # --- (m, d) update: Algorithm 3 lines 4-5, tile-granular -----------
        m_b = jnp.max(xb_f, axis=-1)
        m_new = jnp.maximum(m_run, m_b)
        e_b = jnp.where(jnp.isneginf(xb_f), 0.0, jnp.exp(xb_f - m_new[..., None]))
        d_new = d_run * _rescale(m_run, m_new) + jnp.sum(e_b, axis=-1)
        # --- running top-k update: Alg. 4 lines 8-15, tile-merge form ------
        u_b, p_b = jax.lax.top_k(xb_f, k)
        cand_u = jnp.concatenate([u_run, u_b], axis=-1)
        cand_p = jnp.concatenate([p_run, p_b + j * block], axis=-1)
        u_new, sel = jax.lax.top_k(cand_u, k)
        p_new = jnp.take_along_axis(cand_p, sel, axis=-1)
        return (m_new, d_new, u_new, p_new), None

    batch = x.shape[:-1]
    f32 = jnp.promote_types(x.dtype, jnp.float32)
    init = (jnp.full(batch, float("-inf"), f32), jnp.zeros(batch, f32),
            jnp.full(batch + (k,), float("-inf"), f32),
            jnp.full(batch + (k,), -1, jnp.int32))
    (m, d, u, p), _ = jax.lax.scan(tile, init, jnp.arange(n_blocks))
    probs = jnp.exp(u - m[..., None]) / d[..., None]
    return SoftmaxTopK(probs.astype(x.dtype), p, m + jnp.log(d))


def safe_softmax_then_topk(x: Array, k: int) -> SoftmaxTopK:
    """The paper's unfused baseline: full safe softmax, then top-k (5 passes)."""
    from repro.core.online_softmax import safe_softmax
    y = safe_softmax(x)
    vals, idx = jax.lax.top_k(y, min(k, x.shape[-1]))
    m, d = online_normalizer(x, axis=-1)
    return SoftmaxTopK(vals, idx, m + jnp.log(d))


def gumbel_pick(out: SoftmaxTopK, g: Array) -> Array:
    """Sample ∝ p_i from the K retained probs via Gumbel-max on log p.

    ``g`` is Gumbel noise shaped like ``out.values`` — callers choose whether
    one key covers the batch (``topk_sample``) or each row gets its own
    (``serving.engine.sample_per_slot``, the batch-size-invariance the
    continuous-batching equivalence rests on)."""
    logp = jnp.log(jnp.maximum(out.values.astype(jnp.float32), 1e-30))
    choice = jnp.argmax(logp + g, axis=-1)
    return jnp.take_along_axis(out.indices, choice[..., None], axis=-1)[..., 0]


def topk_sample(rng: Array, x: Array, k: int, *, temperature: float = 1.0,
                block: int | None = None) -> tuple[Array, Array]:
    """Sample a token from the fused top-k softmax (the serving fast path).

    Returns ``(token_ids, top_probs)``.  Uses the Gumbel-max trick over the
    K retained logits — everything after the single pass over the vocabulary
    touches only K elements, which is the paper's §4 scenario.
    """
    if temperature != 1.0:
        x = x / temperature
    out = softmax_topk(x, k, block=block)
    g = jax.random.gumbel(rng, out.values.shape, dtype=jnp.float32)
    return gumbel_pick(out, g), out.values
