"""Online-softmax attention (the paper's ⊕ recurrence applied to attention).

``chunked_attention`` streams KV in chunks and carries ``(m, d, acc)`` — the
running max, normalizer, and un-normalized output — exactly Algorithm 3 with a
weighted-value accumulator bolted on.  It never materializes the [Tq, Tk]
score matrix, so 32k-token prefill and 500k-token contexts fit in memory.
This is the XLA-level twin of ``kernels/flash_attention.py`` (same recurrence;
the kernel adds explicit VMEM tiling) and is what the multi-pod dry-run lowers.

A ``jax.custom_vjp`` supplies the FlashAttention-style backward: the forward
saves only ``(out, lse)`` per row; the backward re-streams KV chunks and
reconstructs probabilities from ``lse``, trading FLOPs for HBM — the same
memory-access economics the paper optimizes.

Layouts: q [B, Tq, Hq, Dh]; k, v [B, Tk, Hkv, Dh]; Hq % Hkv == 0 (GQA/MQA).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = float("-inf")
DEFAULT_CHUNK = 1024


def naive_attention(q: Array, k: Array, v: Array, *, causal: bool = False,
                    q_offset: int | Array = 0, kv_valid_len: Optional[Array] = None,
                    scale: Optional[float] = None) -> Array:
    """Reference attention that materializes the full score matrix (oracle)."""
    b, tq, hq, dh = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    qf = q.astype(jnp.float32).reshape(b, tq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    mask = _mask(tq, tk, causal=causal, q_offset=q_offset,
                 kv_valid_len=kv_valid_len, batch=b)
    if mask is not None:
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m))
    d = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p / jnp.maximum(d, 1e-30),
                   v.astype(jnp.float32))
    return o.reshape(b, tq, hq, dv).astype(q.dtype)


def _mask(tq, tk, *, causal, q_offset, kv_valid_len, batch):
    """[B, Tq, Tk] boolean mask (True = attend), or None if nothing to mask."""
    if not causal and kv_valid_len is None:
        return None
    q_pos = jnp.arange(tq)[:, None] + q_offset          # [Tq, 1]
    k_pos = jnp.arange(tk)[None, :]                     # [1, Tk]
    m = jnp.ones((tq, tk), dtype=bool)
    if causal:
        m = k_pos <= q_pos
    m = jnp.broadcast_to(m, (batch, tq, tk))
    if kv_valid_len is not None:
        m = m & (k_pos[None] < jnp.asarray(kv_valid_len).reshape(-1, 1, 1))
    return m


# ---------------------------------------------------------------------------
# Chunked online attention with FlashAttention-style custom VJP.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def chunked_attention(q: Array, k: Array, v: Array,
                      q_offset: Array, kv_valid_len: Array,
                      causal: bool, chunk_size: int, scale: float) -> Array:
    out, _ = _chunked_fwd_impl(q, k, v, q_offset, kv_valid_len,
                               causal, chunk_size, scale)
    return out


def online_attention(q: Array, k: Array, v: Array, *, causal: bool = False,
                     q_offset: int | Array = 0,
                     kv_valid_len: Optional[Array] = None,
                     chunk_size: int = DEFAULT_CHUNK,
                     scale: Optional[float] = None,
                     causal_blocks: int = 0) -> Array:
    """Public entry point (keyword-friendly wrapper over the custom_vjp core).

    ``causal_blocks > 1`` enables causal chunk skipping for self-attention:
    the query axis is split into that many blocks (unrolled) and block *i*
    only streams KV up to its own end — skipping the strictly-above-diagonal
    work that the masked baseline computes and throws away.  Saves
    ≈ (1 − (B+1)/2B) ≈ 50% of attention FLOPs and score traffic (§Perf).
    """
    b, tq, _, dh = q.shape
    tk = k.shape[1]
    scale = scale if scale is not None else dh ** -0.5
    q_offset = jnp.asarray(q_offset, jnp.int32)
    if kv_valid_len is None:
        kv_valid_len = jnp.full((b,), k.shape[1], jnp.int32)
    kv_valid_len = jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32), (b,))
    # chunk skipping assumes self-aligned q/k (q_offset == 0; the model layer
    # only requests it on the non-cached training/prefill path)
    if causal and causal_blocks > 1 and tq == tk and tq % causal_blocks == 0:
        blk = tq // causal_blocks
        outs = []
        for i in range(causal_blocks):
            kv_end = (i + 1) * blk
            cs = min(chunk_size, kv_end)
            outs.append(chunked_attention(
                q[:, i * blk:(i + 1) * blk], k[:, :kv_end], v[:, :kv_end],
                q_offset + i * blk, jnp.minimum(kv_valid_len, kv_end),
                True, cs, scale))
        return jnp.concatenate(outs, axis=1)
    chunk_size = min(chunk_size, k.shape[1])
    return chunked_attention(q, k, v, q_offset, kv_valid_len,
                             causal, chunk_size, scale)


def _chunk_mask(q_pos, k_pos, kv_valid_len, causal):
    """[B, Tq, C] mask for one KV chunk.  q_pos [Tq] or [B, Tq] (already
    offset — the batched form carries per-row offsets, e.g. continuous-batching
    slots at different lengths), k_pos [C]."""
    m = k_pos[None, None, :] < kv_valid_len[:, None, None]
    if causal:
        qp = q_pos[None, :, None] if q_pos.ndim == 1 else q_pos[:, :, None]
        m = m & (k_pos[None, None, :] <= qp)
    return m


def _q_positions(tq: int, q_offset: Array) -> Array:
    """Query positions: [Tq] for a scalar offset, [B, Tq] for per-row offsets."""
    return jnp.asarray(q_offset, jnp.int32)[..., None] \
        + jnp.arange(tq, dtype=jnp.int32)


def _chunked_fwd_impl(q, k, v, q_offset, kv_valid_len, causal, chunk_size,
                      scale, k_scale=None, v_scale=None):
    """k_scale / v_scale [B, Tk, Hkv]: dequantization scales for int8 caches —
    applied per chunk AFTER the HBM read, so the cache streams at 1 byte/elem
    (the serving-side §Perf lever)."""
    b, tq, hq, dh = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    n_chunks, rem = divmod(tk, chunk_size)
    if rem:  # pad KV; padded keys are masked out via kv_valid_len clamping
        pad = chunk_size - rem
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        n_chunks += 1
    kv_valid_len = jnp.minimum(kv_valid_len, tk)
    qf = (q.astype(jnp.float32) * scale).reshape(b, tq, hkv, g, dh)
    q_pos = _q_positions(tq, q_offset)

    def step(carry, idx):
        m_run, d_run, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, idx * chunk_size, chunk_size, 1)
        vc = jax.lax.dynamic_slice_in_dim(v, idx * chunk_size, chunk_size, 1)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        if k_scale is not None:
            ks_c = jax.lax.dynamic_slice_in_dim(k_scale, idx * chunk_size,
                                                chunk_size, 1)
            vs_c = jax.lax.dynamic_slice_in_dim(v_scale, idx * chunk_size,
                                                chunk_size, 1)
            kc = kc * ks_c.astype(jnp.float32)[..., None]
            vc = vc * vs_c.astype(jnp.float32)[..., None]
        k_pos = idx * chunk_size + jnp.arange(chunk_size, dtype=jnp.int32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc)
        mask = _chunk_mask(q_pos, k_pos, kv_valid_len, causal)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        # --- Algorithm 3 lines 4-5, chunk-granular ------------------------
        m_c = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_run, m_c)
        alpha = jnp.exp(jnp.where(m_run == m_new, 0.0, m_run - m_new))
        p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m_new[..., None]))
        d_new = d_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vc)
        return (m_new, d_new, acc), None

    init = (jnp.full((b, hkv, g, tq), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, tq), jnp.float32),
            jnp.zeros((b, hkv, g, tq, dv), jnp.float32))
    (m, d, acc), _ = jax.lax.scan(step, init, jnp.arange(n_chunks))
    out = acc / jnp.maximum(d, 1e-30)[..., None]
    out = jnp.moveaxis(out, -2, 1).reshape(b, tq, hq, dv).astype(q.dtype)
    lse = jnp.where(d > 0, m + jnp.log(jnp.maximum(d, 1e-30)), NEG_INF)
    return out, lse  # lse: [B, Hkv, G, Tq]


def _fwd(q, k, v, q_offset, kv_valid_len, causal, chunk_size, scale):
    out, lse = _chunked_fwd_impl(q, k, v, q_offset, kv_valid_len,
                                 causal, chunk_size, scale)
    return out, (q, k, v, q_offset, kv_valid_len, out, lse)


def _bwd(causal, chunk_size, scale, res, dout):
    q, k, v, q_offset, kv_valid_len, out, lse = res
    b, tq, hq, dh = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    n_chunks, rem = divmod(tk, chunk_size)
    pad = (chunk_size - rem) if rem else 0
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        n_chunks += 1
    kv_valid_len = jnp.minimum(kv_valid_len, tk)
    qf = jnp.moveaxis(q.astype(jnp.float32).reshape(b, tq, hkv, g, dh), 1, 3)
    dof = dout.astype(jnp.float32).reshape(b, tq, hkv, g, dv)
    dof = jnp.moveaxis(dof, 1, 3)                     # [B,Hkv,G,Tq,Dv]
    of = jnp.moveaxis(out.astype(jnp.float32).reshape(b, tq, hkv, g, dv), 1, 3)
    delta = jnp.sum(dof * of, axis=-1)                # [B,Hkv,G,Tq]
    lse_safe = jnp.where(jnp.isneginf(lse), 0.0, lse)
    q_pos = _q_positions(tq, q_offset)

    def step(dq_acc, idx):
        kc = jax.lax.dynamic_slice_in_dim(k, idx * chunk_size, chunk_size, 1)
        vc = jax.lax.dynamic_slice_in_dim(v, idx * chunk_size, chunk_size, 1)
        k_pos = idx * chunk_size + jnp.arange(chunk_size, dtype=jnp.int32)
        s = jnp.einsum("bhgqd,bkhd->bhgqk", qf * scale, kc.astype(jnp.float32))
        mask = _chunk_mask(q_pos, k_pos, kv_valid_len, causal)[:, None, None]
        p = jnp.where(mask, jnp.exp(s - lse_safe[..., None]), 0.0)
        dv_c = jnp.einsum("bhgqk,bhgqd->bkhd", p, dof)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dof, vc.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bhgqd", ds, kc.astype(jnp.float32))
        dk_c = jnp.einsum("bhgqk,bhgqd->bkhd", ds, qf)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, hkv, g, tq, dh), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(step, dq0, jnp.arange(n_chunks))
    dq = jnp.moveaxis(dq, -2, 1).reshape(b, tq, hq, dh).astype(q.dtype)
    dk_full = dk_c.transpose(1, 0, 2, 3, 4).reshape(
        b, n_chunks * chunk_size, hkv, dh)
    dv_full = dv_c.transpose(1, 0, 2, 3, 4).reshape(
        b, n_chunks * chunk_size, hkv, dv)
    dk_full = dk_full[:, :tk].astype(k.dtype)  # tk = original KV length
    dv_full = dv_full[:, :tk].astype(v.dtype)
    return dq, dk_full, dv_full, None, None


chunked_attention.defvjp(_fwd, _bwd)
