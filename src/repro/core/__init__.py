"""Core: the paper's online-normalizer primitives and their fused consumers."""
from repro.core.online_softmax import (
    ACCESSES_PER_ELEMENT,
    combine,
    identity_like,
    naive_softmax,
    online_log_softmax,
    online_logsumexp,
    online_normalizer,
    online_normalizer_blocked,
    online_normalizer_scan,
    online_softmax,
    safe_softmax,
)
from repro.core.topk_fusion import (
    gumbel_pick,
    SoftmaxTopK,
    safe_softmax_then_topk,
    softmax_topk,
    topk_sample,
)
from repro.core.attention import naive_attention, online_attention
from repro.core.cross_entropy import chunked_cross_entropy, full_cross_entropy

__all__ = [
    "ACCESSES_PER_ELEMENT", "combine", "identity_like", "naive_softmax",
    "online_log_softmax", "online_logsumexp", "online_normalizer",
    "online_normalizer_blocked", "online_normalizer_scan", "online_softmax",
    "safe_softmax", "SoftmaxTopK", "safe_softmax_then_topk", "softmax_topk",
    "gumbel_pick",
    "topk_sample", "naive_attention", "online_attention",
    "chunked_cross_entropy", "full_cross_entropy",
]
