"""Reduced-precision online-softmax forms and their analytic error bounds.

The paper's ``(m, d)`` recurrence is what makes reduced precision *viable*:
the running max rescales every partial sum, so no term ever overflows and the
only damage lower precision can do is bounded rounding — which this module
bounds analytically, per form, from the INPUT alone (row length, dynamic
range).  ``tests/test_numerics.py`` pins every form against the fp32
two-pass reference (``core.safe_softmax``) inside its bound; the bounds are
asserted, never eyeballed.

Forms (the approximation menu of PAPERS.md 2201.04562 — *Reduced Softmax
Unit for DNN Accelerators* — and 2111.10770 — *Efficient Softmax
Approximation*):

* ``softmax_bf16`` — the online recurrence with the normalizer ``d``
  accumulated in bfloat16 (the accelerator-friendly "narrow accumulator"
  form; error is governed by bf16's unit roundoff 2⁻⁸ times the number of
  accumulator roundings).
* ``softmax_exp2`` — every exponential computed as ``2^((x−m)·log₂e)``
  (hardware exp2 menus; error is fp32-level but grows with the row's
  dynamic range R = max(m − xᵢ), because the exponent product rounds).

Both run the same blocked online ``(m, d)`` scan as the kernels (one pass,
⊕-merge across blocks), so their error model transfers to a lowered kernel
unchanged.  They are registered in ``kernels.dispatch`` as
``online_softmax_bf16`` / ``online_softmax_exp2`` behind the
``set_softmax_form`` preference.

The int8 KV-cache quantization bound lives here too (``int8_roundtrip_bound``)
— it is the same numerics surface: ``models.layers._quantize_kv`` stores
``q = round(x/s)`` int8 with ``s = max|x|/127`` kept in bfloat16, and the
reconstruction error per element is at most ``s·(½ + 127·2⁻⁸)`` plus fp32
slack.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.online_softmax import NEG_INF, safe_softmax

Array = jax.Array

BF16_EPS = 2.0 ** -8      # bfloat16 unit roundoff (8-bit mantissa incl. hidden)
F32_EPS = 2.0 ** -24      # float32 unit roundoff
LOG2E = 1.4426950408889634
DEFAULT_BLOCK = 128       # ⊕-tree leaf width of the blocked scan


def _blocked(x: Array, block: int) -> tuple[Array, int]:
    """[..., V] → ([..., NB, BLK] padded with −inf, original V)."""
    xf = jnp.asarray(x, jnp.float32)
    v = xf.shape[-1]
    pad = -v % block
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)],
                     constant_values=NEG_INF)
    return xf.reshape(*xf.shape[:-1], -1, block), v


def _online_md(xb: Array, *, exp_fn: Callable, acc_dtype) -> tuple[Array,
                                                                   Array]:
    """Blocked online (m, d) scan — Algorithm 3 at block granularity, with
    the exponential function and the accumulator dtype as the knobs the
    reduced forms turn.  ``xb`` [..., NB, BLK] → (m [...], d [...])."""
    lead = xb.shape[:-2]

    def step(carry, xj):
        m_prev, d_prev = carry
        m_new = jnp.maximum(m_prev, jnp.max(xj, -1))
        alpha = exp_fn(jnp.where(m_prev == m_new, 0.0, m_prev - m_new))
        p = jnp.where(jnp.isneginf(xj), 0.0, exp_fn(xj - m_new[..., None]))
        d_new = (d_prev * alpha.astype(acc_dtype)
                 + jnp.sum(p, -1).astype(acc_dtype)).astype(acc_dtype)
        return (m_new, d_new), None

    init = (jnp.full(lead, NEG_INF, jnp.float32),
            jnp.zeros(lead, acc_dtype))
    (m, d), _ = jax.lax.scan(step, init, jnp.moveaxis(xb, -2, 0))
    return m, d


def _normalize(x: Array, m: Array, d: Array, exp_fn: Callable) -> Array:
    xf = jnp.asarray(x, jnp.float32)
    num = jnp.where(jnp.isneginf(xf), 0.0, exp_fn(xf - m[..., None]))
    den = jnp.where(d == 0, 1.0, d.astype(jnp.float32))[..., None]
    y = num / den
    return y.astype(x.dtype) if jnp.issubdtype(x.dtype, jnp.floating) else y


def softmax_bf16(x: Array, *, block: int = DEFAULT_BLOCK) -> Array:
    """Online softmax with the normalizer accumulated in bfloat16."""
    xb, v = _blocked(x, block)
    m, d = _online_md(xb, exp_fn=jnp.exp, acc_dtype=jnp.bfloat16)
    return _normalize(x, m, d, jnp.exp)


def _exp2_fn(z: Array) -> Array:
    return jnp.exp2(z * jnp.float32(LOG2E))


def softmax_exp2(x: Array, *, block: int = DEFAULT_BLOCK) -> Array:
    """Online softmax with exponentials as ``2^(z·log₂e)`` (hardware exp2)."""
    xb, v = _blocked(x, block)
    m, d = _online_md(xb, exp_fn=_exp2_fn, acc_dtype=jnp.float32)
    return _normalize(x, m, d, _exp2_fn)


def softmax_exact(x: Array, *, block: int = DEFAULT_BLOCK) -> Array:
    """The fp32 online form on the same blocked scan — the control case:
    its bound is pure fp32 accumulation slop, no reduced-precision term."""
    xb, v = _blocked(x, block)
    m, d = _online_md(xb, exp_fn=jnp.exp, acc_dtype=jnp.float32)
    return _normalize(x, m, d, jnp.exp)


# ---------------------------------------------------------------------------
# Analytic error bounds: worst-case max-abs deviation from the fp32 two-pass
# reference, computed from the input's shape and dynamic range — never from
# the observed output.  Each derivation counts roundings per term; softmax
# outputs are ≤ 1, so relative perturbations of numerator and denominator
# bound the absolute output error directly (|p̂/d̂ − p/d| ≤ rel(p) + rel(d)
# to first order; the /(1−t) factor absorbs the higher-order terms).
# ---------------------------------------------------------------------------
def _n_blocks(v: int, block: int) -> int:
    return max(math.ceil(v / block), 1)


def _row_range(x) -> float:
    """max over rows of (row max − row min) over finite entries — the R in
    the exp2 bound.  −inf entries contribute exp2(−inf) = 0 exactly, so they
    are excluded."""
    xf = np.asarray(x, np.float32).reshape(-1, np.shape(x)[-1])
    fin = np.isfinite(xf)
    hi = np.where(fin, xf, -np.inf).max(axis=-1)
    lo = np.where(fin, xf, np.inf).min(axis=-1)
    r = hi - lo
    r = r[np.isfinite(r)]
    return float(r.max()) if r.size else 0.0


def exact_error_bound(x, *, block: int = DEFAULT_BLOCK) -> float:
    """fp32-vs-fp32 slop: both sides round each exp (1·u each side) and
    accumulate V terms in some order (≤ V−1 roundings per term each side),
    plus the divide — ≤ (2V + 8)·u₃₂ relative on either statistic."""
    v = np.shape(x)[-1]
    t = (2 * v + 8) * F32_EPS
    return t / (1 - t)


def bf16_error_bound(x, *, block: int = DEFAULT_BLOCK) -> float:
    """Per scan step the bf16 accumulator rounds ≤ 4 times (alpha cast,
    multiply, block-sum cast, add), each rounding relatively perturbing every
    term already in ``d`` by ≤ u_bf16; a term enters with ≤ 2 roundings.
    Over NB blocks: rel(d) ≤ (4·NB + 2)·u_bf16.  The numerator and the fp32
    reference add ≤ 2·(V+2)·u₃₂ — folded in as 2 more bf16 ulps (u₃₂ ≪
    u_bf16)."""
    v = np.shape(x)[-1]
    nb = _n_blocks(v, block)
    t = (4 * nb + 4) * BF16_EPS
    if t >= 0.5:
        # bound would be ≥ 1 — vacuous for probabilities.  A bf16 normalizer
        # over this many blocks is outside the form's deployment envelope;
        # refuse loudly instead of returning a number nothing can violate.
        raise ValueError(
            f"vacuous bf16 bound (t={t:.2f} ≥ 0.5) for V={v}, block={block}")
    return t / (1 - t)


def exp2_error_bound(x, *, block: int = DEFAULT_BLOCK) -> float:
    """exp2 term error: the exponent product ``z·fl(log₂e)`` carries ≤ 2·u₃₂
    relative → ≤ 2·u₃₂·|z|·log₂e absolute exponent error → relative term
    error ≤ ln2·(2·u₃₂·|z|·log₂e) + u₃₂ (exp2 eval) = 2·R·u₃₂ + u₃₂ with
    R = max(m − xᵢ) ≤ the row's finite dynamic range.  Numerator +
    denominator (with fp32 accumulation over NB blocks and V terms) + the
    fp32 reference's own (V+2)·u₃₂."""
    v = np.shape(x)[-1]
    nb = _n_blocks(v, block)
    r = _row_range(x)
    t = (4.0 * r + 4 * nb + 2 * v + 16) * F32_EPS
    return t / (1 - t)


class Form(NamedTuple):
    apply: Callable          # x → softmax(x), the reduced-precision way
    error_bound: Callable    # x → analytic max-abs bound vs fp32 reference


#: Every registered reduced-precision softmax form, keyed by the name
#: ``kernels.dispatch.set_softmax_form`` accepts.  ``reference`` is what the
#: bounds are stated against.
FORMS: dict[str, Form] = {
    "exact": Form(softmax_exact, exact_error_bound),
    "bf16": Form(softmax_bf16, bf16_error_bound),
    "exp2": Form(softmax_exp2, exp2_error_bound),
}

reference = safe_softmax


# ---------------------------------------------------------------------------
# int8 KV-cache quantization bound (models.layers._quantize_kv +
# cache_family.DenseInt8Family.dequantize_block).
# ---------------------------------------------------------------------------
#: fp32 slack multiplier in the roundtrip bound: the fp32 divide/round in
#: quantization and the fp32 multiply in dequantization each contribute
#: ≤ a few u₃₂ relative — absorbed as 8 u₃₂ on the 127·s term.
_INT8_F32_SLACK = 8 * F32_EPS


def int8_roundtrip_bound(scale) -> np.ndarray:
    """Per-position max-abs reconstruction bound for the int8 KV roundtrip.

    With fp32 scale ``s`` (clamped ≥ 1e-8), ``q = clip(round(x/s), ±127)``
    and the stored scale bf16-rounded (``|ŝ−s| ≤ s·u_bf16``):

        |q·ŝ − x| ≤ |q|·|ŝ−s| + s·|q − x/s|
                  ≤ 127·s·u_bf16 + s·(½ + fp32 slack)

    ``scale`` is the fp32 (unclamped-then-clamped) per-position scale —
    recompute it in the test, don't read it back from the cache (the cache
    holds the bf16-rounded copy)."""
    s = np.maximum(np.asarray(scale, np.float32), 1e-8)
    return s * (0.5 + 127.0 * BF16_EPS + 127.0 * _INT8_F32_SLACK)
