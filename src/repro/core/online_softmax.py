"""Online normalizer calculation for softmax (Milakov & Gimelshein, 2018).

This module is the paper's contribution as composable pure-JAX primitives:

* ``combine`` — the associative+commutative ``⊕`` operator of Eq. (4) on
  ``(m, d)`` running-statistics pairs.  Everything else in this repo (chunked
  attention, chunked cross-entropy, fused top-k, the Pallas kernels) is an
  application of this operator.
* ``online_normalizer_scan`` — Algorithm 3 lines 1–6, literal sequential form
  (used as the ground-truth recurrence in tests).
* ``online_normalizer`` — tiled/parallel evaluation of the same statistics via
  a ``⊕`` tree reduction (Section 3.1 of the paper).
* ``online_softmax`` / ``online_log_softmax`` / ``online_logsumexp`` — the
  user-facing functions, numerically identical to safe softmax.

Numerical conventions
---------------------
The identity element of ``⊕`` is ``(m, d) = (-inf, 0)``.  ``exp(-inf - -inf)``
is NaN in IEEE arithmetic, so ``combine`` routes the rescale factor through a
``where`` that pins ``m_a == m`` (which covers the ``-inf`` collision) to a
rescale of exactly 1.  Fully-masked rows therefore yield ``d = 0`` and a
softmax of 0 (not NaN) when ``where=`` masks are used.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
MD = Tuple[Array, Array]

NEG_INF = float("-inf")


def _rescale(m_old: Array, m_new: Array) -> Array:
    """exp(m_old - m_new) with the -inf/-inf collision pinned to 1."""
    return jnp.exp(jnp.where(m_old == m_new, 0.0, m_old - m_new))


def combine(a: MD, b: MD) -> MD:
    """The paper's Eq. (4) ``⊕`` operator.

    (m_a, d_a) ⊕ (m_b, d_b) = (max(m_a, m_b),
                               d_a·e^{m_a−m} + d_b·e^{m_b−m})

    Associative and commutative; identity is ``(-inf, 0)``.
    """
    m_a, d_a = a
    m_b, d_b = b
    m = jnp.maximum(m_a, m_b)
    d = d_a * _rescale(m_a, m) + d_b * _rescale(m_b, m)
    return m, d


def identity_like(shape, dtype=jnp.float32) -> MD:
    """The ``⊕`` identity element, broadcast to ``shape``."""
    return (jnp.full(shape, NEG_INF, dtype=dtype), jnp.zeros(shape, dtype=dtype))


# ---------------------------------------------------------------------------
# Algorithm 3, literal sequential form (lines 1-6).
# ---------------------------------------------------------------------------
def online_normalizer_scan(x: Array) -> MD:
    """Sequential single-pass (m, d) over the last axis — Algorithm 3 verbatim.

    Kept as the executable specification; production paths use the tiled
    ``online_normalizer`` below.  Works on any leading batch shape.
    """
    x = jnp.asarray(x)
    init = identity_like(x.shape[:-1], dtype=jnp.promote_types(x.dtype, jnp.float32))

    def step(carry: MD, x_j: Array) -> tuple[MD, None]:
        m_prev, d_prev = carry
        m_j = jnp.maximum(m_prev, x_j)                      # line 4
        d_j = d_prev * _rescale(m_prev, m_j) + jnp.exp(x_j - m_j)  # line 5
        return (m_j, d_j), None

    (m, d), _ = jax.lax.scan(step, init, jnp.moveaxis(x, -1, 0))
    return m, d


# ---------------------------------------------------------------------------
# Section 3.1: parallel evaluation via the ⊕ reduction tree.
# ---------------------------------------------------------------------------
def online_normalizer(x: Array, *, axis: int = -1, where: Array | None = None) -> MD:
    """(m, d) = (max x, Σ e^{x−m}) computed as one fused reduction.

    Under XLA this lowers to a single reduction over ``axis`` for ``m`` plus a
    fused exp-sum — the compiler's realization of the ⊕ tree.  ``where`` masks
    elements out of both statistics (they behave as the ⊕ identity).
    """
    xf = jnp.asarray(x, dtype=jnp.promote_types(x.dtype, jnp.float32))
    if where is not None:
        xf = jnp.where(where, xf, NEG_INF)
    m = jnp.max(xf, axis=axis)
    # exp(x - m): masked/all-masked rows give exp(-inf - -inf) -> guard.
    shifted = xf - jnp.expand_dims(m, axis)
    e = jnp.where(jnp.isneginf(xf), 0.0, jnp.exp(shifted))
    d = jnp.sum(e, axis=axis)
    return m, d


def online_normalizer_blocked(x: Array, *, block: int, axis: int = -1) -> MD:
    """Explicit tiled ⊕ evaluation: reduce each block, then ⊕-merge blocks.

    This is the structure the Pallas kernels and the distributed (model-axis
    sharded) vocab softmax use; exposed in the core API both for tests of the
    ⊕ algebra and so XLA-level users can pick the tree shape.
    """
    x = jnp.moveaxis(jnp.asarray(x), axis, -1)
    v = x.shape[-1]
    if v % block != 0:
        pad = block - v % block
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                    constant_values=NEG_INF)
    xb = x.reshape(*x.shape[:-1], x.shape[-1] // block, block)
    m_b, d_b = online_normalizer(xb, axis=-1)       # per-block stats
    # ⊕-merge across the block axis (a balanced tree under XLA's reduce).
    m = jnp.max(m_b, axis=-1)
    d = jnp.sum(d_b * _rescale(m_b, m[..., None]), axis=-1)
    return m, d


# ---------------------------------------------------------------------------
# User-facing softmax family.
# ---------------------------------------------------------------------------
def online_logsumexp(x: Array, *, axis: int = -1, where: Array | None = None) -> Array:
    m, d = online_normalizer(x, axis=axis, where=where)
    return m + jnp.log(d)


def online_softmax(x: Array, *, axis: int = -1, where: Array | None = None) -> Array:
    """Safe softmax computed with the online normalizer; same result as Eq. (2)."""
    m, d = online_normalizer(x, axis=axis, where=where)
    xf = jnp.asarray(x, dtype=m.dtype)
    if where is not None:
        xf = jnp.where(where, xf, NEG_INF)
    e = jnp.where(jnp.isneginf(xf), 0.0,
                  jnp.exp(xf - jnp.expand_dims(m, axis)))
    denom = jnp.expand_dims(jnp.where(d == 0, 1.0, d), axis)
    y = e / denom
    return y.astype(x.dtype) if jnp.issubdtype(x.dtype, jnp.floating) else y


def online_log_softmax(x: Array, *, axis: int = -1) -> Array:
    lse = online_logsumexp(x, axis=axis)
    return (jnp.asarray(x, lse.dtype) - jnp.expand_dims(lse, axis)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Reference implementations from the paper (Algorithms 1 & 2), used by tests
# and benchmarks as the baselines the paper compares against.
# ---------------------------------------------------------------------------
def naive_softmax(x: Array, *, axis: int = -1) -> Array:
    """Algorithm 1 — two passes, numerically unsafe (overflow for x >~ 88)."""
    xf = jnp.asarray(x, dtype=jnp.promote_types(x.dtype, jnp.float32))
    e = jnp.exp(xf)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


def safe_softmax(x: Array, *, axis: int = -1) -> Array:
    """Algorithm 2 — three passes (max, sum, normalize); the frameworks' default."""
    xf = jnp.asarray(x, dtype=jnp.promote_types(x.dtype, jnp.float32))
    m = jnp.max(xf, axis=axis, keepdims=True)
    e = jnp.exp(xf - m)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Memory-access model (paper Sections 2-4) — analytic counts used by the
# benchmark harness to validate the paper's 4->3 and 5->1 claims.
# ---------------------------------------------------------------------------
ACCESSES_PER_ELEMENT = {
    # loads + stores per input element, from the paper's own accounting
    "naive_softmax": 3,        # 2 loads + 1 store   (§2)
    "safe_softmax": 4,         # 3 loads + 1 store   (§2)
    "online_softmax": 3,       # 2 loads + 1 store   (§3)
    "safe_softmax_topk_unfused": 5,   # §4: safe softmax (4) + topk load (1)
    "online_softmax_topk_unfused": 4, # §4
    "safe_softmax_topk_fused": 2,     # max pass + fused (d,topk) pass
    "online_softmax_topk_fused": 1,   # §4: single pass, Algorithm 4
}


@functools.partial(jax.jit, static_argnames=("axis",))
def jit_online_softmax(x: Array, axis: int = -1) -> Array:
    return online_softmax(x, axis=axis)
