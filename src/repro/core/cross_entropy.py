"""Vocabulary-chunked online cross-entropy (paper §7 "fuse with the preceding
layer", realized at the LM head).

``loss_i = lse(h_i · W) − (h_i · W)[label_i]``.  The logsumexp is computed with
the paper's online normalizer, streaming the vocabulary in chunks: logits for
a chunk are produced, folded into the running ``(m, d)`` via ⊕, and discarded.
The [tokens × vocab] logit tensor — 808 MB *per 1k tokens* at V=202k/fp32 —
never exists.  The custom VJP re-streams chunks, so backward needs the same
O(T·chunk) workspace.

Under a model-axis-sharded ``W`` (vocab partitioned), each device folds its
local chunks and XLA inserts the cross-device ⊕ (a max + sum all-reduce over
[T]-shaped statistics) — the distributed form of Algorithm 3.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = float("-inf")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def chunked_softmax_xent(hidden: Array, w: Array, labels: Array,
                         num_chunks: int, z_loss: float) -> Array:
    loss, _, _ = _fwd_impl(hidden, w, labels, num_chunks, z_loss)
    return loss


def chunked_cross_entropy(hidden: Array, w: Array, labels: Array, *,
                          num_chunks: int = 8, z_loss: float = 0.0) -> Array:
    """Per-token CE loss [T] from hidden [T, D], head W [D, V], labels [T].

    ``num_chunks`` is the vocab-streaming factor; V % num_chunks == 0 is
    required (configs guarantee it; pad the head if adapting).
    """
    assert w.shape[1] % num_chunks == 0, (w.shape, num_chunks)
    return chunked_softmax_xent(hidden, w, labels, num_chunks, z_loss)


def _fwd_impl(hidden, w, labels, num_chunks, z_loss):
    t, d = hidden.shape
    v = w.shape[1]
    c = v // num_chunks
    hf = hidden.astype(jnp.float32)

    def body(carry, i):
        m_run, d_run, label_logit = carry
        wc = jax.lax.dynamic_slice_in_dim(w, i * c, c, axis=1)
        logits = hf @ wc.astype(jnp.float32)               # [T, c] — transient
        # ⊕ fold (Algorithm 3, chunk-granular)
        m_c = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_run, m_c)
        alpha = jnp.exp(jnp.where(m_run == m_new, 0.0, m_run - m_new))
        d_new = d_run * alpha + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=-1)
        # pick out the label logit if it lives in this chunk
        local = labels - i * c
        in_chunk = (local >= 0) & (local < c)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, c - 1)[:, None], axis=1)[:, 0]
        label_logit = jnp.where(in_chunk, picked, label_logit)
        return (m_new, d_new, label_logit), None

    init = (jnp.full((t,), NEG_INF, jnp.float32), jnp.zeros((t,), jnp.float32),
            jnp.zeros((t,), jnp.float32))
    (m, dsum, label_logit), _ = jax.lax.scan(body, init, jnp.arange(num_chunks))
    lse = m + jnp.log(dsum)
    loss = lse - label_logit
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss, lse, label_logit


def _fwd(hidden, w, labels, num_chunks, z_loss):
    loss, lse, _ = _fwd_impl(hidden, w, labels, num_chunks, z_loss)
    return loss, (hidden, w, labels, lse)


def _bwd(num_chunks, z_loss, res, dloss):
    hidden, w, labels, lse = res
    t, d = hidden.shape
    v = w.shape[1]
    c = v // num_chunks
    hf = hidden.astype(jnp.float32)
    dloss = dloss.astype(jnp.float32)
    # d loss_i / d logits_ij = softmax_ij − onehot(label)_ij  (+ z-loss term)
    zcoef = (1.0 + 2.0 * z_loss * lse) * dloss if z_loss else dloss

    def body(dh_acc, i):
        wc = jax.lax.dynamic_slice_in_dim(w, i * c, c, axis=1).astype(jnp.float32)
        logits = hf @ wc
        p = jnp.exp(logits - lse[:, None])
        local = labels - i * c
        in_chunk = (local >= 0) & (local < c)
        onehot = (jax.nn.one_hot(jnp.clip(local, 0, c - 1), c, dtype=jnp.float32)
                  * in_chunk[:, None])
        dlogits = p * zcoef[:, None] - onehot * dloss[:, None]
        dh_acc = dh_acc + dlogits @ wc.T
        dwc = hf.T @ dlogits
        return dh_acc, dwc

    dh, dw_chunks = jax.lax.scan(body, jnp.zeros((t, d), jnp.float32),
                                 jnp.arange(num_chunks))
    # scan stacks [num_chunks, D, c] -> [D, V]
    dw = jnp.moveaxis(dw_chunks, 0, 1).reshape(d, v)
    return dh.astype(hidden.dtype), dw.astype(w.dtype), None


chunked_softmax_xent.defvjp(_fwd, _bwd)


def full_cross_entropy(hidden: Array, w: Array, labels: Array, *,
                       z_loss: float = 0.0) -> Array:
    """Baseline that materializes all logits (the framework-default the paper
    improves on); used by tests and the bench_chunked_ce benchmark."""
    logits = hidden.astype(jnp.float32) @ w.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss
