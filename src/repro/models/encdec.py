"""Encoder–decoder transformer (Whisper-style backbone).

Per the assignment, the conv/audio frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings [B, S_enc, D]; a linear adapter stands
in for the conv stack.  Encoder: bidirectional self-attention + sinusoidal
positions.  Decoder: causal self-attention (KV-cached) + cross-attention over
the encoder output (K/V computed once at prefill) + MLP.  LayerNorm + GELU,
learned decoder positions — whisper conventions.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import core
from repro.configs.base import ModelConfig
from repro.models import layers as L

Array = jax.Array
PyTree = Any


def sinusoidal(t: int, d: int) -> Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block_init(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.layer_norm_init(cfg), "attn": L.attention_init(k1, cfg),
            "ln2": L.layer_norm_init(cfg), "mlp": L.mlp_init(k2, cfg)}


def _dec_block_init(key, cfg: ModelConfig) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.layer_norm_init(cfg), "self_attn": L.attention_init(k1, cfg),
            "lnx": L.layer_norm_init(cfg), "cross_attn": L.attention_init(k2, cfg),
            "ln2": L.layer_norm_init(cfg), "mlp": L.mlp_init(k3, cfg)}


def init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)

    def stack(init_fn, key, n):
        return L.stack_layer_init(lambda k: init_fn(k, cfg), key, n)

    return {
        "adapter": L._dense_init(ks[0], (cfg.d_model, cfg.d_model),
                                 (None, "embed"), dtype=dt),
        "encoder": stack(_enc_block_init, ks[1], cfg.encoder_layers),
        "enc_norm": L.layer_norm_init(cfg),
        "embedding": L.embedding_init(ks[2], cfg),
        "pos_embed": L._dense_init(ks[3], (cfg.max_seq_len, cfg.d_model),
                                   (None, "embed"), scale=0.02, dtype=dt),
        "decoder": stack(_dec_block_init, ks[4], cfg.num_layers),
        "dec_norm": L.layer_norm_init(cfg),
    }


def encode(params: PyTree, frames: Array, cfg: ModelConfig) -> Array:
    """frames [B, S_enc, D] (stub embeddings) → encoder hidden [B, S_enc, D]."""
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["adapter"]
    x = x + sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(x, p):
        h = L.layer_norm(p["ln1"], x, cfg.norm_eps)
        a, _ = L.attention_apply(p["attn"], h, cfg,
                                 positions=jnp.arange(x.shape[1]),
                                 causal=False)
        x = x + a
        h = L.layer_norm(p["ln2"], x, cfg.norm_eps)
        return x + L.mlp_apply(p["mlp"], h, cfg), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return L.layer_norm(params["enc_norm"], x, cfg.norm_eps)


def _cross_attention(p, x, cfg, *, enc_out=None, kv_cache=None):
    """Cross-attention: K/V from encoder output (or its cached projection)."""
    b, t, d = x.shape
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, t, hq, hd)
    if enc_out is not None:
        # prefill/train: compute K/V from the encoder output (any provided
        # cache is the zero-initialized buffer — it gets REPLACED, not read)
        s_enc = enc_out.shape[1]
        k = (enc_out @ p["wk"]).reshape(b, s_enc, cfg.num_kv_heads, hd)
        v = (enc_out @ p["wv"]).reshape(b, s_enc, cfg.num_kv_heads, hd)
    else:
        k, v = kv_cache["k"], kv_cache["v"]
    out = core.online_attention(q, k, v, causal=False,
                                chunk_size=cfg.attn_chunk)
    return out.reshape(b, t, hq * hd) @ p["wo"], {"k": k, "v": v}


def decode_hidden(params: PyTree, tokens: Array, enc_out: Optional[Array],
                  cfg: ModelConfig, *, caches: Optional[list] = None,
                  cache_len: Optional[Array] = None):
    """Decoder forward.  caches = [{self: {k,v}, cross: {k,v}} per layer]
    (stacked).  Returns (hidden [B,T,D], new stacked caches)."""
    x = L.embed_tokens(params["embedding"], tokens)
    base = jnp.asarray(cache_len if cache_len is not None else 0, jnp.int32)
    # scalar cache_len → positions [T] (broadcast over the batch); per-slot
    # [B] cache_len → positions [B, T] (rope and the learned table both
    # accept leading batch dims)
    positions = base[..., None] + jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x = x + jnp.take(params["pos_embed"], positions, axis=0)

    def body(x, layer_in):
        p, cache = layer_in
        h = L.layer_norm(p["ln1"], x, cfg.norm_eps)
        self_cache = None if cache is None else cache["self"]
        a, new_self = L.attention_apply(p["self_attn"], h, cfg,
                                        positions=positions,
                                        cache=self_cache,
                                        cache_len=cache_len)
        x = x + a
        h = L.layer_norm(p["lnx"], x, cfg.norm_eps)
        cross_cache = None if cache is None else cache["cross"]
        a, new_cross = _cross_attention(p["cross_attn"], h, cfg,
                                        enc_out=enc_out, kv_cache=cross_cache)
        x = x + a
        h = L.layer_norm(p["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h, cfg)
        new_cache = {"self": new_self, "cross": new_cross}
        return x, new_cache

    wrapped = body if caches is not None else jax.checkpoint(body)
    x, new_caches = jax.lax.scan(wrapped, x, (params["decoder"], caches))
    return L.layer_norm(params["dec_norm"], x, cfg.norm_eps), new_caches


def loss_fn(params: PyTree, batch: dict, cfg: ModelConfig):
    """batch: frames [B,S,D], tokens [B,T], labels [B,T]."""
    enc_out = encode(params, batch["frames"], cfg)
    hidden, _ = decode_hidden(params, batch["tokens"], enc_out, cfg)
    b, t, d = hidden.shape
    labels = batch["labels"].reshape(-1)
    valid = labels >= 0
    w = L.head_matrix(params["embedding"], cfg)
    tok_loss = core.chunked_cross_entropy(hidden.reshape(-1, d), w,
                                          jnp.where(valid, labels, 0),
                                          num_chunks=cfg.vocab_chunks)
    loss = jnp.sum(tok_loss * valid) / jnp.maximum(valid.sum(), 1)
    return loss, {"loss": loss, "ce_loss": loss}
