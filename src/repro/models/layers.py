"""Shared model layers, parameter system, and sharding annotations.

Parameters are plain nested dicts of ``jax.Array``.  Every ``*_init`` builds a
matching *logical-axis* tree (tuples of axis names per leaf) alongside the
values via the ``Param`` box; ``split_params`` separates them.  Logical names
("embed", "ffn", "heads", "vocab", "expert", …) are mapped to mesh axes by
``repro.distributed.sharding`` — the model code never mentions a mesh.

Attention comes in two implementations of the same math:
* ``repro.core.online_attention`` — chunked online-softmax (XLA; default, and
  the thing the multi-pod dry-run lowers), and
* ``repro.kernels.ops.flash_attention`` — the Pallas TPU kernel
  (``cfg.use_pallas``).
Which one runs is resolved by ``repro.kernels.dispatch`` against the probed
backend capabilities; this module only states preferences.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat, core
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Parameter boxing: value + logical axes in one leaf, split after init.
# ---------------------------------------------------------------------------
class Param(NamedTuple):
    value: Array
    axes: tuple


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree: PyTree) -> tuple[PyTree, PyTree]:
    values = compat.tree_map(lambda p: p.value, tree, is_leaf=is_param)
    axes = compat.tree_map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def stack_layer_init(init_fn, key, n: int) -> PyTree:
    """vmap an init over ``n`` layer keys, stacking values on a leading
    "layers" axis.  (The string axes inside Param boxes can't be vmapped, so
    values are batched separately and re-boxed.)"""
    keys = jax.random.split(key, n)
    template = init_fn(keys[0])
    boxes = compat.tree_leaves(template, is_leaf=is_param)
    treedef = compat.tree_structure(template, is_leaf=is_param)

    def values_only(k):
        return [p.value for p in compat.tree_leaves(init_fn(k), is_leaf=is_param)]

    stacked = jax.vmap(values_only)(keys)
    reboxed = [Param(v, ("layers",) + p.axes) for v, p in zip(stacked, boxes)]
    return compat.tree_unflatten(treedef, reboxed)


def _dense_init(key, shape, axes, *, scale: Optional[float] = None,
                dtype=jnp.float32) -> Param:
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    v = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return Param(v.astype(dtype), axes)


def _zeros(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def _ones(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Norms & positional encoding.
# ---------------------------------------------------------------------------
def rms_norm_init(cfg: ModelConfig, d: Optional[int] = None) -> PyTree:
    return {"scale": _ones((d or cfg.d_model,), ("embed",))}


def rms_norm(p: PyTree, x: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def layer_norm_init(cfg: ModelConfig, d: Optional[int] = None) -> PyTree:
    d = d or cfg.d_model
    return {"scale": _ones((d,), ("embed",)), "bias": _zeros((d,), ("embed",))}


def layer_norm(p: PyTree, x: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
            + p["bias"]).astype(x.dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x [..., T, H, D_rot]; positions [..., T] or [T]."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angles = positions[..., :, None].astype(jnp.float32) * freqs   # [..,T,D/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense GQA attention.
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig) -> PyTree:
    """Projections are stored FLAT ([D, H·hd]) under the "qkv_out"/"kv_out"
    logical axes: H·hd shards over the model axis even when H itself does not
    divide it (the sequence-parallel fallback then reshards activations, not
    weights — DESIGN.md §4)."""
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, hq * hd), ("embed", "qkv_out"), dtype=dt),
        "wk": _dense_init(ks[1], (d, hkv * hd), ("embed", "kv_out"), dtype=dt),
        "wv": _dense_init(ks[2], (d, hkv * hd), ("embed", "kv_out"), dtype=dt),
        "wo": _dense_init(ks[3], (hq * hd, d), ("qkv_out", "embed"), dtype=dt),
    }


def _shard_ctx():
    from repro.distributed import context
    return context.get()


def cache_write(cache_arr: Array, new: Array, cache_len) -> Array:
    """Write ``new`` ([B, t, ...]) into ``cache_arr`` ([B, S, ...]) at offset
    ``cache_len`` along the sequence axis.

    A scalar ``cache_len`` is the lockstep-batch case (one shared offset); a
    ``[B]`` vector writes each row at its own offset — the continuous-batching
    slot pool, where every cache slot holds a sequence of different length.
    """
    ln = jnp.asarray(cache_len, jnp.int32)
    new = new.astype(cache_arr.dtype)
    if ln.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache_arr, new, ln, axis=1)
    return jax.vmap(
        lambda c, n, l: jax.lax.dynamic_update_slice_in_dim(c, n, l, axis=0)
    )(cache_arr, new, ln)


def paged_cache_write(pool: Array, new: Array, cache_len,
                      block_tables: Array) -> Array:
    """Write ``new`` ([B, t, Hkv, D]) into the block pool ([P, Hkv, BS, D])
    through the block table ([B, M]).

    Row b's position ``cache_len[b] + i`` lands in physical block
    ``block_tables[b, pos // BS]`` at offset ``pos % BS``.  The allocator
    guarantees distinct rows never write the same (block, offset) — shared
    prefix blocks are copy-on-write'd by ``serving.paged`` before any write —
    except idle rows (length 0), which all land harmlessly in the sentinel
    block the allocator never hands out."""
    b, t = new.shape[:2]
    bs = pool.shape[2]
    ln = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    pos = ln[:, None] + jnp.arange(t, dtype=jnp.int32)          # [B, t]
    bids = jnp.take_along_axis(jnp.asarray(block_tables, jnp.int32),
                               pos // bs, axis=1)
    offs = pos % bs
    flat = new.astype(pool.dtype).reshape((b * t,) + new.shape[2:])
    return pool.at[bids.reshape(-1), :, offs.reshape(-1)].set(flat)


def _valid_len(cache_len, t: int, b: int) -> Array:
    """Per-row valid KV length after writing ``t`` new positions."""
    return jnp.broadcast_to(jnp.asarray(cache_len + t, jnp.int32), (b,))


def _sdpa(cfg: ModelConfig, q, k, v, *, causal, q_offset, kv_valid_len,
          scale: Optional[float] = None, decode: bool = False,
          k_scale=None, v_scale=None, block_tables=None):
    """Attention via the capability-probing registry (kernels.dispatch):
    shard_map ⊕-merge decode / Pallas (compiled or interpret) / XLA chunked;
    ``block_tables`` set routes the paged block-pool forms."""
    from repro.kernels import dispatch
    return dispatch.sdpa(cfg, q, k, v, causal=causal, q_offset=q_offset,
                         kv_valid_len=kv_valid_len, scale=scale,
                         decode=decode, k_scale=k_scale, v_scale=v_scale,
                         block_tables=block_tables)


def _quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(position, head) int8 quantization: x [B,T,H,D] → (int8, scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _constrain_seq_parallel(ctx, q, k, v):
    """Sequence-parallel (context-parallel) attention sharding: q sharded on
    T over the model axis, K/V gathered — used when the head count does not
    divide the model axis (DESIGN.md §4)."""
    from jax.sharding import PartitionSpec as P
    dp = ctx.batch_axes
    m = ctx.par.model_axis
    mesh = ctx.mesh
    q = jax.lax.with_sharding_constraint(
        q, compat.named_sharding(mesh, P(dp, m, None, None)))
    k = jax.lax.with_sharding_constraint(
        k, compat.named_sharding(mesh, P(dp, None, None, None)))
    v = jax.lax.with_sharding_constraint(
        v, compat.named_sharding(mesh, P(dp, None, None, None)))
    return q, k, v


def _maybe_expand_kv(ctx, cfg: ModelConfig, k, v):
    """Heads-sharded GQA with kv_heads not divisible by the model axis:
    expand K/V to Hq (h -> h // G map) so the head axis shards cleanly."""
    if ctx is None or ctx.par.attn_mode != "heads":
        return k, v
    mp = ctx.mesh.shape[ctx.par.model_axis]
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    if hkv % mp == 0 or hkv == hq:
        return k, v
    reps = hq // hkv
    return (jnp.repeat(k, reps, axis=2), jnp.repeat(v, reps, axis=2))


def attention_apply(p: PyTree, x: Array, cfg: ModelConfig, *,
                    positions: Array, causal: bool = True,
                    cache: Optional[dict] = None,
                    cache_len: Optional[Array] = None,
                    kv_source: Optional[Array] = None,
                    block_tables: Optional[Array] = None):
    """x [B, T, D] → (out [B, T, D], new_cache).

    * train/prefill: ``cache=None`` (prefill callers build the cache from the
      returned k/v — see ``serving``).
    * decode: ``cache={k,v}`` with static length S, ``cache_len`` giving the
      number of valid entries; the new token is written at ``cache_len``.
    * paged serving: ``block_tables`` [B, M] set — ``cache`` leaves are block
      *pools* ([P, Hkv, BS, D], shared by every sequence); this step's K/V
      are scattered through the table at ``cache_len`` and attention gathers
      pages (Pallas index maps, or a gather + chunked-XLA fallback).
    * ``kv_source``: cross-attention (whisper decoder) reads K/V from here.
    """
    b, t, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    src = x if kv_source is None else kv_source
    s_len = src.shape[1]
    q = (x @ p["wq"]).reshape(b, t, hq, hd)
    k = (src @ p["wk"]).reshape(b, s_len, hkv, hd)
    v = (src @ p["wv"]).reshape(b, s_len, hkv, hd)
    if kv_source is None:                      # self-attention: rotary
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    ctx = _shard_ctx()
    new_cache = None
    if cache is not None and block_tables is not None:
        # paged: scatter this step's K/V into the shared pool through the
        # block table, then attend over the gathered page list.  Single-host
        # (dispatch raises under an ambient ShardContext).
        if "k_scale" in cache:
            # quantized pool: int8 K/V pages + bf16 per-(pos, head) scale
            # pages share one block table; the gather step dequantizes
            # after the HBM read.  Prefill is single-shot (scheduler policy
            # from the family), so t > 1 attends over the exact fp tensors
            # of the whole prompt — identical math to the unpaged int8
            # prefill — while the quantized pages are written for decode.
            k8, ks = _quantize_kv(k)
            v8, vs = _quantize_kv(v)
            new_cache = {
                "k": paged_cache_write(cache["k"], k8, cache_len,
                                       block_tables),
                "v": paged_cache_write(cache["v"], v8, cache_len,
                                       block_tables),
                "k_scale": paged_cache_write(cache["k_scale"], ks, cache_len,
                                             block_tables),
                "v_scale": paged_cache_write(cache["v_scale"], vs, cache_len,
                                             block_tables)}
            valid = _valid_len(cache_len, t, b)
            if t > 1:
                out = _sdpa(cfg, q, k, v, causal=True, q_offset=cache_len,
                            kv_valid_len=valid)
            else:
                out = _sdpa(cfg, q, new_cache["k"], new_cache["v"],
                            causal=False, q_offset=cache_len,
                            kv_valid_len=valid, decode=True,
                            k_scale=new_cache["k_scale"],
                            v_scale=new_cache["v_scale"],
                            block_tables=block_tables)
        else:
            k_pool = paged_cache_write(cache["k"], k, cache_len, block_tables)
            v_pool = paged_cache_write(cache["v"], v, cache_len, block_tables)
            new_cache = {"k": k_pool, "v": v_pool}
            valid = _valid_len(cache_len, t, b)
            out = _sdpa(cfg, q, k_pool, v_pool, causal=t > 1,
                        q_offset=cache_len, kv_valid_len=valid,
                        decode=(t == 1), block_tables=block_tables)
    elif cache is not None and "k_scale" in cache:
        # the cache layout, not a config string, selects the quantized path
        # (layout construction lives in serving.cache_family)
        # quantized cache: store int8 + per-(pos, head) scales; decode
        # dequantizes per chunk AFTER the HBM read (1 byte/elem streamed)
        k8, ks = _quantize_kv(k)
        v8, vs = _quantize_kv(v)
        new_cache = {"k": cache_write(cache["k"], k8, cache_len),
                     "v": cache_write(cache["v"], v8, cache_len),
                     "k_scale": cache_write(cache["k_scale"], ks, cache_len),
                     "v_scale": cache_write(cache["v_scale"], vs, cache_len)}
        valid = _valid_len(cache_len, t, b)
        if t > 1:   # prefill computes on the exact fp tensors
            if ctx is not None and ctx.par.attn_mode == "sequence":
                q, k, v = _constrain_seq_parallel(ctx, q, k, v)
            else:
                k, v = _maybe_expand_kv(ctx, cfg, k, v)
            out = _sdpa(cfg, q, k, v, causal=True, q_offset=cache_len,
                        kv_valid_len=valid)
        else:
            out = _sdpa(cfg, q, new_cache["k"], new_cache["v"],
                        causal=False, q_offset=cache_len, kv_valid_len=valid,
                        decode=True, k_scale=new_cache["k_scale"],
                        v_scale=new_cache["v_scale"])
    elif cache is not None:
        # decode: append this step's k/v at cache_len (scalar: lockstep batch;
        # [B] vector: per-slot offsets), attend over the cache
        k_cache = cache_write(cache["k"], k, cache_len)
        v_cache = cache_write(cache["v"], v, cache_len)
        new_cache = {"k": k_cache, "v": v_cache}
        valid = _valid_len(cache_len, t, b)
        ka, va = k_cache, v_cache
        if t > 1:      # prefill: same compute sharding as the train path
            if ctx is not None and ctx.par.attn_mode == "sequence":
                q, ka, va = _constrain_seq_parallel(ctx, q, ka, va)
            else:
                ka, va = _maybe_expand_kv(ctx, cfg, ka, va)
        # t == 1 (decode): the valid-length mask alone implies causality.
        out = _sdpa(cfg, q, ka, va, causal=t > 1,
                    q_offset=cache_len, kv_valid_len=valid, decode=(t == 1))
    else:
        if ctx is not None and ctx.par.attn_mode == "sequence" and t > 1:
            q, k, v = _constrain_seq_parallel(ctx, q, k, v)
        else:
            k, v = _maybe_expand_kv(ctx, cfg, k, v)
        out = _sdpa(cfg, q, k, v, causal=causal, q_offset=0, kv_valid_len=None)
    out = out.reshape(b, t, hq * hd) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2).
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ModelConfig) -> PyTree:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "wdq": _dense_init(ks[0], (d, m.q_lora_rank), ("embed", None), dtype=dt),
        "q_norm": rms_norm_init(cfg, m.q_lora_rank),
        "wuq": _dense_init(ks[1], (m.q_lora_rank, h * qk), (None, "qkv_out"), dtype=dt),
        "wdkv": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            ("embed", None), dtype=dt),
        "kv_norm": rms_norm_init(cfg, m.kv_lora_rank),
        "wuk": _dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim),
                           (None, "qkv_out"), dtype=dt),
        "wuv": _dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim),
                           (None, "qkv_out"), dtype=dt),
        "wo": _dense_init(ks[5], (h * m.v_head_dim, d), ("qkv_out", "embed"), dtype=dt),
    }


def mla_apply(p: PyTree, x: Array, cfg: ModelConfig, *, positions: Array,
              cache: Optional[dict] = None, cache_len: Optional[Array] = None):
    """MLA attention.  Cache stores the COMPRESSED c_kv + shared rope key —
    the latent form that makes MLA's KV cache ~9x smaller; decode uses the
    absorbed-matmul trick so the cache is never decompressed."""
    m: MLAConfig = cfg.mla
    b, t, d = x.shape
    h = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = rms_norm(p["q_norm"], x @ p["wdq"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(b, t, h, qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["wdkv"]                                     # [B,T,Rkv+Dr]
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    shard_ctx = _shard_ctx()
    if cache is not None:
        c_cache = cache_write(cache["c_kv"], c_kv, cache_len)
        r_cache = cache_write(cache["k_rope"], k_rope, cache_len)
        new_cache = {"c_kv": c_cache, "k_rope": r_cache}
        # absorbed decode: q_eff = W_uk^T q_nope  ∈ R^{Rkv} per head
        wuk3 = p["wuk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        q_eff = jnp.einsum("bthk,rhk->bthr", q_nope, wuk3)
        # scores over latent cache: MQA-like (shared "key" = [c_kv, k_rope])
        q_cat = jnp.concatenate([q_eff, q_rope], axis=-1)       # [B,T,H,Rkv+Dr]
        k_cat = jnp.concatenate([c_cache, r_cache], axis=-1)    # [B,S,Rkv+Dr]
        valid = _valid_len(cache_len, t, b)
        kk = k_cat[:, :, None, :]
        vv = c_cache[:, :, None, :]
        if shard_ctx is not None and t > 1:
            q_cat, kk, vv = _constrain_seq_parallel(shard_ctx, q_cat, kk, vv)
        ctx = _sdpa(cfg, q_cat, kk, vv, causal=t > 1, q_offset=cache_len,
                    kv_valid_len=valid, scale=scale, decode=(t == 1))
        wuv3 = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bthr,rhk->bthk", ctx, wuv3)           # absorb W_uv
    else:
        new_cache = None
        k_nope = (c_kv @ p["wuk"]).reshape(b, t, h, m.qk_nope_head_dim)
        v = (c_kv @ p["wuv"]).reshape(b, t, h, m.v_head_dim)
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(k_rope[:, :, None, :],
                                              (b, t, h, m.qk_rope_head_dim))],
                            axis=-1)
        qc = jnp.concatenate([q_nope, q_rope], axis=-1)
        if shard_ctx is not None and shard_ctx.par.attn_mode == "sequence":
            qc, k, v = _constrain_seq_parallel(shard_ctx, qc, k, v)
        out = core.online_attention(qc, k, v, causal=True,
                                    chunk_size=cfg.attn_chunk, scale=scale)
    out_flat = out.reshape(b, t, h * m.v_head_dim)
    return out_flat @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU).
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> PyTree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense_init(ks[1], (d, f), ("embed", "ffn"), dtype=dt),
         "w_down": _dense_init(ks[2], (f, d), ("ffn", "embed"), dtype=dt)}
    if cfg.act == "silu":
        p["w_gate"] = _dense_init(ks[0], (d, f), ("embed", "ffn"), dtype=dt)
    return p


def mlp_apply(p: PyTree, x: Array, cfg: ModelConfig) -> Array:
    up = x @ p["w_up"]
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts.  Router = paper's Algorithm 4 (fused softmax+top-k over
# experts); capacity-bucketed one-hot dispatch (Mesh-TF style) so the
# collective pattern (all-to-all on [G, E, C, D]) is explicit in the HLO.
# ---------------------------------------------------------------------------
def moe_init(key, cfg: ModelConfig) -> PyTree:
    mc: MoEConfig = cfg.moe
    e = mc.pad_experts_to or mc.num_experts
    d, f = cfg.d_model, mc.d_ff_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, mc.num_experts), ("embed", None),
                              dtype=jnp.float32),
        "w_gate": _dense_init(ks[1], (e, d, f), ("expert", "embed", "expert_ffn"), dtype=dt),
        "w_up": _dense_init(ks[2], (e, d, f), ("expert", "embed", "expert_ffn"), dtype=dt),
        "w_down": _dense_init(ks[3], (e, f, d), ("expert", "expert_ffn", "embed"), dtype=dt),
    }
    if mc.d_ff_shared:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=mc.d_ff_shared)
    return p


def moe_apply(p: PyTree, x: Array, cfg: ModelConfig) -> tuple[Array, dict]:
    mc: MoEConfig = cfg.moe
    b, t, d = x.shape
    e_pad = mc.pad_experts_to or mc.num_experts
    k = mc.experts_per_token
    # ---- group tokens for capacity bucketing ------------------------------
    n = b * t
    s = min(mc.group_size, t)
    g = n // s
    xg = x.reshape(g, s, d)
    # ---- router: fused softmax+top-k (paper Alg. 4 at V = num_experts) ----
    from repro.kernels import dispatch
    logits = (xg.astype(jnp.float32) @ p["router"])          # [G,S,E]
    # the router sits under value_and_grad in training; the Pallas kernel's
    # custom VJP (recompute-from-LSE) makes the registry's own backend choice
    # safe here — no XLA pin
    probs, idx, lse = dispatch.softmax_topk(logits, k)       # [G,S,K]
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    cap = int(math.ceil(s * k * mc.capacity_factor / mc.num_experts))
    cap = max(cap, 4)
    # ---- capacity assignment ----------------------------------------------
    em = jax.nn.one_hot(idx, e_pad, dtype=jnp.float32)       # [G,S,K,E]
    em_flat = em.transpose(0, 1, 2, 3).reshape(g, s * k, e_pad)
    pos = jnp.cumsum(em_flat, axis=1) * em_flat - 1.0        # [G,S*K,E]
    keep = (pos >= 0) & (pos < cap)
    disp_sk = jax.nn.one_hot(pos.clip(0), cap, dtype=jnp.float32) \
        * keep[..., None] * em_flat[..., None]               # [G,S*K,E,C]
    disp = disp_sk.reshape(g, s, k, e_pad, cap)
    combine = jnp.einsum("gske,gskec->gsec",
                         em * probs[..., None], disp)        # [G,S,E,C]
    dispatch = disp.sum(axis=2)                              # [G,S,E,C] 0/1
    # ---- expert computation ------------------------------------------------
    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    hg = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    hu = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    he = jax.nn.silu(hg) * hu
    ye = jnp.einsum("gecf,efd->gecd", he, p["w_down"])
    y = jnp.einsum("gecd,gsec->gsd", ye, combine.astype(x.dtype))
    y = y.reshape(b, t, d)
    # ---- aux losses ---------------------------------------------------------
    me = jnp.mean(em.sum(2), axis=1)                          # fraction routed
    pe = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=1)
    pe = jnp.pad(pe, ((0, 0), (0, e_pad - mc.num_experts)))
    lb_loss = mc.num_experts * jnp.mean(jnp.sum(me * pe, axis=-1))
    z_loss = mc.router_z_loss * jnp.mean(jnp.square(lse))
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


# ---------------------------------------------------------------------------
# Embedding / LM head.
# ---------------------------------------------------------------------------
def embedding_init(key, cfg: ModelConfig) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    p = {"embed": _dense_init(key, (cfg.vocab_size, cfg.d_model),
                              ("vocab", "embed"), scale=1.0, dtype=dt)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(jax.random.fold_in(key, 1),
                                (cfg.d_model, cfg.vocab_size),
                                ("embed", "vocab"), dtype=dt)
    return p


def embed_tokens(p: PyTree, tokens: Array) -> Array:
    return jnp.take(p["embed"], tokens, axis=0)


def head_matrix(p: PyTree, cfg: ModelConfig) -> Array:
    return p["embed"].T if cfg.tie_embeddings else p["head"]
