"""Mamba2 (SSD) block — chunked-parallel training form + O(1) decode step.

The SSD recurrence  S_t = a_t·S_{t−1} + Δ_t·B_t x_tᵀ,  y_t = C_tᵀS_t + D·x_t
is evaluated chunkwise: intra-chunk pairs via a masked [L, L] score matrix
(MXU-friendly), inter-chunk via a scan over per-chunk states.  Structurally
this is the same single-pass carry pattern as the paper's online softmax —
a running statistic ⊕-updated per tile — with exp-decay weights instead of
exp-normalized ones (DESIGN.md §5).

Shapes: x [B, T, H, P]; B, C [B, T, N] (single group); Δ [B, T, H]; A, D [H].
Sharding: d_inner ("inner" = H·P) over the model axis; B/C/N replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import Param, _dense_init, _ones, rms_norm

Array = jax.Array


def ssd_chunked(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                d_skip: Array, *, chunk: int,
                init_state: Optional[Array] = None):
    """Chunked SSD scan.

    x [B,T,H,P]; dt [B,T,H] (>0); a_log [H] (A = −exp(a_log));
    b, c [B,T,N]; d_skip [H].  Returns (y [B,T,H,P], final_state [B,H,N,P]).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    l = min(chunk, t)
    assert t % l == 0, (t, l)
    nc = t // l
    f32 = jnp.float32

    # [nc, B, L, ...] chunk-major for the scan
    xc = jnp.moveaxis(x.reshape(bsz, nc, l, h, p), 1, 0).astype(f32)
    dtc = jnp.moveaxis(dt.reshape(bsz, nc, l, h), 1, 0).astype(f32)
    bc = jnp.moveaxis(b.reshape(bsz, nc, l, n), 1, 0).astype(f32)
    cc = jnp.moveaxis(c.reshape(bsz, nc, l, n), 1, 0).astype(f32)
    a = -jnp.exp(a_log.astype(f32))                          # [H] < 0
    mask = jnp.tril(jnp.ones((l, l), bool))
    s0 = (jnp.zeros((bsz, h, n, p), f32) if init_state is None
          else init_state.astype(f32))

    def step(s_in, inputs):
        """One chunk: intra (masked decay scores) + inter (carried state).
        Transients are [B,L,L,H] — chunk-local, recomputed in the bwd pass."""
        xk, dtk, bk, ck = inputs                             # [B,L,...]
        la = jnp.cumsum(dtk * a, axis=1)                     # [B,L,H] inclusive
        # M[i,j] = (C_i·B_j)·exp(la_i − la_j)·Δ_j, j ≤ i
        scores = jnp.einsum("bin,bjn->bij", ck, bk)
        decay = la[:, :, None, :] - la[:, None, :, :]        # [B,L,L,H]
        w = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        y_intra = jnp.einsum("bij,bijh,bjh,bjhp->bihp",
                             scores, w, dtk, xk)
        # inter: contribution of the entering state
        y_inter = jnp.einsum("bin,bih,bhnp->bihp", ck, jnp.exp(la), s_in)
        y_k = y_intra + y_inter + d_skip[None, None, :, None] * xk
        # boundary state update
        w_end = jnp.exp(la[:, -1:, :] - la)                  # [B,L,H]
        sc_k = jnp.einsum("bjn,bjh,bjhp->bhnp", bk, w_end * dtk, xk)
        gamma = jnp.exp(la[:, -1, :])                        # [B,H]
        s_out = gamma[..., None, None] * s_in + sc_k
        return s_out, y_k

    step = jax.checkpoint(step)   # recompute chunk transients in backward
    s_final, y = jax.lax.scan(step, s0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(y, 0, 1).reshape(bsz, t, h, p).astype(x.dtype)
    return y, s_final


def ssd_decode_step(state: Array, x: Array, dt: Array, a_log: Array,
                    b: Array, c: Array, d_skip: Array):
    """One-token SSD update.  state [B,H,N,P]; x [B,H,P]; dt [B,H]; b,c [B,N]."""
    f32 = jnp.float32
    a = -jnp.exp(a_log.astype(f32))
    decay = jnp.exp(dt.astype(f32) * a)                      # [B,H]
    upd = jnp.einsum("bn,bh,bhp->bhnp", b.astype(f32),
                     dt.astype(f32), x.astype(f32))
    new_state = decay[..., None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", c.astype(f32), new_state) \
        + d_skip[None, :, None] * x.astype(f32)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv (width w) via shift-adds — sharding-friendly.
# ---------------------------------------------------------------------------
def causal_conv(x: Array, w: Array, state: Optional[Array] = None):
    """x [B,T,C]; w [C, width].  Returns (y [B,T,C], new_state [B,width−1,C])."""
    width = w.shape[-1]
    w = w.astype(x.dtype)   # keep activation dtype (no f32 promotion)
    if state is not None:
        x_ext = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    t = x.shape[1]
    y = sum(x_ext[:, i:i + t] * w[None, None, :, width - 1 - i]
            for i in range(width))
    new_state = x_ext[:, -(width - 1):] if width > 1 else None
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 block.
# ---------------------------------------------------------------------------
def mamba2_init(key, cfg: ModelConfig) -> dict:
    sc: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner = sc.expand * d
    h = d_inner // sc.head_dim
    n = sc.d_state
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    import numpy as np
    dt_bias = jnp.asarray(
        np.log(np.expm1(np.exp(np.linspace(np.log(sc.dt_min),
                                           np.log(sc.dt_max), h)))),
        jnp.float32)
    return {
        "w_zx": _dense_init(ks[0], (d, 2 * d_inner), ("embed", "inner"), dtype=dt),
        "w_bc": _dense_init(ks[1], (d, 2 * n), ("embed", None), dtype=dt),
        "w_dt": _dense_init(ks[2], (d, h), ("embed", "inner_heads"), dtype=dt),
        "dt_bias": Param(dt_bias, ("inner_heads",)),
        "a_log": Param(jnp.zeros((h,), jnp.float32), ("inner_heads",)),
        "d_skip": _ones((h,), ("inner_heads",)),
        "conv_x": _dense_init(ks[3], (d_inner, sc.d_conv), ("inner", None),
                              scale=0.5, dtype=jnp.float32),
        "conv_b": _dense_init(ks[4], (n, sc.d_conv), (None, None),
                              scale=0.5, dtype=jnp.float32),
        "conv_c": _dense_init(ks[5], (n, sc.d_conv), (None, None),
                              scale=0.5, dtype=jnp.float32),
        "norm": {"scale": _ones((d_inner,), ("inner",))},
        "w_out": _dense_init(ks[6], (d_inner, d), ("inner", "embed"), dtype=dt),
    }


def mamba2_apply(p: dict, x: Array, cfg: ModelConfig, *,
                 cache: Optional[dict] = None):
    """x [B,T,D] → (y [B,T,D], new_cache).

    cache = {"ssm": [B,H,N,P], "conv_x": [B,w−1,inner], "conv_b", "conv_c"}.
    ``cache is not None`` and T == 1 → decode step.
    """
    sc: SSMConfig = cfg.ssm
    bsz, t, d = x.shape
    d_inner = sc.expand * d
    h = d_inner // sc.head_dim
    n = sc.d_state

    zx = x @ p["w_zx"]
    z, xin = jnp.split(zx, 2, axis=-1)
    bcr = x @ p["w_bc"]
    dt_raw = x @ p["w_dt"]
    dt_act = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if cache is None or t > 1:
        xc, st_x = causal_conv(xin, p["conv_x"])
        bc_b, st_b = causal_conv(bcr[..., :n], p["conv_b"])
        bc_c, st_c = causal_conv(bcr[..., n:], p["conv_c"])
        xc = jax.nn.silu(xc)
        bc_b = jax.nn.silu(bc_b)
        bc_c = jax.nn.silu(bc_c)
        xh = xc.reshape(bsz, t, h, sc.head_dim)
        y, s_final = ssd_chunked(xh, dt_act, p["a_log"], bc_b, bc_c,
                                 p["d_skip"], chunk=sc.chunk)
        y = y.reshape(bsz, t, d_inner)
        new_cache = {"ssm": s_final, "conv_x": st_x, "conv_b": st_b,
                     "conv_c": st_c}
    else:
        # --- decode: O(1) state update --------------------------------------
        xc1, st_x = causal_conv(xin, p["conv_x"], state=cache["conv_x"])
        b1, st_b = causal_conv(bcr[..., :n], p["conv_b"], state=cache["conv_b"])
        c1, st_c = causal_conv(bcr[..., n:], p["conv_c"], state=cache["conv_c"])
        xc1 = jax.nn.silu(xc1)[:, 0]
        b1 = jax.nn.silu(b1)[:, 0]
        c1 = jax.nn.silu(c1)[:, 0]
        xh = xc1.reshape(bsz, h, sc.head_dim)
        y1, s_new = ssd_decode_step(cache["ssm"], xh, dt_act[:, 0],
                                    p["a_log"], b1, c1, p["d_skip"])
        y = y1.reshape(bsz, 1, d_inner)
        new_cache = {"ssm": s_new, "conv_x": st_x, "conv_b": st_b,
                     "conv_c": st_c}

    y = y * jax.nn.silu(z)
    y = rms_norm(p["norm"], y, cfg.norm_eps)
    return (y @ p["w_out"]).astype(x.dtype), new_cache


def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    sc: SSMConfig = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    h = d_inner // sc.head_dim
    return {
        "ssm": jnp.zeros((batch, h, sc.d_state, sc.head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, sc.d_conv - 1, d_inner), dtype),
        "conv_b": jnp.zeros((batch, sc.d_conv - 1, sc.d_state), dtype),
        "conv_c": jnp.zeros((batch, sc.d_conv - 1, sc.d_state), dtype),
    }
