"""xLSTM blocks: chunked-parallel mLSTM + sequential sLSTM.

The mLSTM's exponential gating needs the running stabilizer
``m_t = max(log f_t + m_{t−1}, ĩ_t)`` — *the same online-max recurrence as the
paper's Algorithm 3* (m plays the role of the running max; C and n are the
rescaled running statistics, exactly like d).  The chunked form below carries
``(m, C, n)`` across chunks and ⊕-rescales them by ``exp(m_old − m_new)``,
i.e., FlashAttention-with-decay.  This connection is why the arch is assigned
to this paper (DESIGN.md §5).

sLSTM has hidden-state feedback through its recurrent weights, so it is
inherently sequential — a ``lax.scan`` over time (cheap scalar states).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.models.layers import _dense_init, _ones, _zeros, rms_norm
from repro.models.ssm import causal_conv

Array = jax.Array
NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# mLSTM core — chunked parallel form with online-max stabilizer.
# ---------------------------------------------------------------------------
def mlstm_chunked(q: Array, k: Array, v: Array, i_gate: Array, f_gate: Array,
                  *, chunk: int, init: Optional[tuple] = None):
    """q,k,v [B,T,H,D]; i_gate,f_gate [B,T,H] (pre-activation logits).

    Returns (h [B,T,H,D], (m, C, n) final state).
    k is expected pre-scaled by 1/sqrt(D).
    """
    bsz, t, h, dh = q.shape
    l = min(chunk, t)
    assert t % l == 0
    nc = t // l
    f32 = jnp.float32

    def tochunks(x):
        return jnp.moveaxis(
            x.reshape(bsz, nc, l, *x.shape[2:]), 1, 0).astype(f32)

    qc, kc, vc = tochunks(q), tochunks(k), tochunks(v)
    ic, fc = tochunks(i_gate), tochunks(f_gate)
    mask = jnp.tril(jnp.ones((l, l), bool))

    if init is None:
        m0 = jnp.full((bsz, h), NEG_INF, f32)
        c0 = jnp.zeros((bsz, h, dh, dh), f32)
        n0 = jnp.zeros((bsz, h, dh), f32)
    else:
        m0, c0, n0 = [x.astype(f32) for x in init]

    def step(carry, inputs):
        m_run, c_run, n_run = carry
        qk_, kk_, vk_, ik_, fk_ = inputs                     # [B,L,H,*]
        logf = -jax.nn.softplus(-fk_)                        # log sigmoid
        la = jnp.cumsum(logf, axis=1)                        # [B,L,H] inclusive
        # intra log-weights W[i,j] = la_i − la_j + ĩ_j  (j ≤ i)
        w = la[:, :, None, :] - la[:, None, :, :] + ik_[:, None, :, :]
        w = jnp.where(mask[None, :, :, None], w, NEG_INF)    # [B,L,L,H]
        m_intra = jnp.max(w, axis=2)                         # [B,L,H]
        m_inter = la + m_run[:, None, :]                     # decayed carry max
        m_i = jnp.maximum(m_intra, m_inter)                  # online max (⊕)
        p = jnp.exp(w - m_i[:, :, None, :])                  # [B,L,L,H]
        s = jnp.einsum("bihd,bjhd->bijh", qk_, kk_)          # scores
        inter_scale = jnp.exp(m_inter - m_i)                 # [B,L,H]
        h_num = jnp.einsum("bijh,bjhd->bihd", p * s, vk_) + \
            inter_scale[..., None] * jnp.einsum("bihd,bhde->bihe", qk_, c_run)
        # denominator: q·n accumulated with the same weights
        qn_intra = jnp.einsum("bijh,bijh->bih", p, s)
        qn_inter = inter_scale * jnp.einsum("bihd,bhd->bih", qk_, n_run)
        qn = qn_intra + qn_inter
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_i))
        h_out = h_num / denom[..., None]
        # ---- carry update (boundary ⊕ rescale) ----------------------------
        la_end = la[:, -1, :]                                # [B,H]
        m_bnd = jnp.max(la_end[:, None, :] - la + ik_, axis=1)  # chunk part
        m_new = jnp.maximum(la_end + m_run, m_bnd)
        wb = jnp.exp(la_end[:, None, :] - la + ik_ - m_new[:, None, :])
        c_new = (jnp.exp(la_end + m_run - m_new)[:, :, None, None] * c_run
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", wb, kk_, vk_))
        n_new = (jnp.exp(la_end + m_run - m_new)[:, :, None] * n_run
                 + jnp.einsum("bjh,bjhd->bhd", wb, kk_))
        return (m_new, c_new, n_new), h_out

    step = jax.checkpoint(step)
    (m_f, c_f, n_f), hs = jax.lax.scan(step, (m0, c0, n0), (qc, kc, vc, ic, fc))
    h_full = jnp.moveaxis(hs, 0, 1).reshape(bsz, t, h, dh)
    return h_full.astype(q.dtype), (m_f, c_f, n_f)


def mlstm_decode_step(state: tuple, q: Array, k: Array, v: Array,
                      i_gate: Array, f_gate: Array):
    """Sequential stabilized mLSTM step. q,k,v [B,H,D]; gates [B,H]."""
    m, c, n = state
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    logf = -jax.nn.softplus(-f_gate.astype(f32))
    m_new = jnp.maximum(logf + m, i_gate.astype(f32))
    f_sc = jnp.exp(logf + m - m_new)
    i_sc = jnp.exp(i_gate.astype(f32) - m_new)
    c_new = f_sc[..., None, None] * c + i_sc[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = f_sc[..., None] * n + i_sc[..., None] * k
    qn = jnp.einsum("bhd,bhd->bh", q, n_new)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h = jnp.einsum("bhd,bhde->bhe", q, c_new) / denom[..., None]
    return h, (m_new, c_new, n_new)


# ---------------------------------------------------------------------------
# sLSTM core — sequential scan (hidden-state feedback).
# ---------------------------------------------------------------------------
def slstm_scan(gates_x: Array, r_weights: Array, *, num_heads: int,
               init: Optional[tuple] = None):
    """gates_x [B,T,4,Dm]: pre-computed W·x_t for (i, f, z, o).
    r_weights [4, H, Dh, Dh]: per-head recurrent matrices on h_{t−1}.
    Returns (h [B,T,Dm], (c, n, m, h_prev) final)."""
    bsz, t, _, dm = gates_x.shape
    hh = num_heads
    dh = dm // hh
    f32 = jnp.float32

    if init is None:
        c0 = jnp.zeros((bsz, dm), f32)
        n0 = jnp.ones((bsz, dm), f32)
        m0 = jnp.zeros((bsz, dm), f32)
        h0 = jnp.zeros((bsz, dm), f32)
    else:
        c0, n0, m0, h0 = [x.astype(f32) for x in init]

    def step(carry, gx):
        c, n, m, h_prev = carry
        hp = h_prev.reshape(bsz, hh, dh)
        rec = jnp.einsum("bhd,ghde->gbhe", hp, r_weights).reshape(4, bsz, dm)
        gi, gf, gz, go = gx[:, 0] + rec[0], gx[:, 1] + rec[1], \
            gx[:, 2] + rec[2], gx[:, 3] + rec[3]
        logf = -jax.nn.softplus(-gf)                     # sigmoid forget (log)
        m_new = jnp.maximum(logf + m, gi)                # online-max stabilizer
        i_sc = jnp.exp(gi - m_new)
        f_sc = jnp.exp(logf + m - m_new)
        z = jnp.tanh(gz)
        c_new = f_sc * c + i_sc * z
        n_new = jnp.maximum(f_sc * n + i_sc, 1e-6)
        h_new = jax.nn.sigmoid(go) * (c_new / n_new)
        return (c_new, n_new, m_new, h_new), h_new

    carry, hs = jax.lax.scan(step, (c0, n0, m0, h0),
                             jnp.moveaxis(gates_x.astype(f32), 1, 0))
    return jnp.moveaxis(hs, 0, 1), carry


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------
def mlstm_block_init(key, cfg: ModelConfig) -> dict:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    inner = xc.expand * d
    hh = xc.num_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "norm": {"scale": _ones((d,), ("embed",))},
        "w_up": _dense_init(ks[0], (d, 2 * inner), ("embed", "inner"), dtype=dt),
        "conv": _dense_init(ks[1], (inner, xc.conv_width), ("inner", None),
                            scale=0.5, dtype=jnp.float32),
        "wq": _dense_init(ks[2], (inner, inner), ("inner", None), dtype=dt),
        "wk": _dense_init(ks[3], (inner, inner), ("inner", None), dtype=dt),
        "wv": _dense_init(ks[4], (inner, inner), ("inner", None), dtype=dt),
        "w_if": _dense_init(ks[5], (inner, 2 * hh), ("inner", None),
                            dtype=jnp.float32),
        "if_bias": _zeros((2 * hh,), (None,)),
        "hnorm": {"scale": _ones((inner,), ("inner",))},
        "w_down": _dense_init(ks[6], (inner, d), ("inner", "embed"), dtype=dt),
    }


def mlstm_block_apply(p: dict, x: Array, cfg: ModelConfig, *,
                      cache: Optional[dict] = None):
    xc: XLSTMConfig = cfg.xlstm
    bsz, t, d = x.shape
    inner = xc.expand * d
    hh = xc.num_heads
    dh = inner // hh
    resid = x
    x = rms_norm(p["norm"], x, cfg.norm_eps)
    up = x @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = None if cache is None else cache["conv"]
    xcv, new_conv = causal_conv(xm, p["conv"], state=conv_state)
    xcv = jax.nn.silu(xcv)
    q = (xcv @ p["wq"]).reshape(bsz, t, hh, dh)
    k = (xcv @ p["wk"]).reshape(bsz, t, hh, dh) * (dh ** -0.5)
    v = (xm @ p["wv"]).reshape(bsz, t, hh, dh)
    gifs = (xcv @ p["w_if"]).astype(jnp.float32) + p["if_bias"]
    gi, gf = jnp.split(gifs, 2, axis=-1)                    # [B,T,H]

    if cache is not None and t == 1:
        h, new_state = mlstm_decode_step(
            cache["mlstm"], q[:, 0], k[:, 0], v[:, 0], gi[:, 0], gf[:, 0])
        h = h[:, None]
    else:
        init = None if cache is None else cache["mlstm"]
        h, new_state = mlstm_chunked(q, k, v, gi, gf, chunk=xc.chunk,
                                     init=init)
    h = h.reshape(bsz, t, inner).astype(x.dtype)
    h = rms_norm(p["hnorm"], h, cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    new_cache = {"mlstm": new_state, "conv": new_conv}
    return resid + out, new_cache


def slstm_block_init(key, cfg: ModelConfig) -> dict:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    hh = xc.num_heads
    dh = d // hh
    f_up = int(d * 4 / 3 / 64) * 64 or 64
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "norm": {"scale": _ones((d,), ("embed",))},
        "w_gates": _dense_init(ks[0], (d, 4, d), ("embed", None, None),
                               dtype=jnp.float32),
        "r_weights": _dense_init(ks[1], (4, hh, dh, dh), (None, None, None, None),
                                 scale=1.0 / (dh ** 0.5), dtype=jnp.float32),
        "hnorm": {"scale": _ones((d,), ("embed",))},
        "w_up1": _dense_init(ks[2], (d, f_up), ("embed", "ffn"), dtype=dt),
        "w_up2": _dense_init(ks[3], (d, f_up), ("embed", "ffn"), dtype=dt),
        "w_down": _dense_init(ks[4], (f_up, d), ("ffn", "embed"), dtype=dt),
    }


def slstm_block_apply(p: dict, x: Array, cfg: ModelConfig, *,
                      cache: Optional[dict] = None):
    xc: XLSTMConfig = cfg.xlstm
    bsz, t, d = x.shape
    resid = x
    xn = rms_norm(p["norm"], x, cfg.norm_eps)
    gates_x = jnp.einsum("btd,dge->btge", xn.astype(jnp.float32),
                         p["w_gates"])
    init = None if cache is None else cache["slstm"]
    h, new_state = slstm_scan(gates_x, p["r_weights"],
                              num_heads=xc.num_heads, init=init)
    h = rms_norm(p["hnorm"], h.astype(x.dtype), cfg.norm_eps)
    y = (jax.nn.gelu(h @ p["w_up1"]) * (h @ p["w_up2"])) @ p["w_down"]
    return resid + y, {"slstm": new_state}


def xlstm_cache_init(cfg: ModelConfig, layer_idx: int, batch: int, dtype):
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    if layer_idx % xc.slstm_every == xc.slstm_every - 1:
        return {"slstm": (jnp.zeros((batch, d), jnp.float32),
                          jnp.ones((batch, d), jnp.float32),
                          jnp.zeros((batch, d), jnp.float32),
                          jnp.zeros((batch, d), jnp.float32))}
    inner = xc.expand * d
    hh = xc.num_heads
    dh = inner // hh
    return {
        "mlstm": (jnp.full((batch, hh), float("-inf"), jnp.float32),
                  jnp.zeros((batch, hh, dh, dh), jnp.float32),
                  jnp.zeros((batch, hh, dh), jnp.float32)),
        "conv": jnp.zeros((batch, xc.conv_width - 1, inner), dtype),
    }
