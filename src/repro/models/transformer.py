"""Decoder-only LM covering all assigned families via a segment/block system.

A model is a sequence of *segments*; each segment is ``count`` copies of one
block kind with params stacked on a leading layer axis and executed with
``lax.scan`` (HLO stays O(1 block), which keeps 512-device compiles cheap and
gives remat a uniform cut point).  Heterogeneous stacks (xLSTM's
mLSTM/sLSTM mix, Zamba2's mamba-with-shared-attention) are just multiple
segments; Zamba2's shared transformer block has its params stored ONCE at the
top level and is invoked between segments (weight sharing, per the arch).

Block kinds:
  dense   — [norm→GQA attn] + [norm→MLP]
  mla     — [norm→MLA attn] + [norm→MLP]
  moe     — [norm→GQA attn] + [norm→MoE]
  mamba   — [norm→Mamba2]
  mlstm / slstm — xLSTM blocks (own norms/residuals)

The LM head loss uses chunked online cross-entropy (paper §7 fusion) and
decode sampling uses fused softmax+top-k (paper §4).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import core
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm, xlstm

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Segment pattern per family.
# ---------------------------------------------------------------------------
def block_pattern(cfg: ModelConfig) -> list[tuple[str, int]]:
    if cfg.family in ("dense", "vlm"):
        return [("dense", cfg.num_layers)]
    if cfg.family == "mla":
        return [("mla", cfg.num_layers)]
    if cfg.family == "moe":
        return [("moe", cfg.num_layers)]
    if cfg.family == "ssm":        # xLSTM: sLSTM every `slstm_every` layers
        ev = cfg.xlstm.slstm_every
        segs: list[tuple[str, int]] = []
        run = 0
        for i in range(cfg.num_layers):
            if i % ev == ev - 1:
                if run:
                    segs.append(("mlstm", run))
                    run = 0
                segs.append(("slstm", 1))
            else:
                run += 1
        if run:
            segs.append(("mlstm", run))
        return segs
    if cfg.family == "hybrid":     # Zamba2: shared attn block every N mamba
        ev = cfg.hybrid_attn_every
        segs = []
        remaining = cfg.num_layers
        while remaining > 0:
            n = min(ev, remaining)
            segs.append(("mamba", n))
            remaining -= n
            if remaining > 0 or True:   # shared block also closes the stack
                segs.append(("shared_attn", 1))
        return segs
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Per-block init/apply.
# ---------------------------------------------------------------------------
def _norm_init(cfg: ModelConfig):
    return (L.layer_norm_init(cfg) if cfg.norm_type == "layernorm"
            else L.rms_norm_init(cfg))


def _norm(cfg: ModelConfig, p, x):
    return (L.layer_norm(p, x, cfg.norm_eps) if cfg.norm_type == "layernorm"
            else L.rms_norm(p, x, cfg.norm_eps))


def _dense_block_init(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg), "attn": L.attention_init(k1, cfg),
            "ln2": _norm_init(cfg), "mlp": L.mlp_init(k2, cfg)}


def _mla_block_init(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg), "attn": L.mla_init(k1, cfg),
            "ln2": _norm_init(cfg), "mlp": L.mlp_init(k2, cfg)}


def _moe_block_init(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg), "attn": L.attention_init(k1, cfg),
            "ln2": _norm_init(cfg), "moe": L.moe_init(k2, cfg)}


def _mamba_block_init(key, cfg: ModelConfig) -> PyTree:
    return {"ln": _norm_init(cfg), "mamba": ssm.mamba2_init(key, cfg)}


BLOCK_INIT = {
    "dense": _dense_block_init,
    "mla": _mla_block_init,
    "moe": _moe_block_init,
    "mamba": _mamba_block_init,
    "mlstm": xlstm.mlstm_block_init,
    "slstm": xlstm.slstm_block_init,
    "shared_attn": _dense_block_init,
}


def block_apply(kind: str, p: PyTree, x: Array, cfg: ModelConfig, *,
                positions: Array, cache: Optional[PyTree] = None,
                cache_len: Optional[Array] = None,
                block_tables: Optional[Array] = None):
    """Returns (x_out, new_cache, aux-losses dict).

    ``block_tables`` (paged KV serving) is only meaningful for standard
    attention caches; MLA/SSM/xLSTM block kinds reject it loudly rather than
    silently ignoring the paging request."""
    aux: dict = {}
    if block_tables is not None and kind not in ("dense", "moe",
                                                 "shared_attn"):
        raise ValueError(f"paged KV cache serves standard attention blocks "
                         f"only (got {kind!r})")
    if kind in ("dense", "moe", "mla", "shared_attn"):
        h = _norm(cfg, p["ln1"], x)
        attn_cache = None if cache is None else cache["attn"]
        if kind == "mla":
            a, new_attn_cache = L.mla_apply(p["attn"], h, cfg,
                                            positions=positions,
                                            cache=attn_cache,
                                            cache_len=cache_len)
        else:
            a, new_attn_cache = L.attention_apply(p["attn"], h, cfg,
                                                  positions=positions,
                                                  cache=attn_cache,
                                                  cache_len=cache_len,
                                                  block_tables=block_tables)
        x = x + a
        h = _norm(cfg, p["ln2"], x)
        if kind == "moe":
            m, aux = L.moe_apply(p["moe"], h, cfg)
        else:
            m = L.mlp_apply(p["mlp"], h, cfg)
        x = x + m
        new_cache = None if new_attn_cache is None else {"attn": new_attn_cache}
        return x, new_cache, aux
    if kind == "mamba":
        h = _norm(cfg, p["ln"], x)
        y, new_cache = ssm.mamba2_apply(p["mamba"], h, cfg, cache=cache)
        return x + y, new_cache, aux
    if kind == "mlstm":
        y, new_cache = xlstm.mlstm_block_apply(p, x, cfg, cache=cache)
        return y, new_cache, aux
    if kind == "slstm":
        y, new_cache = xlstm.slstm_block_apply(p, x, cfg, cache=cache)
        return y, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init.
# ---------------------------------------------------------------------------
def init(key, cfg: ModelConfig) -> PyTree:
    """Returns a BOXED param tree (repro.models.layers.Param leaves)."""
    segs = block_pattern(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params: dict = {"embedding": L.embedding_init(keys[0], cfg),
                    "final_norm": _norm_init(cfg), "segments": []}
    if cfg.pos_embedding == "learned":
        params["pos_embed"] = L._dense_init(
            keys[1], (cfg.max_seq_len, cfg.d_model), (None, "embed"),
            scale=0.02, dtype=jnp.dtype(cfg.dtype))
    if cfg.num_patches:
        params["mm_proj"] = L._dense_init(
            keys[2], (cfg.d_model, cfg.d_model), ("embed", None),
            dtype=jnp.dtype(cfg.dtype))
    shared_done = False
    for si, (kind, count) in enumerate(segs):
        if kind == "shared_attn":
            if not shared_done:
                params["shared_attn"] = BLOCK_INIT[kind](keys[si + 3], cfg)
                shared_done = True
            params["segments"].append({})          # placeholder, uses shared
            continue
        stacked = L.stack_layer_init(
            lambda k, kind=kind: BLOCK_INIT[kind](k, cfg), keys[si + 3], count)
        params["segments"].append(stacked)
    return params


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------
def _maybe_remat(cfg: ModelConfig, fn, *, inference: bool = False):
    if cfg.remat == "none" or inference:
        # remat exists for the backward pass; on cached/serving forwards it
        # only inserts convert/copy round-trips of the whole cache stack.
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def forward(params: PyTree, tokens: Array, cfg: ModelConfig, *,
            patch_embeds: Optional[Array] = None,
            caches: Optional[list] = None,
            cache_len: Optional[Array] = None,
            block_tables: Optional[Array] = None):
    """tokens [B, T] → (hidden [B, T', D], new_caches).

    VLM: ``patch_embeds [B, P, D]`` are projected and prepended; T' = P + T.
    ``block_tables`` [B, M]: paged KV serving — ``caches`` hold block *pools*
    (no batch axis; see ``serving.engine.init_paged_cache``) and every
    attention layer reads/writes through the table.
    """
    x = L.embed_tokens(params["embedding"], tokens)
    if cfg.num_patches and patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["mm_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    b, t, _ = x.shape
    base = jnp.asarray(cache_len if cache_len is not None else 0, jnp.int32)
    # scalar base → positions [T]; per-sequence base [B] (continuous-batching
    # slots at ragged lengths) → positions [B, T]; rope broadcasts either.
    positions = base[..., None] + jnp.arange(t, dtype=jnp.int32)
    if cfg.pos_embedding == "learned":
        x = x + jnp.take(params["pos_embed"], positions, axis=0)

    segs = block_pattern(cfg)
    new_caches: list = []
    aux_total: dict = {}
    for si, (kind, count) in enumerate(segs):
        if kind == "shared_attn":
            cache = None if caches is None else caches[si]
            step = _maybe_remat(
                cfg, functools.partial(block_apply, "shared_attn", cfg=cfg,
                                       positions=positions,
                                       cache_len=cache_len,
                                       block_tables=block_tables),
                inference=caches is not None)
            x, nc, _ = step(params["shared_attn"], x, cache=cache)
            new_caches.append(nc)
            continue
        seg_params = params["segments"][si]
        seg_cache = None if caches is None else caches[si]

        def body(x, layer_in, kind=kind):
            p_i, cache_i = layer_in
            out, nc, aux = block_apply(kind, p_i, x, cfg,
                                       positions=positions, cache=cache_i,
                                       cache_len=cache_len,
                                       block_tables=block_tables)
            return out, (nc, aux)

        body = _maybe_remat(cfg, body, inference=caches is not None)
        x, (nc_stack, aux_stack) = jax.lax.scan(
            body, x, (seg_params, seg_cache))
        new_caches.append(nc_stack)
        for k, v in (aux_stack or {}).items():
            aux_total[k] = aux_total.get(k, 0.0) + jnp.sum(v)
    x = _norm(cfg, params["final_norm"], x)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Training loss (chunked online CE) and decode logits.
# ---------------------------------------------------------------------------
def loss_fn(params: PyTree, batch: dict, cfg: ModelConfig):
    """batch: tokens [B,T], labels [B,T] (−1 = masked).  Mean CE + aux."""
    hidden, _, aux = forward(params, batch["tokens"], cfg,
                             patch_embeds=batch.get("patch_embeds"))
    if cfg.num_patches and "patch_embeds" in batch:
        hidden = hidden[:, cfg.num_patches:]       # loss on text positions
    b, t, d = hidden.shape
    labels = batch["labels"].reshape(-1)
    w = L.head_matrix(params["embedding"], cfg)
    h2 = hidden.reshape(-1, d)
    valid = labels >= 0
    safe_labels = jnp.where(valid, labels, 0)
    if cfg.use_chunked_ce:
        tok_loss = core.chunked_cross_entropy(h2, w, safe_labels,
                                              num_chunks=cfg.vocab_chunks)
    else:
        tok_loss = core.full_cross_entropy(h2, w, safe_labels)
    denom = jnp.maximum(valid.sum(), 1)
    loss = jnp.sum(tok_loss * valid) / denom
    metrics = {"ce_loss": loss, **{k: v for k, v in aux.items()}}
    for v in aux.values():
        loss = loss + v / max(cfg.num_layers, 1)
    metrics["loss"] = loss
    return loss, metrics


def logits_last(params: PyTree, hidden: Array, cfg: ModelConfig) -> Array:
    """LM-head logits for the last position only (decode path)."""
    w = L.head_matrix(params["embedding"], cfg)
    return hidden[:, -1].astype(jnp.float32) @ w.astype(jnp.float32)
