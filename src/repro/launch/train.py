"""Training launcher: ``python -m repro.launch.train --arch smollm-360m``.

Single-process only in this container; at real scale this process runs per
host (jax.distributed.initialize) and everything below is unchanged — the
mesh axes span hosts, the data loader shards by host id, and the
checkpoint/restart loop in ``repro.training.loop`` handles preemptions.
"""
from __future__ import annotations

import argparse

import jax

import repro.configs as configs
from repro import compat
from repro.configs.base import OptimizerConfig, ParallelConfig, RunConfig
from repro.data.synthetic import SyntheticConfig, SyntheticDataset
from repro.distributed import context, sharding
from repro.training import loop
from repro.training.train_step import init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 → mesh (data=2, model=4); default: all "
                         "devices on the data axis")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    run_cfg = RunConfig(
        model=cfg,
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps),
        parallel=ParallelConfig(microbatches=args.microbatches),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )

    devices = jax.devices()
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        mesh = compat.make_mesh(dims, ("data", "model")[:len(dims)])
    else:
        mesh = compat.make_mesh((len(devices), 1), ("data", "model"))

    params, opt_state, axes = init_state(run_cfg, jax.random.PRNGKey(run_cfg.seed))
    par = sharding.derive_parallel(cfg, mesh, run_cfg.parallel)
    p_sh = sharding.param_sharding(axes, cfg, par, mesh)
    params = jax.device_put(params, p_sh)
    opt_sh = compat.tree_map(lambda _: None, opt_state)  # follow params
    step_fn = jax.jit(make_train_step(run_cfg), donate_argnums=(0, 1))

    ds = SyntheticDataset(SyntheticConfig(
        vocab_size=cfg.real_vocab_size or cfg.vocab_size,
        seq_len=args.seq_len, global_batch=args.global_batch,
        seed=run_cfg.seed))

    ctx = context.ShardContext(mesh=mesh, par=par)
    with mesh, context.use(ctx):
        params, opt_state, history = loop.run(
            run_cfg, steps=args.steps, train_step=step_fn,
            params=params, opt_state=opt_state, dataset=ds)
    losses = [h["loss"] for h in history if "loss" in h]
    if losses:
        print(f"first loss {losses[0]:.4f} → last loss {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
