"""Serving launcher: lockstep baseline and the continuous-batching loop.

``python -m repro.launch.serve --smoke --continuous`` drives the slot-pool
scheduler (``repro.serving.scheduler``) over synthetic Poisson-staggered
arrivals and reports throughput, p50/p95 per-token latency, and batch
occupancy against the drain-and-refill bound.  Adding ``--paged`` switches
the KV cache to the block pool (``repro.serving.paged``): admission gates on
free blocks, every prompt carries a shared synthetic prefix
(``--shared-prefix``, the system-prompt pattern), and the report adds
block-pool accounting — free-block low-water mark, blocks saved by prefix
sharing, copy-on-write count, persistent-prefix-cache residency/hits.
``--priority-classes N`` makes the workload mixed-priority (admission
orders by (priority, arrival); in paged mode a blocked urgent request
preempts lower-priority decodes by swapping their blocks out — disable
with ``--no-preempt``) and ``--slo-ms`` attaches a completion deadline to
the urgent class; the report then adds p95-by-class, SLO attainment, and
preemption/swap counts.  ``--replicas N`` serves the same workload through
``repro.serving.router.ReplicaRouter`` over N engine replicas —
prefix-affinity routed (``--no-affinity`` for round-robin), with admission
backpressure and a globally merged report.  Without ``--continuous`` the original
lockstep batch runs: one shared cache length, prefill-everything-then-decode
— kept as the baseline the scheduler has to beat.  Either way the decode hot
path is the paper's §4 scenario: project to the vocabulary, fused
online-softmax + top-k, sample.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models import encdec, layers as L, transformer
from repro.obs import clock as obs_clock
from repro.obs import kernels as obs_kernels
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import cache_family, engine


def _lockstep(args, cfg, params) -> int:
    """The original drain-and-refill loop (one shared cache_len)."""
    max_len = args.max_len or (args.prompt_len + args.tokens)
    rng = jax.random.PRNGKey(0)
    vocab = cfg.real_vocab_size or cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, vocab)
    patch = None
    if cfg.num_patches:
        patch = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, t, pe: engine.prefill(
        p, t, cfg, max_len=max_len + (cfg.num_patches or 0),
        patch_embeds=pe))
    decode = jax.jit(lambda p, c, ln, t, r: engine.decode_step(
        p, c, ln, t, cfg, rng=r, top_k=args.top_k), donate_argnums=(1,))

    t0 = obs_clock.monotonic()
    last_hidden, caches, length = prefill(params, prompts, patch)
    logits = transformer.logits_last(params, last_hidden[:, None], cfg)
    from repro.core import topk_sample
    tok, _ = topk_sample(jax.random.PRNGKey(3), logits, args.top_k)
    jax.block_until_ready(tok)
    t_prefill = obs_clock.monotonic() - t0

    out = [tok]
    t0 = obs_clock.monotonic()
    for i in range(args.tokens - 1):
        tok, caches, length = decode(params, caches, length, tok[:, None],
                                     jax.random.fold_in(rng, i))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = obs_clock.monotonic() - t0
    gen = jnp.stack(out, axis=1)
    print(f"prefill: {args.batch}×{args.prompt_len} in {t_prefill*1e3:.1f}ms")
    print(f"decode: {args.tokens - 1} steps × {args.batch} seqs in "
          f"{t_decode*1e3:.1f}ms "
          f"({(args.tokens - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())
    return 0


def _continuous(args, cfg, params) -> int:
    """Continuous batching over staggered (Poisson) synthetic arrivals.

    Always drives a ``ReplicaRouter`` — with ``--replicas 1`` (the default)
    it owns a single ``Engine`` and the report lines are byte-identical to
    the pre-router CLI (pinned by tests/test_serving_router.py); with more,
    traffic spreads across replicas by prefix affinity (``--no-affinity``
    for the round-robin baseline) and the report merges globally."""
    from repro.serving import scheduler as sched_mod
    from repro.serving.router import ReplicaRouter

    vocab = cfg.real_vocab_size or cfg.vocab_size
    slot_len = args.max_len or (args.prompt_len + args.tokens + 8)
    if args.paged:                     # the paged determinism contract
        slot_len += -slot_len % args.block_size
    shared_prefix = args.shared_prefix if args.paged else 0
    requests = sched_mod.poisson_workload(
        args.requests, rate_per_tick=args.rate,
        prompt_lens=(max(2, args.prompt_len // 4), args.prompt_len),
        decode_lens=(max(2, args.tokens // 8), args.tokens),
        vocab=vocab, seed=1, shared_prefix=shared_prefix,
        priority_classes=args.priority_classes,
        slo_ms=args.slo_ms or None)
    family = cache_family.resolve(cfg)
    if family.kind == "encdec":
        # prompts are audio: a small set of distinct frame-id sequences, each
        # filling the encoder window; repeats of the same audio are where the
        # shared encoder blocks (and zero recompute) pay
        rng = np.random.default_rng(2)
        audios = [rng.integers(0, vocab, cfg.encoder_seq_len)
                  for _ in range(max(1, args.audios))]
        for r in requests:
            r.prompt = audios[r.rid % len(audios)]
    elif family.kind == "state":
        # single-shot prefill through the chunked scan: snap prompt lengths
        # to the scan's chunk quantum (≤ q, or a multiple of q)
        q = family.prompt_quantum()
        for r in requests:
            n = len(r.prompt)
            if n > q and n % q:
                r.prompt = r.prompt[:n - n % q]
    if args.metrics:
        obs_metrics.enable()
        obs_kernels.enable_profiling()
    # --trace FILE shares one Tracer across replicas (pids split the
    # tracks); --trace DIR/ writes replica{i}.json per replica plus a
    # clock-aligned merged.json via repro.obs.merge
    trace_dir = None
    tracer = None
    tracers = None
    if args.trace:
        if args.trace.endswith(os.sep) or os.path.isdir(args.trace):
            trace_dir = args.trace.rstrip(os.sep) or os.sep
            os.makedirs(trace_dir, exist_ok=True)
            tracers = [obs_trace.Tracer(
                           os.path.join(trace_dir, f"replica{i}.json"))
                       for i in range(args.replicas)]
        else:
            tracer = obs_trace.Tracer(args.trace)
    router = ReplicaRouter(
        params, cfg, replicas=args.replicas,
        affinity=not args.no_affinity,
        num_slots=args.slots, slot_len=slot_len,
        prefill_chunk=args.prefill_chunk, top_k=args.top_k,
        base_rng=jax.random.PRNGKey(0), paged=args.paged,
        block_size=args.block_size,
        num_blocks=args.blocks or None,
        preempt=not args.no_preempt, tracer=tracer, tracers=tracers)
    report = router.serve(requests)
    if tracer is not None:
        tracer.close()
    merged_path = None
    if tracers is not None:
        for t in tracers:
            t.close()
        from repro.obs import merge as obs_merge
        merged_path = os.path.join(trace_dir, "merged.json")
        obs_merge.merge_traces(
            [os.path.join(trace_dir, f"replica{i}.json")
             for i in range(args.replicas)],
            out=merged_path)

    pct = report.latency_percentiles((50, 95))
    baseline = report.baseline_occupancy(args.slots * args.replicas)
    mode = "paged continuous batching" if args.paged else "continuous batching"
    where = (f"{args.slots} slots" if args.replicas == 1
             else f"{args.replicas} replicas × {args.slots} slots")
    print(f"{mode}: {len(report.results)} requests over "
          f"{where} (slot_len={slot_len}, "
          f"prefill_chunk={args.prefill_chunk})")
    print(f"tokens: {report.total_tokens} in {report.wall_time:.2f}s "
          f"→ {report.tokens_per_s:.1f} tok/s")
    print(f"per-token latency: p50={pct['p50']*1e3:.1f}ms "
          f"p95={pct['p95']*1e3:.1f}ms")
    print(f"decode steps: {report.decode_steps}  "
          f"prefill chunks: {report.prefill_chunks}")
    print(f"batch occupancy: {report.occupancy:.3f} "
          f"(drain-and-refill baseline: {baseline:.3f})")
    if report.paged is not None:
        p = report.paged
        print(f"block pool: {p['num_blocks']}×{p['block_size']} blocks, "
              f"free now {p['free_blocks']}, "
              f"min free {p['min_free_blocks']}")
        print(f"blocks saved by sharing: {p['blocks_shared']} "
              f"(prefill tokens reused: {p['tokens_reused']}, "
              f"copy-on-write copies: {p['cow_copies']})")
        print(f"prefix cache: {p['cached_blocks']} blocks resident, "
              f"{p['prefix_cache_hits']} hits, "
              f"{p['reclaimed_blocks']} reclaimed under pressure")
    if args.replicas > 1:
        r = report.router
        routing = "prefix-affinity" if r["affinity"] else "round-robin"
        print(f"router: {routing}, per-replica requests "
              f"{r['per_replica']}, affinity routes {r['affinity_routes']}")
        if r["backpressure_rejects"]:
            print(f"backpressure: {r['backpressure_rejects']} rejected "
                  f"{r['rejected']}")
    if args.priority_classes > 1:
        for pr, pct_c in sorted(
                report.latency_percentiles_by_class((50, 95)).items()):
            rs = [r for r in report.results if r.priority == pr]
            npre = sum(r.preempted for r in rs)
            # phase split: queue wait / prefill compute / decode, so a slow
            # first token can be attributed instead of conflated
            def _mean(vals):
                vals = [v for v in vals if v is not None]
                return sum(vals) / len(vals) if vals else 0.0
            print(f"class {pr}: n={len(rs)} p50={pct_c['p50']*1e3:.1f}ms "
                  f"p95={pct_c['p95']*1e3:.1f}ms "
                  f"queued={_mean([r.queued_ms for r in rs]):.1f}ms "
                  f"prefill={_mean([r.prefill_ms for r in rs]):.1f}ms "
                  f"decode={_mean([r.decode_ms for r in rs]):.1f}ms "
                  f"preemptions={npre}")
        att = report.slo_attainment()
        if att is not None:
            bearing = sum(1 for r in report.results if r.slo_ms is not None)
            print(f"SLO attainment: {att*100:.1f}% of {bearing} "
                  f"deadline-bearing requests")
        if report.paged is not None:
            p = report.paged
            print(f"preemptions: {report.preemptions} "
                  f"(blocks swapped out: {p['swapped_blocks_out']}, "
                  f"swapped back in: {p['swapped_blocks_in']})")
    evicted = [r.rid for r in report.results if r.evicted]
    if evicted:
        print(f"evicted at capacity: {evicted}")
    if args.metrics:
        prof = obs_kernels.snapshot()
        for op, rec in prof["paths"].items():
            print(f"kernel path: {op} → {rec['path']} (×{rec['count']})")
        for label, cost in prof["costs"].items():
            print(f"kernel cost: {label} flops={cost['flops']:.4g} "
                  f"bytes={cost['bytes_accessed']:.4g}")
        snap = obs_metrics.snapshot()
        for mname, rec in snap.items():
            if rec.get("type") != "histogram":
                continue
            print(f"metric {mname}: n={rec['count']} "
                  f"mean={rec['mean']:.4g} p50={rec['p50']:.4g} "
                  f"p95={rec['p95']:.4g}")
        print(f"metrics: {len(snap)} instruments recorded")
    if tracer is not None:
        print(f"trace: {len(tracer.events)} events → {args.trace} "
              f"(open in Perfetto, or: python -m repro.obs.report "
              f"{args.trace})")
    if merged_path is not None:
        print(f"trace: {args.replicas} per-replica files in {trace_dir}"
              f"{os.sep} → merged view {merged_path} "
              f"(open in Perfetto, or: python -m repro.obs.report "
              f"{merged_path})")
    if report.occupancy <= baseline:
        print("WARNING: occupancy did not beat the drain-and-refill baseline")
        return 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="slot-pool continuous batching over Poisson arrivals")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache slots in the pool (continuous mode)")
    ap.add_argument("--requests", type=int, default=16,
                    help="synthetic requests to serve (continuous mode)")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean arrivals per scheduler tick (continuous mode)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens prefilled per tick (continuous mode)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: block pool + prefix sharing "
                         "(continuous mode)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="KV block size in tokens (paged mode)")
    ap.add_argument("--blocks", type=int, default=0,
                    help="pool capacity in blocks (paged mode; 0 = enough "
                         "for every slot at full length)")
    ap.add_argument("--audios", type=int, default=3,
                    help="distinct synthetic audios in the enc-dec workload "
                         "(requests cycle through them, so repeats share "
                         "encoder blocks)")
    ap.add_argument("--shared-prefix", type=int, default=8,
                    help="shared synthetic prompt prefix length (paged "
                         "mode; demonstrates block sharing)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="priority classes in the synthetic workload (>1 "
                         "assigns each request a random class; smaller = "
                         "more urgent; report adds p95-by-class)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="completion deadline attached to priority-0 "
                         "requests; report adds SLO attainment (0 = off)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the router (continuous "
                         "mode; 1 = the single-engine CLI, byte-identical "
                         "report)")
    ap.add_argument("--no-affinity", action="store_true",
                    help="route round-robin instead of by prefix affinity "
                         "(multi-replica baseline)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable preempt-and-swap of lower-priority "
                         "decodes (paged mode; priorities stay "
                         "ordering-only)")
    ap.add_argument("--trace", default="",
                    help="write request-lifecycle + scheduler spans to this "
                         "Chrome trace_event file (continuous mode; open in "
                         "Perfetto or summarize with repro.obs.report); a "
                         "directory (trailing '/' or existing dir) writes "
                         "one replica{i}.json per replica plus a "
                         "clock-aligned merged.json")
    ap.add_argument("--metrics", action="store_true",
                    help="enable the repro.obs metrics registry + kernel "
                         "cost profiling; prints dispatch paths and a "
                         "snapshot summary after the run")
    ap.add_argument("--kv-cache-dtype", default="",
                    help="override the config's KV-cache dtype (e.g. int8: "
                         "quantized K/V with per-position scales, dequantized "
                         "in the gather; empty = config default)")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if args.kv_cache_dtype:
        cfg = cfg.replace(kv_cache_dtype=args.kv_cache_dtype)
    family = cache_family.resolve(cfg)
    if family.requires_paged and not (args.continuous and args.paged):
        raise SystemExit(f"{args.arch}: enc-dec serves under --continuous "
                         "--paged (the encoder output pages as immutable "
                         "shared blocks)")
    if args.continuous and cfg.num_patches:
        raise SystemExit("continuous batching serves text-only archs for now")
    if args.paged and not args.continuous:
        raise SystemExit("--paged requires --continuous (the lockstep "
                         "baseline keeps its contiguous cache)")

    init_fn = encdec.init if family.kind == "encdec" else transformer.init
    params, _ = L.split_params(init_fn(jax.random.PRNGKey(0), cfg))
    if args.continuous:
        return _continuous(args, cfg, params)
    return _lockstep(args, cfg, params)


if __name__ == "__main__":
    raise SystemExit(main())
