"""Serving launcher: batched prefill + decode with fused top-k sampling.

``python -m repro.launch.serve --arch smollm-360m --smoke --tokens 32``
runs a batch of synthetic prompts through prefill and autoregressive decode,
reporting tokens/s.  The decode hot path is the paper's §4 scenario: project
to the vocabulary, fused online-softmax + top-k, sample.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--max-len", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("use examples/serve_whisper.py for enc-dec serving")
    max_len = args.max_len or (args.prompt_len + args.tokens)

    rng = jax.random.PRNGKey(0)
    params, _ = L.split_params(transformer.init(rng, cfg))
    vocab = cfg.real_vocab_size or cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, vocab)
    patch = None
    if cfg.num_patches:
        patch = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, t, pe: engine.prefill(
        p, t, cfg, max_len=max_len + (cfg.num_patches or 0),
        patch_embeds=pe))
    decode = jax.jit(lambda p, c, ln, t, r: engine.decode_step(
        p, c, ln, t, cfg, rng=r, top_k=args.top_k), donate_argnums=(1,))

    t0 = time.monotonic()
    last_hidden, caches, length = prefill(params, prompts, patch)
    logits = transformer.logits_last(params, last_hidden[:, None], cfg)
    from repro.core import topk_sample
    tok, _ = topk_sample(jax.random.PRNGKey(3), logits, args.top_k)
    jax.block_until_ready(tok)
    t_prefill = time.monotonic() - t0

    out = [tok]
    t0 = time.monotonic()
    for i in range(args.tokens - 1):
        tok, caches, length = decode(params, caches, length, tok[:, None],
                                     jax.random.fold_in(rng, i))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t0
    gen = jnp.stack(out, axis=1)
    print(f"prefill: {args.batch}×{args.prompt_len} in {t_prefill*1e3:.1f}ms")
    print(f"decode: {args.tokens - 1} steps × {args.batch} seqs in "
          f"{t_decode*1e3:.1f}ms "
          f"({(args.tokens - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
