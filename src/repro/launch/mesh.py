"""Production mesh builders.

Functions, not module-level constants — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax use).
``compat.make_mesh`` resolves ``jax.make_mesh`` vs the pre-0.4.34
``mesh_utils`` construction.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small host-device mesh for tests (requires XLA_FLAGS device count)."""
    return make_mesh((n_data, n_model), ("data", "model"))
