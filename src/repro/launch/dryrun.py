import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  REPRO_DRYRUN_DEVICES overrides for the test suite.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and extract the roofline terms.

For each cell this builds ShapeDtypeStruct stand-ins (no allocation), lowers
the right step function —

    train_4k    → train_step  (loss → grads → bf16 reduce → sharded AdamW)
    prefill_32k → prefill_step (cache fill + first fused-top-k token)
    decode_32k / long_500k → serve_step (one token, shard_map ⊕-merge
                   attention over the sharded KV cache, fused top-k sampling)

— compiles it, prints ``memory_analysis()`` / ``cost_analysis()``, and writes
a JSON record (roofline terms, collective breakdown, bytes/device) consumed
by EXPERIMENTS.md.  A failure here is a sharding bug by definition.
"""
import argparse
import functools
import json
import sys
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro import compat
from repro.obs import clock as obs_clock
from repro.configs.base import SHAPE_BY_NAME, ModelConfig, ParallelConfig, RunConfig, ShapeConfig
from repro.distributed import context, sharding
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, layers as L, transformer
from repro.optim import adamw
from repro.roofline.analysis import analyze
from repro.serving import engine as serving
from repro.training import train_step as ts

# long-context cells run only for sub-quadratic archs (DESIGN.md §5)
LONG_OK = {"xlstm-125m", "zamba2-1.2b"}
LONG_OK_SMOKE = {"xlstm-125m-smoke", "zamba2-1.2b-smoke"}

# reduced shapes for the smoke-mode matrix (tests exercise every builder
# path on a small host mesh without the 512-device compile cost)
SMOKE_SHAPES = {
    "train_4k": ("train", 64, 8),
    "prefill_32k": ("prefill", 128, 4),
    "decode_32k": ("decode", 128, 8),
    "long_500k": ("decode", 256, 2),
}
# archs whose params+opt need FSDP-style data-axis sharding to fit v5e HBM
FSDP_ARCHS = {"llama4-scout-17b-a16e", "deepseek-coder-33b", "llava-next-34b"}


def sds(shape, dtype, mesh=None, spec=None):
    sh = compat.named_sharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def _ba(shape_cfg: ShapeConfig, mesh) -> tuple:
    """Mesh axes for the batch dim ('' tuple = replicated, e.g. batch 1)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return dp if shape_cfg.global_batch % n == 0 else ()


def _sa(shape_cfg: ShapeConfig, mesh, ba: tuple) -> tuple:
    """Mesh axes for the KV-cache sequence dim (decode cells)."""
    if ba:
        return ("model",)
    return tuple(mesh.axis_names)          # batch replicated: shard S fully


def eval_params(cfg: ModelConfig):
    """(values SDS tree, logical-axes tree) without allocating anything."""
    init_fn = encdec.init if cfg.family == "encdec" else transformer.init
    captured = {}

    def f(key):
        vals, axes = L.split_params(init_fn(key, cfg))
        captured["axes"] = axes
        return vals

    vals = jax.eval_shape(f, jax.random.PRNGKey(0))
    return vals, captured["axes"]


def count_params(vals_sds) -> int:
    return int(sum(x.size for x in compat.tree_leaves(vals_sds)))


def active_params(cfg: ModelConfig, vals_sds) -> int:
    """N_active: routed-expert params scaled by k/E (MoE), else total."""
    total = count_params(vals_sds)
    if cfg.moe is None:
        return total
    routed = 0
    for path, leaf in compat.tree_flatten_with_path(vals_sds)[0]:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "moe" in keys and "shared" not in keys and \
                any(k in ("w_gate", "w_up", "w_down") for k in keys):
            routed += leaf.size
    frac = cfg.moe.experts_per_token / cfg.moe.num_experts
    return int(total - routed + routed * frac)


def model_flops(cfg: ModelConfig, shape_cfg: ShapeConfig, n_active: int) -> float:
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        if cfg.family == "encdec":
            tokens += shape_cfg.global_batch * cfg.encoder_seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape_cfg.global_batch      # decode: 1 tok/seq


# ---------------------------------------------------------------------------
# Cache sharding by path.
# ---------------------------------------------------------------------------
def cache_shardings(cache_sds, mesh, rules: dict, ba: tuple, sa: tuple):
    ba_s = ba if ba else None
    sa_s = sa if sa else None

    def one(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        nd = len(leaf.shape)
        kn = keys[-1] if keys else ""
        if kn in ("k", "v"):
            spec = (P(ba_s, sa_s, None, None) if nd == 4
                    else P(None, ba_s, sa_s, None, None))
        elif kn in ("k_scale", "v_scale"):
            spec = (P(ba_s, sa_s, None) if nd == 3
                    else P(None, ba_s, sa_s, None))
        elif kn in ("c_kv", "k_rope"):
            spec = P(None, ba_s, sa_s, None)
        elif kn == "ssm":
            spec = P(None, ba_s, rules.get("inner_heads"), None, None)
        elif kn in ("conv_x", "conv"):
            spec = P(None, ba_s, None, rules.get("inner"))
        elif kn in ("conv_b", "conv_c"):
            spec = P(None, ba_s, None, None)
        else:  # state tuples (mlstm/slstm scalar states)
            spec = P(*([None, ba_s] + [None] * (nd - 2))) if nd >= 2 else P()
        return compat.named_sharding(mesh, spec)

    return compat.tree_map_with_path(one, cache_sds)


# ---------------------------------------------------------------------------
# Per-kind builders: return (function, arg SDS tuple, out_shardings, donate).
# ---------------------------------------------------------------------------
def build_train(run: RunConfig, mesh, par, shape_cfg: ShapeConfig):
    cfg = run.model
    vals_sds, axes = eval_params(cfg)
    p_sh = sharding.param_sharding(axes, cfg, par, mesh)
    if par.fsdp:
        p_sh = sharding.fsdp_param_sharding(p_sh, vals_sds, mesh, par)
    opt_moments = sharding.optimizer_sharding(p_sh, vals_sds, mesh, par)
    opt_sh = adamw.AdamWState(step=compat.named_sharding(mesh, P()),
                              mu=opt_moments, nu=opt_moments)
    params = compat.tree_map(lambda s, sh: sds(s.shape, s.dtype, mesh, sh.spec),
                          vals_sds, p_sh)
    opt_shape = jax.eval_shape(adamw.init, vals_sds)
    opt = adamw.AdamWState(
        step=sds((), jnp.int32, mesh, P()),
        mu=compat.tree_map(lambda s, sh: sds(s.shape, s.dtype, mesh, sh.spec),
                        opt_shape.mu, opt_moments),
        nu=compat.tree_map(lambda s, sh: sds(s.shape, s.dtype, mesh, sh.spec),
                        opt_shape.nu, opt_moments))
    ba = _ba(shape_cfg, mesh)
    ba_s = ba if ba else None
    gb, t = shape_cfg.global_batch, shape_cfg.seq_len
    batch = {"tokens": sds((gb, t), jnp.int32, mesh, P(ba_s, None)),
             "labels": sds((gb, t), jnp.int32, mesh, P(ba_s, None))}
    if cfg.family == "vlm":
        tt = t - cfg.num_patches
        batch = {"tokens": sds((gb, tt), jnp.int32, mesh, P(ba_s, None)),
                 "labels": sds((gb, tt), jnp.int32, mesh, P(ba_s, None)),
                 "patch_embeds": sds((gb, cfg.num_patches, cfg.d_model),
                                     jnp.bfloat16, mesh, P(ba_s, None, None))}
    if cfg.family == "encdec":
        batch["frames"] = sds((gb, cfg.encoder_seq_len, cfg.d_model),
                              jnp.bfloat16, mesh, P(ba_s, None, None))
    fn = ts.make_train_step(run)
    return fn, (params, opt, batch), (p_sh, opt_sh, None), (0, 1)


def build_prefill(run: RunConfig, mesh, par, shape_cfg: ShapeConfig):
    cfg = run.model
    vals_sds, axes = eval_params(cfg)
    p_sh = sharding.param_sharding(axes, cfg, par, mesh)
    params = compat.tree_map(lambda s, sh: sds(s.shape, s.dtype, mesh, sh.spec),
                          vals_sds, p_sh)
    ba = _ba(shape_cfg, mesh)
    sa = _sa(shape_cfg, mesh, ba)
    ba_s = ba if ba else None
    rules = sharding.axis_rules(cfg, par, mesh)
    gb, t = shape_cfg.global_batch, shape_cfg.seq_len

    if cfg.family == "encdec":
        def fn(params, frames, tokens, rng):
            last, caches, ln = serving.encdec_prefill(params, frames, tokens,
                                                      cfg, max_len=t)
            logits = transformer.logits_last(params, last[:, None], cfg)
            from repro.distributed.decode_attention import sharded_topk_sample
            tok, _ = sharded_topk_sample(rng, logits, 5, mesh=mesh,
                                         batch_axes=ba)
            return tok, caches, ln
        args = (params,
                sds((gb, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16,
                    mesh, P(ba_s, None, None)),
                sds((gb, t), jnp.int32, mesh, P(ba_s, None)),
                sds((2,), jnp.uint32, mesh, P()))
    else:
        tt = t - cfg.num_patches if cfg.family == "vlm" else t

        def fn(params, tokens, rng, *extra):
            pe = extra[0] if extra else None
            last, caches, ln = serving.prefill(params, tokens, cfg,
                                               max_len=t, patch_embeds=pe)
            logits = transformer.logits_last(params, last[:, None], cfg)
            from repro.distributed.decode_attention import sharded_topk_sample
            tok, _ = sharded_topk_sample(rng, logits, 5, mesh=mesh,
                                         batch_axes=ba)
            return tok, caches, ln
        args = [params, sds((gb, tt), jnp.int32, mesh, P(ba_s, None)),
                sds((2,), jnp.uint32, mesh, P())]
        if cfg.family == "vlm":
            args.append(sds((gb, cfg.num_patches, cfg.d_model), jnp.bfloat16,
                            mesh, P(ba_s, None, None)))
        args = tuple(args)
    # cache shapes come from the config's cache family — one owner for
    # every layout (dense, quantized, state, enc-dec), no local duplicates
    cache_sds = jax.eval_shape(lambda: serving.init_cache(cfg, gb, t))
    cache_sh = cache_shardings(cache_sds, mesh, rules, ba, sa)
    out_sh = (compat.named_sharding(mesh, P(ba_s)), cache_sh, compat.named_sharding(mesh, P()))
    return fn, args, out_sh, ()


def build_decode(run: RunConfig, mesh, par, shape_cfg: ShapeConfig):
    cfg = run.model
    vals_sds, axes = eval_params(cfg)
    p_sh = sharding.param_sharding(axes, cfg, par, mesh)
    params = compat.tree_map(lambda s, sh: sds(s.shape, s.dtype, mesh, sh.spec),
                          vals_sds, p_sh)
    ba = _ba(shape_cfg, mesh)
    sa = _sa(shape_cfg, mesh, ba)
    ba_s = ba if ba else None
    rules = sharding.axis_rules(cfg, par, mesh)
    gb, s = shape_cfg.global_batch, shape_cfg.seq_len

    if cfg.family == "encdec":
        def fn(params, caches, cache_len, tokens, rng):
            return serving.encdec_decode_step(params, caches, cache_len,
                                              tokens, cfg, rng=rng)
    else:
        def fn(params, caches, cache_len, tokens, rng):
            return serving.decode_step(params, caches, cache_len, tokens,
                                       cfg, rng=rng, top_k=5)
    cache_sds = jax.eval_shape(lambda: serving.init_cache(cfg, gb, s))
    cache_sh = cache_shardings(cache_sds, mesh, rules, ba, sa)
    caches = compat.tree_map(lambda x, sh: sds(x.shape, x.dtype, mesh, sh.spec),
                          cache_sds, cache_sh)
    args = (params, caches, sds((), jnp.int32, mesh, P()),
            sds((gb, 1), jnp.int32, mesh, P(ba_s, None)),
            sds((2,), jnp.uint32, mesh, P()))
    out_sh = (compat.named_sharding(mesh, P(ba_s)), cache_sh, compat.named_sharding(mesh, P()))
    return fn, args, out_sh, (1,)


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


# ---------------------------------------------------------------------------
# Cell runner.
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             mesh=None, verbose: bool = True, smoke: bool = False,
             overrides: dict | None = None,
             hlo_path: str | None = None) -> dict:
    if smoke:
        kind, seq, gb = SMOKE_SHAPES[shape_name]
        shape_cfg = ShapeConfig(shape_name, seq, gb, kind)
        cfg = configs.get_smoke(arch)
    else:
        shape_cfg = SHAPE_BY_NAME[shape_name]
        cfg = configs.get(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if shape_name == "long_500k" and cfg.name not in (LONG_OK | LONG_OK_SMOKE):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch: 500k decode requires "
                          "sub-quadratic mixer (DESIGN.md §5)"}
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    par = sharding.derive_parallel(cfg, mesh)
    par = ParallelConfig(**{**par.__dict__,
                            "fsdp": cfg.name in FSDP_ARCHS})
    run = RunConfig(model=cfg, parallel=par)
    ba = _ba(shape_cfg, mesh)
    sa = _sa(shape_cfg, mesh, ba)
    ctx = context.ShardContext(mesh=mesh, par=par, cache_seq_axes=sa,
                               batch_axes=ba)
    t0 = obs_clock.monotonic()
    with context.use(ctx), mesh:
        fn, args, out_sh, donate = BUILDERS[shape_cfg.kind](
            run, mesh, par, shape_cfg)
        lowered = jax.jit(fn, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = obs_clock.monotonic() - t0
        compiled = lowered.compile()
        t_compile = obs_clock.monotonic() - t0 - t_lower
    if hlo_path:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(compiled.as_text())
    vals_sds, _ = eval_params(cfg)
    n_active = active_params(cfg, vals_sds)
    chips = mesh.size
    rf = analyze(compiled, arch=arch, shape=shape_name,
                 mesh_desc="x".join(str(mesh.shape[a]) for a in mesh.axis_names),
                 chips=chips,
                 model_flops=model_flops(cfg, shape_cfg, n_active))
    rec = rf.to_dict()
    rec.update(status="ok", attn_mode=par.attn_mode, fsdp=par.fsdp,
               n_params=count_params(vals_sds), n_active=n_active,
               lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
               env=compat.capabilities().to_dict())
    if verbose:
        ma = compat.memory_analysis(compiled)
        print(f"[{arch} × {shape_name} × {rec['mesh']}] "
              f"attn={par.attn_mode} fsdp={par.fsdp}")
        if ma is not None:
            print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
                  f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
                  f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB per device")
        print(f"  cost_analysis: flops/dev={rec['hlo_flops_per_device']:.3e} "
              f"bytes/dev={rec['hlo_bytes_per_device']:.3e}")
        print(f"  roofline: compute={rec['compute_s']*1e3:.2f}ms "
              f"memory={rec['memory_s']*1e3:.2f}ms "
              f"collective={rec['collective_s']*1e3:.2f}ms "
              f"→ {rec['dominant']}-bound; "
              f"useful-flops={rec['useful_flops_ratio']:.2f}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            name = configs.get(arch).name
            for shape in SHAPE_BY_NAME:
                cells.append((name, shape))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{'multipod' if args.multi_pod else 'pod'}"
        path = os.path.join(args.out, tag + ".json")
        hlo_dir = os.path.join(args.out, "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        if os.path.exists(path):
            print(f"[skip cached] {tag}")
            continue
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           hlo_path=os.path.join(hlo_dir, tag + ".hlo.gz"))
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "failed",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[wrote] {path}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
