"""Chrome ``trace_event`` tracer for the serving stack.

Events follow the Trace Event Format that Perfetto and ``chrome://tracing``
ingest: complete spans (``ph="X"`` with ``ts``/``dur`` in microseconds),
instants (``"i"``), counters (``"C"``) and thread-name metadata (``"M"``).
The scheduler maps ``pid`` to the replica index and ``tid`` to a track —
tid 0 is the scheduler tick track, tid ``rid + 1`` is request ``rid``'s
lifecycle track.

The output file is a valid JSON **array** written one event per line::

    [
    {"name": "tick", "ph": "X", ...},
    {"name": "queued", "ph": "X", ...}
    ]

so it both ``json.load``s (Perfetto-compatible) and can be parsed line by
line by :mod:`repro.obs.report` without holding the whole file.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs import clock as _clock

# one prebuilt encoder: json.dumps with non-default separators constructs a
# fresh JSONEncoder per call, which roughly doubles per-event cost
_ENCODE = json.JSONEncoder(separators=(",", ":")).encode


class Span:
    """An open span handle: ``begin()`` returns one, ``end()`` closes it."""

    __slots__ = ("name", "pid", "tid", "cat", "start_us", "args", "closed")

    def __init__(self, name: str, pid: int, tid: int, cat: str,
                 start_us: float, args: Optional[Dict[str, Any]]):
        self.name = name
        self.pid = pid
        self.tid = tid
        self.cat = cat
        self.start_us = start_us
        self.args = dict(args) if args else {}
        self.closed = False


class _SpanCtx:
    """``with tracer.span(...)`` handle — a plain class, not a
    ``@contextmanager`` generator, because the generator protocol costs
    ~1µs per use and spans are the hot path."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: "Span"):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> "Span":
        return self._span

    def __exit__(self, *exc) -> None:
        self._tracer.end(self._span)


class Tracer:
    """Collects trace events in memory; :meth:`close` writes the file.

    ``clock`` defaults to the process-wide :mod:`repro.obs.clock`; the
    tracer records microseconds relative to its own creation so virtual
    clocks produce small, exact timestamps.

    The hot-path buffer holds one flat tuple of scalars per event — no
    dict build, no serialization — so emitting costs a tuple pack and a
    list append, and the growing buffer is cheap for the cyclic garbage
    collector (CPython untracks tuples of atoms after a collection pass,
    where a heap of long-lived dicts keeps gen-2 scans expensive).  JSON
    encoding happens once, in :meth:`close`, outside the serve loop.

    ``flush_every=N`` bounds the buffer instead: whenever N events are
    pending they are encoded and appended to ``path`` incrementally, so a
    long-lived server holds at most N events in memory.  The file stays
    the same valid JSON array (:meth:`close` writes the closing bracket);
    :attr:`events` then exposes only the still-buffered tail and
    :attr:`total_events` counts everything emitted.
    """

    def __init__(self, path: Optional[str] = None, *,
                 clock: Optional[_clock.Clock] = None, pid: int = 0,
                 flush_every: Optional[int] = None):
        if flush_every is not None:
            if path is None:
                raise ValueError("flush_every needs a path to flush to")
            if flush_every < 1:
                raise ValueError(f"flush_every must be >= 1, got {flush_every}")
        self.path = path
        self.clock = clock or _clock.get()
        self.pid = pid
        self.flush_every = flush_every
        # entries: ("X", name, cat, pid, tid, ts, dur, args_items)
        #          ("i", name, cat, pid, tid, ts, args_items)
        #          ("C", name, cat, pid, ts, args_items)
        #          ("M", pid, tid, name)
        self._buf: List[tuple] = []
        self._mono = self.clock.monotonic          # bound: hot-path calls
        self._epoch = self._mono()
        self._open: Dict[int, Span] = {}           # id(span) → span, O(1) end
        self._named_tracks: set = set()
        self._fh = None                            # lazy incremental handle
        self._flushed = 0                          # events already on disk

    @staticmethod
    def _to_dict(entry: tuple) -> Dict[str, Any]:
        ph = entry[0]
        if ph == "X":
            _, name, cat, pid, tid, ts, dur, args = entry
            return {"name": name, "ph": "X", "cat": cat, "pid": pid,
                    "tid": tid, "ts": round(ts, 3),
                    "dur": round(max(dur, 0.0), 3), "args": dict(args)}
        if ph == "i":
            _, name, cat, pid, tid, ts, args = entry
            return {"name": name, "ph": "i", "s": "t", "cat": cat,
                    "pid": pid, "tid": tid, "ts": round(ts, 3),
                    "args": dict(args)}
        if ph == "C":
            _, name, cat, pid, ts, args = entry
            return {"name": name, "ph": "C", "cat": cat, "pid": pid,
                    "tid": 0, "ts": round(ts, 3), "args": dict(args)}
        _, pid, tid, name = entry
        return {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": name}}

    @property
    def events(self) -> List[Dict[str, Any]]:
        """The buffered events, materialized as trace_event dicts.  With
        ``flush_every`` set this is only the unflushed tail — already
        flushed events live in the file."""
        return [self._to_dict(e) for e in self._buf]

    @property
    def total_events(self) -> int:
        """Events emitted over the tracer's lifetime: flushed + buffered."""
        return self._flushed + len(self._buf)

    def _emit(self, entry: tuple) -> None:
        self._buf.append(entry)
        if self.flush_every is not None and len(self._buf) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        """Append the buffered events to ``path`` and empty the buffer.
        No-op without a path or with nothing buffered."""
        if self.path is None or not self._buf:
            return
        if self._fh is None:
            self._fh = open(self.path, "w")
            self._fh.write("[\n")
        body = ",\n".join(_ENCODE(self._to_dict(e)) for e in self._buf)
        self._fh.write(",\n" + body if self._flushed else body)
        self._flushed += len(self._buf)
        self._buf.clear()

    # -- time ------------------------------------------------------------
    def now_us(self) -> float:
        return (self._mono() - self._epoch) * 1e6

    # -- spans -----------------------------------------------------------
    def begin(self, name: str, *, tid: int = 0, pid: Optional[int] = None,
              cat: str = "serving", args: Optional[Dict[str, Any]] = None,
              ) -> Span:
        span = Span(name, self.pid if pid is None else pid, tid, cat,
                    (self._mono() - self._epoch) * 1e6, args)
        self._open[id(span)] = span
        return span

    def end(self, span: Span, args: Optional[Dict[str, Any]] = None) -> None:
        if span.closed:
            raise RuntimeError(f"span {span.name!r} ended twice")
        span.closed = True
        del self._open[id(span)]
        if args:
            span.args.update(args)
        now = (self._mono() - self._epoch) * 1e6
        self._emit((
            "X", span.name, span.cat, span.pid, span.tid,
            span.start_us, now - span.start_us, tuple(span.args.items())))

    def span(self, name: str, *, tid: int = 0, pid: Optional[int] = None,
             cat: str = "serving", args: Optional[Dict[str, Any]] = None,
             ) -> _SpanCtx:
        return _SpanCtx(self, self.begin(name, tid=tid, pid=pid, cat=cat,
                                         args=args))

    # -- point events ----------------------------------------------------
    def instant(self, name: str, *, tid: int = 0, pid: Optional[int] = None,
                cat: str = "serving", args: Optional[Dict[str, Any]] = None,
                ) -> None:
        self._emit((
            "i", name, cat, self.pid if pid is None else pid, tid,
            (self._mono() - self._epoch) * 1e6,
            tuple(args.items()) if args else ()))

    def counter(self, name: str, values: Dict[str, float], *,
                pid: Optional[int] = None, cat: str = "serving") -> None:
        self._emit((
            "C", name, cat, self.pid if pid is None else pid,
            (self._mono() - self._epoch) * 1e6, tuple(values.items())))

    def thread_name(self, tid: int, name: str, *,
                    pid: Optional[int] = None) -> None:
        """Label a track (once per (pid, tid)); Perfetto shows it as the
        row name."""
        p = self.pid if pid is None else pid
        if (p, tid) in self._named_tracks:
            return
        self._named_tracks.add((p, tid))
        self._emit(("M", p, tid, name))

    # -- output ----------------------------------------------------------
    def close(self) -> List[Dict[str, Any]]:
        """Force-close leftovers (flagged ``unclosed``) and finish the file.

        Returns the events still in memory — everything, unless
        ``flush_every`` already streamed a prefix to disk (then only the
        tail; the file has the rest).  Without incremental flushing a
        second close rewrites the file from the retained buffer.
        """
        for span in list(self._open.values()):
            span.args["unclosed"] = True
            self.end(span)
        events = self.events
        if self._fh is not None or self.flush_every is not None:
            # incremental mode: append the tail, close the array, release
            # the handle.  The buffer was streamed out, so a second close
            # has nothing left to write.
            self.flush()
            if (self._fh is None and self.path is not None
                    and self._flushed == 0):
                self._fh = open(self.path, "w")   # zero events: empty array
                self._fh.write("[\n")
            if self._fh is not None:
                self._fh.write("\n]\n")
                self._fh.close()
                self._fh = None
        elif self.path is not None:
            with open(self.path, "w") as fh:
                fh.write("[\n")
                fh.write(",\n".join(_ENCODE(ev) for ev in events))
                fh.write("\n]\n")
        return events
