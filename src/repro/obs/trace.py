"""Chrome ``trace_event`` tracer for the serving stack.

Events follow the Trace Event Format that Perfetto and ``chrome://tracing``
ingest: complete spans (``ph="X"`` with ``ts``/``dur`` in microseconds),
instants (``"i"``), counters (``"C"``) and thread-name metadata (``"M"``).
The scheduler maps ``pid`` to the replica index and ``tid`` to a track —
tid 0 is the scheduler tick track, tid ``rid + 1`` is request ``rid``'s
lifecycle track.

The output file is a valid JSON **array** written one event per line::

    [
    {"name": "tick", "ph": "X", ...},
    {"name": "queued", "ph": "X", ...}
    ]

so it both ``json.load``s (Perfetto-compatible) and can be parsed line by
line by :mod:`repro.obs.report` without holding the whole file.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs import clock as _clock

# one prebuilt encoder: json.dumps with non-default separators constructs a
# fresh JSONEncoder per call, which roughly doubles per-event cost
_ENCODE = json.JSONEncoder(separators=(",", ":")).encode


class Span:
    """An open span handle: ``begin()`` returns one, ``end()`` closes it."""

    __slots__ = ("name", "pid", "tid", "cat", "start_us", "args", "closed")

    def __init__(self, name: str, pid: int, tid: int, cat: str,
                 start_us: float, args: Optional[Dict[str, Any]]):
        self.name = name
        self.pid = pid
        self.tid = tid
        self.cat = cat
        self.start_us = start_us
        self.args = dict(args) if args else {}
        self.closed = False


class _SpanCtx:
    """``with tracer.span(...)`` handle — a plain class, not a
    ``@contextmanager`` generator, because the generator protocol costs
    ~1µs per use and spans are the hot path."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: "Span"):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> "Span":
        return self._span

    def __exit__(self, *exc) -> None:
        self._tracer.end(self._span)


class Tracer:
    """Collects trace events in memory; :meth:`close` writes the file.

    ``clock`` defaults to the process-wide :mod:`repro.obs.clock`; the
    tracer records microseconds relative to its own creation so virtual
    clocks produce small, exact timestamps.

    The hot-path buffer holds one flat tuple of scalars per event — no
    dict build, no serialization — so emitting costs a tuple pack and a
    list append, and the growing buffer is cheap for the cyclic garbage
    collector (CPython untracks tuples of atoms after a collection pass,
    where a heap of long-lived dicts keeps gen-2 scans expensive).  JSON
    encoding happens once, in :meth:`close`, outside the serve loop.
    """

    def __init__(self, path: Optional[str] = None, *,
                 clock: Optional[_clock.Clock] = None, pid: int = 0):
        self.path = path
        self.clock = clock or _clock.get()
        self.pid = pid
        # entries: ("X", name, cat, pid, tid, ts, dur, args_items)
        #          ("i", name, cat, pid, tid, ts, args_items)
        #          ("C", name, cat, pid, ts, args_items)
        #          ("M", pid, tid, name)
        self._buf: List[tuple] = []
        self._mono = self.clock.monotonic          # bound: hot-path calls
        self._epoch = self._mono()
        self._open: Dict[int, Span] = {}           # id(span) → span, O(1) end
        self._named_tracks: set = set()

    @staticmethod
    def _to_dict(entry: tuple) -> Dict[str, Any]:
        ph = entry[0]
        if ph == "X":
            _, name, cat, pid, tid, ts, dur, args = entry
            return {"name": name, "ph": "X", "cat": cat, "pid": pid,
                    "tid": tid, "ts": round(ts, 3),
                    "dur": round(max(dur, 0.0), 3), "args": dict(args)}
        if ph == "i":
            _, name, cat, pid, tid, ts, args = entry
            return {"name": name, "ph": "i", "s": "t", "cat": cat,
                    "pid": pid, "tid": tid, "ts": round(ts, 3),
                    "args": dict(args)}
        if ph == "C":
            _, name, cat, pid, ts, args = entry
            return {"name": name, "ph": "C", "cat": cat, "pid": pid,
                    "tid": 0, "ts": round(ts, 3), "args": dict(args)}
        _, pid, tid, name = entry
        return {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": name}}

    @property
    def events(self) -> List[Dict[str, Any]]:
        """The buffered events, materialized as trace_event dicts."""
        return [self._to_dict(e) for e in self._buf]

    # -- time ------------------------------------------------------------
    def now_us(self) -> float:
        return (self._mono() - self._epoch) * 1e6

    # -- spans -----------------------------------------------------------
    def begin(self, name: str, *, tid: int = 0, pid: Optional[int] = None,
              cat: str = "serving", args: Optional[Dict[str, Any]] = None,
              ) -> Span:
        span = Span(name, self.pid if pid is None else pid, tid, cat,
                    (self._mono() - self._epoch) * 1e6, args)
        self._open[id(span)] = span
        return span

    def end(self, span: Span, args: Optional[Dict[str, Any]] = None) -> None:
        if span.closed:
            raise RuntimeError(f"span {span.name!r} ended twice")
        span.closed = True
        del self._open[id(span)]
        if args:
            span.args.update(args)
        now = (self._mono() - self._epoch) * 1e6
        self._buf.append((
            "X", span.name, span.cat, span.pid, span.tid,
            span.start_us, now - span.start_us, tuple(span.args.items())))

    def span(self, name: str, *, tid: int = 0, pid: Optional[int] = None,
             cat: str = "serving", args: Optional[Dict[str, Any]] = None,
             ) -> _SpanCtx:
        return _SpanCtx(self, self.begin(name, tid=tid, pid=pid, cat=cat,
                                         args=args))

    # -- point events ----------------------------------------------------
    def instant(self, name: str, *, tid: int = 0, pid: Optional[int] = None,
                cat: str = "serving", args: Optional[Dict[str, Any]] = None,
                ) -> None:
        self._buf.append((
            "i", name, cat, self.pid if pid is None else pid, tid,
            (self._mono() - self._epoch) * 1e6,
            tuple(args.items()) if args else ()))

    def counter(self, name: str, values: Dict[str, float], *,
                pid: Optional[int] = None, cat: str = "serving") -> None:
        self._buf.append((
            "C", name, cat, self.pid if pid is None else pid,
            (self._mono() - self._epoch) * 1e6, tuple(values.items())))

    def thread_name(self, tid: int, name: str, *,
                    pid: Optional[int] = None) -> None:
        """Label a track (once per (pid, tid)); Perfetto shows it as the
        row name."""
        p = self.pid if pid is None else pid
        if (p, tid) in self._named_tracks:
            return
        self._named_tracks.add((p, tid))
        self._buf.append(("M", p, tid, name))

    # -- output ----------------------------------------------------------
    def close(self) -> List[Dict[str, Any]]:
        """Force-close leftovers (flagged ``unclosed``) and write the file.

        Returns the event list so in-process callers can skip the file
        round-trip.  Idempotent on the file: a second close rewrites it.
        """
        for span in list(self._open.values()):
            span.args["unclosed"] = True
            self.end(span)
        events = self.events
        if self.path is not None:
            with open(self.path, "w") as fh:
                fh.write("[\n")
                fh.write(",\n".join(_ENCODE(ev) for ev in events))
                fh.write("\n]\n")
        return events
