"""Zero-dependency observability layer for the serving stack.

Four seams, all stdlib-only at import time:

- :mod:`repro.obs.clock` — injectable wall-clock (``monotonic`` /
  ``perf_counter`` / ``wall_time``).  Everything in ``src/`` that needs a
  timestamp goes through here (grep-enforced by ``tests/test_compat.py``),
  so tests can swap in a :class:`~repro.obs.clock.VirtualClock` and assert
  latencies deterministically.
- :mod:`repro.obs.trace` — a :class:`~repro.obs.trace.Tracer` emitting
  Chrome ``trace_event`` spans (request lifecycle + per-tick scheduler
  work) to a file that both Perfetto and ``repro.obs.report`` can read.
- :mod:`repro.obs.metrics` — process-wide registry of counters / gauges /
  histograms.  Off by default; every instrument method is a guarded no-op
  when the registry is disabled.
- :mod:`repro.obs.kernels` — records which dispatch path each op resolved
  to, the autotune decisions used, and XLA cost-analysis FLOPs/bytes for
  compiled serving steps.
- :mod:`repro.obs.history` / :mod:`repro.obs.regress` — the performance
  regression sentry: an append-only JSONL store of ``benchmarks/run.py
  --json`` records keyed by env fingerprint, and the noise-aware detector
  ``run.py check`` gates CI on (verdicts ``ok`` / ``regressed`` /
  ``improved`` / ``no-baseline``).

``python -m repro.obs.report trace.json`` renders a tick timeline,
per-request waterfall, and preemption-cause table from a trace file;
``--diff A.json B.json`` compares two traces, and ``python -m
repro.obs.merge`` aligns per-replica traces into one Perfetto view.
"""
from repro.obs import clock, history, kernels, metrics, regress, trace

__all__ = ["clock", "history", "kernels", "metrics", "regress", "trace"]
