"""Process-wide metrics registry: counters, gauges, histograms.

Off by default.  Every instrument method starts with an enabled check, so
with the registry disabled a call costs one attribute load and a branch —
the serving hot path keeps its plain-int counters as the authoritative
source for ``ServeReport`` (those must not change with observability off)
and *mirrors* them into the registry when it is on.

Naming convention: dotted lowercase, ``serving.*`` for single-engine
scheduler metrics, ``serving.r{i}.*`` per replica, ``router.*`` for the
front-end, ``kernels.*`` for dispatch/cost figures.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "_reg", "value")

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self._reg = reg
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        self.value += n


class Gauge:
    """Point-in-time value; tracks min/max so low-water marks survive
    the snapshot."""

    __slots__ = ("name", "_reg", "value", "min", "max")

    def __init__(self, name: str, reg: "Registry"):
        self.name = name
        self._reg = reg
        self.value: Optional[float] = None
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)


class Histogram:
    """Exponential-bucket histogram (base 2 from ``least``), plus exact
    count/sum/min/max."""

    __slots__ = ("name", "_reg", "least", "buckets", "count", "sum",
                 "min", "max")

    NUM_BUCKETS = 24

    def __init__(self, name: str, reg: "Registry", least: float = 1e-4):
        self.name = name
        self._reg = reg
        self.least = least
        self.buckets: List[int] = [0] * (self.NUM_BUCKETS + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if v < self.least:
            idx = 0
        else:
            idx = min(int(math.log2(v / self.least)) + 1, self.NUM_BUCKETS)
        self.buckets[idx] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> Optional[float]:
        """Estimated q-th percentile (0–100) from the exponential buckets.

        Walks the cumulative counts to the bucket holding the q-th sample
        and interpolates linearly inside it; bucket bounds are clamped to
        the exactly-tracked ``min``/``max``, so a single-value histogram
        reports that value exactly and no estimate ever leaves the
        observed range.
        """
        if not self.count:
            return None
        target = max(q / 100.0 * self.count, 1.0)
        cum = 0
        for i, n in enumerate(self.buckets):
            if not n:
                continue
            if cum + n >= target:
                lo = 0.0 if i == 0 else self.least * (2.0 ** (i - 1))
                hi = (self.least if i == 0
                      else self.least * (2.0 ** i))
                lo = max(lo, self.min)
                hi = self.max if i == self.NUM_BUCKETS else min(hi, self.max)
                if hi < lo:
                    hi = lo
                frac = (target - cum) / n
                return lo + frac * (hi - lo)
            cum += n
        return self.max


class Registry:
    """Get-or-create instrument store.

    Instruments can be created while disabled (they just no-op); flipping
    ``enabled`` arms every existing handle — callers never re-fetch.
    """

    def __init__(self):
        self.enabled = False
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, self, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, least: float = 1e-4) -> Histogram:
        return self._get(name, Histogram, least=least)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable dump of every instrument with data."""
        out: Dict[str, Any] = {}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if isinstance(inst, Counter):
                if not inst.value:
                    continue
                out[name] = {"type": "counter", "value": inst.value}
            elif isinstance(inst, Gauge):
                if inst.value is None:
                    continue
                out[name] = {"type": "gauge", "value": inst.value,
                             "min": inst.min, "max": inst.max}
            else:
                if not inst.count:
                    continue
                out[name] = {"type": "histogram", "count": inst.count,
                             "sum": inst.sum, "mean": inst.mean,
                             "min": inst.min, "max": inst.max,
                             "p50": inst.percentile(50),
                             "p95": inst.percentile(95)}
        return out

    def reset(self) -> None:
        self._instruments.clear()


REGISTRY = Registry()


def enable() -> None:
    REGISTRY.enabled = True


def disable() -> None:
    REGISTRY.enabled = False


def enabled() -> bool:
    return REGISTRY.enabled


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str, least: float = 1e-4) -> Histogram:
    return REGISTRY.histogram(name, least)


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
