"""Merge N per-replica trace files into one Perfetto-loadable view.

Each replica's :class:`repro.obs.trace.Tracer` stamps microseconds relative
to its *own* creation, so two files from the same serve disagree about when
"now" started by however long replica construction was staggered.  The
replicas do, however, tick in lockstep (``ReplicaRouter.step`` advances all
of them per router tick), which makes each trace's **first ``tick`` span**
a common fiducial: shifting every file so its first tick starts at t=0
aligns the monotonic clocks without any shared-epoch bookkeeping.  A file
with no tick span (edge: a replica that never ran) falls back to its
earliest timestamp.

pids: the scheduler already stamps ``pid = replica index`` into every
event, so per-replica files written through the router carry distinct pids
and merge untouched.  Files whose pids collide (e.g. two independent
single-replica serves) are re-numbered by input order and get a
``process_name`` metadata event naming the source file, so Perfetto shows
which track came from where.

CLI::

    python -m repro.obs.merge --out merged.json r0.json r1.json ...

Validates the merged result (same structural checks as
``repro.obs.report``) and exits nonzero on problems, like the report CLI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.report import load_trace, validate

# one prebuilt encoder, same rationale as repro.obs.trace
_ENCODE = json.JSONEncoder(separators=(",", ":")).encode


def align_offset(events: Sequence[Dict[str, Any]]) -> float:
    """The timestamp to subtract from ``events``: the first ``tick`` span's
    start, else the earliest timestamp, else 0 (empty trace)."""
    ticks = [ev["ts"] for ev in events
             if ev.get("ph") == "X" and ev.get("name") == "tick"
             and "ts" in ev]
    if ticks:
        return min(ticks)
    stamped = [ev["ts"] for ev in events if "ts" in ev]
    return min(stamped) if stamped else 0.0


def merge_events(traces: Sequence[Sequence[Dict[str, Any]]], *,
                 labels: Optional[Sequence[str]] = None,
                 ) -> List[Dict[str, Any]]:
    """Merge already-loaded event lists: align each on its first tick,
    renumber pids if any two inputs collide, keep every file's events in a
    single time-sorted stream (never negative timestamps)."""
    pid_sets = [{ev.get("pid", 0) for ev in t} for t in traces]
    collide = any(pid_sets[i] & pid_sets[j]
                  for i in range(len(traces)) for j in range(i))
    merged: List[Dict[str, Any]] = []
    for i, events in enumerate(traces):
        off = align_offset(events)
        if collide and labels is not None:
            merged.append({"name": "process_name", "ph": "M", "pid": i,
                           "args": {"name": f"replica {i} ({labels[i]})"}})
        for ev in events:
            ev = dict(ev)
            if "ts" in ev:
                ev["ts"] = round(ev["ts"] - off, 3)
            if collide:
                ev["pid"] = i
            merged.append(ev)
    # a uniform shift keeps the alignment; Perfetto dislikes negative ts
    stamped = [ev["ts"] for ev in merged if "ts" in ev]
    if stamped and min(stamped) < 0:
        lift = -min(stamped)
        for ev in merged:
            if "ts" in ev:
                ev["ts"] = round(ev["ts"] + lift, 3)
    merged.sort(key=lambda ev: ev.get("ts", -1.0))
    return merged


def merge_traces(paths: Sequence[str],
                 out: Optional[str] = None) -> List[Dict[str, Any]]:
    """Load, align, and merge trace files; optionally write the merged
    array to ``out`` in the same one-event-per-line form ``Tracer.close``
    uses (``json.load``-able AND line-parseable)."""
    if not paths:
        raise ValueError("merge_traces needs at least one trace file")
    traces = [load_trace(p) for p in paths]
    merged = merge_events(traces,
                          labels=[os.path.basename(p) for p in paths])
    if out:
        with open(out, "w") as fh:
            fh.write("[\n")
            fh.write(",\n".join(_ENCODE(ev) for ev in merged))
            fh.write("\n]\n")
    return merged


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.merge",
        description="Merge per-replica trace files into one "
                    "Perfetto-loadable file (first-tick clock alignment).")
    ap.add_argument("traces", nargs="+", metavar="TRACE.json",
                    help="per-replica trace_event files, replica order")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write the merged JSON array here")
    args = ap.parse_args(argv)
    merged = merge_traces(args.traces, out=args.out)
    pids = sorted({ev.get("pid", 0) for ev in merged})
    print(f"merged {len(args.traces)} traces → {len(merged)} events, "
          f"pids {pids}" + (f" → {args.out}" if args.out else ""))
    problems = validate(merged)
    for p in problems:
        print(f"  - {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
