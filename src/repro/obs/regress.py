"""Noise-aware benchmark regression detection over the history store.

The problem: one-shot timing comparisons on a shared CI box are noise.
Contention adds ±5–8% per run (measured in ``benchmarks/bench_serving.py``'s
overhead harness), and serving rows swing wider still — an eyeballed diff of
two result files cannot tell a kernel regression from a noisy neighbour.

The approach, per row:

* **Window** — the last K ``us_per_call`` samples for this row from history
  records whose env fingerprint matches the candidate's (different backend /
  jax version / device count / smoke flag → different window; see
  :mod:`repro.obs.history`).  Fewer than ``min_records`` samples →
  ``no-baseline`` (never a gate failure: a fresh environment starts by
  recording, not by failing).
* **Baseline** — two estimates of the window.  The *median* is the robust
  center reported to humans.  The *fastest-half mean* is what the gate
  compares against: contention noise is strictly additive (a neighbour only
  ever slows a run down), so the mean of the window's fastest half
  approaches the uncontended cost while keeping enough samples that one
  lucky run cannot swing it — the same estimator the ``--obs`` overhead
  bench uses, shared here as :func:`fastest_half_mean`.
* **Verdict** — relative delta of the candidate against the fastest-half
  mean, judged against a per-row threshold (longest-prefix match in
  :data:`THRESHOLDS`; serving rows get a wider band than kernel
  microbenches).  ``regressed`` above ``+threshold``, ``improved`` below
  ``-threshold``, ``ok`` between.

``benchmarks/run.py check`` renders the verdicts (markdown, the same style
as ``run.py report``) and exits nonzero iff anything regressed — the CI
gate the ROADMAP's measurement surface was missing.

Stdlib-only: no jax at import time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

from repro.obs.history import HistoryStore, fingerprint

OK = "ok"
REGRESSED = "regressed"
IMPROVED = "improved"
NO_BASELINE = "no-baseline"

DEFAULT_K = 5               # baseline window: last K same-env samples
DEFAULT_MIN_RECORDS = 2     # fewer → no-baseline
DEFAULT_THRESHOLD = 0.25    # relative band for kernel microbenches

# per-row relative thresholds, longest matching prefix wins; the fallback
# is DEFAULT_THRESHOLD.  Serving rows aggregate a whole scheduler run on a
# contended box, so their band is wider than the microbench rows'.
THRESHOLDS: Sequence = (
    ("serving/", 0.50),
)


def fastest_half_mean(values: Sequence[float], *,
                      bigger_is_faster: bool = False) -> float:
    """Mean of the fastest half of ``values`` (at least one kept).

    For µs-per-call series "fastest" means smallest; rate series
    (tokens/s) pass ``bigger_is_faster=True``.  Additive-noise estimator:
    the fastest runs approach the uncontended cost, and averaging half the
    samples (rather than taking the single min) keeps one lucky run from
    deciding the number.
    """
    if not values:
        raise ValueError("fastest_half_mean of an empty sequence")
    ordered = sorted(values, reverse=bigger_is_faster)
    top = ordered[:max(len(ordered) // 2, 1)]
    return sum(top) / len(top)


def median(values: Sequence[float]) -> float:
    if not values:
        raise ValueError("median of an empty sequence")
    s = sorted(values)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def threshold_for(name: str,
                  overrides: Optional[Sequence] = None) -> float:
    """Relative threshold for row ``name``: longest matching prefix in
    ``overrides`` (default :data:`THRESHOLDS`), else
    :data:`DEFAULT_THRESHOLD`."""
    best, best_len = DEFAULT_THRESHOLD, -1
    for prefix, thr in (THRESHOLDS if overrides is None else overrides):
        if name.startswith(prefix) and len(prefix) > best_len:
            best, best_len = float(thr), len(prefix)
    return best


@dataclass
class RowVerdict:
    """One row's comparison against its same-env baseline window."""

    name: str
    verdict: str                       # ok / regressed / improved / no-baseline
    candidate_us: float
    baseline_us: Optional[float] = None   # fastest-half mean (the gate side)
    median_us: Optional[float] = None     # robust center (the human side)
    delta_pct: Optional[float] = None     # candidate vs baseline_us
    threshold_pct: float = DEFAULT_THRESHOLD * 100.0
    window: int = 0                    # samples behind the baseline


def check_rows(rows: Iterable, store: HistoryStore, env: Dict, *,
               smoke: bool = False, k: int = DEFAULT_K,
               min_records: int = DEFAULT_MIN_RECORDS,
               threshold: Optional[float] = None) -> List[RowVerdict]:
    """Compare candidate ``rows`` (dicts or ``(name, us, derived)`` tuples)
    against ``store``'s same-fingerprint window.  ``threshold`` overrides
    the per-row prefix table with one global relative band."""
    fp = fingerprint(env, smoke=smoke)
    verdicts = []
    for row in rows:
        if isinstance(row, dict):
            name, us = str(row["name"]), float(row["us_per_call"])
        else:
            name, us = str(row[0]), float(row[1])
        thr = threshold if threshold is not None else threshold_for(name)
        values = store.samples(name, fp, k=k)
        if len(values) < min_records:
            verdicts.append(RowVerdict(
                name=name, verdict=NO_BASELINE, candidate_us=us,
                threshold_pct=thr * 100.0, window=len(values)))
            continue
        base = fastest_half_mean(values)
        med = median(values)
        delta = (us - base) / base if base else float("inf")
        if delta > thr:
            verdict = REGRESSED
        elif delta < -thr:
            verdict = IMPROVED
        else:
            verdict = OK
        verdicts.append(RowVerdict(
            name=name, verdict=verdict, candidate_us=us, baseline_us=base,
            median_us=med, delta_pct=delta * 100.0,
            threshold_pct=thr * 100.0, window=len(values)))
    return verdicts


def regressions(verdicts: Iterable[RowVerdict]) -> List[RowVerdict]:
    return [v for v in verdicts if v.verdict == REGRESSED]


def render(verdicts: Sequence[RowVerdict], *, fp: str = "") -> str:
    """Markdown verdict table (the ``run.py report`` house style), plus one
    named ``REGRESSION:`` line per offending row so a CI log grep finds
    the culprit without parsing the table."""
    lines = [f"## Regression check — {len(verdicts)} rows"
             + (f" (fingerprint {fp})" if fp else ""), ""]
    lines += ["| name | baseline µs | median µs | candidate µs | Δ% "
              "| thr % | n | verdict |",
              "|---|---:|---:|---:|---:|---:|---:|---|"]
    for v in verdicts:
        base = f"{v.baseline_us:.2f}" if v.baseline_us is not None else "—"
        med = f"{v.median_us:.2f}" if v.median_us is not None else "—"
        delta = f"{v.delta_pct:+.1f}%" if v.delta_pct is not None else "—"
        lines.append(f"| {v.name} | {base} | {med} | {v.candidate_us:.2f} "
                     f"| {delta} | {v.threshold_pct:.0f} | {v.window} "
                     f"| {v.verdict} |")
    counts: Dict[str, int] = {}
    for v in verdicts:
        counts[v.verdict] = counts.get(v.verdict, 0) + 1
    lines += ["", "check: " + ", ".join(
        f"{counts.get(k, 0)} {k}"
        for k in (OK, IMPROVED, NO_BASELINE, REGRESSED))]
    for v in regressions(verdicts):
        lines.append(f"REGRESSION: {v.name} {v.delta_pct:+.1f}% over "
                     f"baseline {v.baseline_us:.2f}µs "
                     f"(threshold {v.threshold_pct:.0f}%)")
    return "\n".join(lines) + "\n"


__all__ = ["RowVerdict", "check_rows", "regressions", "render",
           "fastest_half_mean", "median", "threshold_for",
           "OK", "REGRESSED", "IMPROVED", "NO_BASELINE",
           "DEFAULT_K", "DEFAULT_MIN_RECORDS", "DEFAULT_THRESHOLD",
           "THRESHOLDS"]
