"""Kernel profiling hooks: dispatch paths, autotune decisions, XLA costs.

``kernels/dispatch.py`` calls :func:`record_path` when an op resolves and
:func:`record_autotune` when an autotune decision is used; the serving
layer calls :func:`profile_jitted` (gated behind :func:`enable_profiling`)
to attach ``compat.cost_analysis`` FLOPs/bytes to its compiled step.  All
recording is plain-dict bookkeeping — no jax import at module load — so
the hooks cost nothing measurable on the dispatch fast path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.obs import metrics as _metrics

_paths: Dict[str, Dict[str, Any]] = {}
_autotune: Dict[str, Dict[str, Any]] = {}
_costs: Dict[str, Dict[str, float]] = {}
_profiling = False


def enable_profiling() -> None:
    """Arm :func:`profile_jitted` (cost analysis forces a compile, so it
    is opt-in even when metrics are on)."""
    global _profiling
    _profiling = True


def disable_profiling() -> None:
    global _profiling
    _profiling = False


def profiling_enabled() -> bool:
    return _profiling


def record_path(op: str, path: str, *, prefer_pallas: bool = False) -> None:
    """An op resolved to a dispatch path ('pallas' / 'interpret' / 'xla')."""
    entry = _paths.setdefault(op, {"path": path, "count": 0,
                                   "prefer_pallas": prefer_pallas})
    entry["path"] = path
    entry["prefer_pallas"] = prefer_pallas
    entry["count"] += 1


def record_autotune(kind: str, key: Any, decision: Dict[str, Any]) -> None:
    """An autotune decision (cached or freshly swept) was used."""
    _autotune[f"{kind}/{key}"] = dict(decision)


def record_cost(label: str, analysis: Optional[Dict[str, Any]]) -> None:
    """Store normalized FLOPs / bytes for a compiled computation."""
    if not analysis:
        return
    flops = float(analysis.get("flops", 0.0) or 0.0)
    nbytes = float(analysis.get("bytes accessed", 0.0) or 0.0)
    _costs[label] = {"flops": flops, "bytes_accessed": nbytes}
    if _metrics.enabled():
        _metrics.gauge(f"kernels.{label}.flops").set(flops)
        _metrics.gauge(f"kernels.{label}.bytes_accessed").set(nbytes)


def profile_jitted(fn, label: str, *args, **kwargs) -> Optional[Dict[str, float]]:
    """Cost-analyze a jitted callable on the given example args.

    Lowering+compiling can be expensive and may hit paths XLA's analysis
    does not support, so this never raises — failures record nothing.
    Returns the stored cost dict, or None.
    """
    if not _profiling:
        return None
    try:
        from repro import compat
        compiled = fn.lower(*args, **kwargs).compile()
        record_cost(label, compat.cost_analysis(compiled))
    except Exception:
        return None
    return _costs.get(label)


def snapshot() -> Dict[str, Any]:
    return {
        "paths": {k: dict(v) for k, v in sorted(_paths.items())},
        "autotune": {k: dict(v) for k, v in sorted(_autotune.items())},
        "costs": {k: dict(v) for k, v in sorted(_costs.items())},
    }


def reset() -> None:
    _paths.clear()
    _autotune.clear()
    _costs.clear()
