"""Render a serving trace: tick timeline, request waterfall, causes.

Usage::

    python -m repro.obs.report trace.json

The input is the JSON-array trace_event file written by
:class:`repro.obs.trace.Tracer` (also line-parseable — see that module).
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Optional

# Request-lifecycle phases in waterfall order, with 1-char bar glyphs.
_PHASES = ("queued", "prefill", "decode", "suspended")
_GLYPH = {"queued": ".", "prefill": "=", "decode": "#", "suspended": "~"}


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Read a trace file; tolerates both the array form and bare JSONL."""
    with open(path) as fh:
        text = fh.read()
    try:
        data = json.loads(text)
        if isinstance(data, list):
            return data
    except json.JSONDecodeError:
        pass
    events = []
    for line in text.splitlines():
        line = line.strip().rstrip(",")
        if not line or line in ("[", "]"):
            continue
        events.append(json.loads(line))
    return events


def validate(events: List[Dict[str, Any]]) -> List[str]:
    """Structural checks; returns a list of problems (empty == clean).

    - no span was force-closed (``unclosed`` flag from ``Tracer.close``)
    - complete spans on each (pid, tid) track nest properly: a span that
      starts inside another must end inside it too.
    """
    problems = []
    tracks: Dict[Any, List[Dict[str, Any]]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        if ev.get("args", {}).get("unclosed"):
            problems.append(f"unclosed span {ev['name']!r} on "
                            f"pid={ev.get('pid')} tid={ev.get('tid')}")
        tracks.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
    for (pid, tid), spans in tracks.items():
        spans.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack: List[Dict[str, Any]] = []
        for ev in spans:
            start, end = ev["ts"], ev["ts"] + ev.get("dur", 0.0)
            while stack and start >= stack[-1]["ts"] + stack[-1]["dur"] - 1e-6:
                stack.pop()
            if stack:
                outer_end = stack[-1]["ts"] + stack[-1]["dur"]
                if end > outer_end + 1e-6:
                    problems.append(
                        f"span {ev['name']!r} overlaps {stack[-1]['name']!r} "
                        f"without nesting (pid={pid} tid={tid})")
            stack.append(ev)
    return problems


def _request_rows(events):
    """Aggregate per-request phase totals + lifecycle instants."""
    names = {}            # (pid, tid) -> track label
    rows: Dict[Any, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    for ev in events:
        pid, tid = ev.get("pid", 0), ev.get("tid", 0)
        if tid == 0:      # scheduler track
            continue
        key = (pid, tid)
        row = rows.setdefault(key, {
            "label": names.get(key, f"pid{pid}/tid{tid}"),
            "phase_ms": {p: 0.0 for p in _PHASES},
            "segments": [], "tokens": 0, "preempts": 0,
            "retire": None, "start": None, "end": None,
        })
        ts = ev.get("ts", 0.0)
        if ev.get("ph") == "X":
            name, dur = ev["name"], ev.get("dur", 0.0)
            if name in row["phase_ms"]:
                row["phase_ms"][name] += dur / 1000.0
                row["segments"].append((ts, dur, name))
            row["start"] = ts if row["start"] is None else min(row["start"], ts)
            row["end"] = max(row["end"] or 0.0, ts + dur)
        elif ev.get("ph") == "i":
            if ev["name"] == "token":
                row["tokens"] += 1
            elif ev["name"] == "preempt":
                row["preempts"] += 1
            elif ev["name"] == "retire":
                row["retire"] = ev.get("args", {}).get("cause", "?")
    return rows


def summarize(events: List[Dict[str, Any]], *, width: int = 48,
              max_ticks: int = 40) -> str:
    out: List[str] = []
    problems = validate(events)
    if problems:
        out.append("TRACE PROBLEMS:")
        out.extend(f"  - {p}" for p in problems)

    # --- tick timeline --------------------------------------------------
    ticks = [ev for ev in events
             if ev.get("ph") == "X" and ev["name"] == "tick"]
    counters = [ev for ev in events
                if ev.get("ph") == "C" and ev["name"] == "sched"]
    out.append(f"tick timeline ({len(ticks)} ticks)")
    shown = ticks[:max_ticks]
    gauges = {round(c["ts"], 1): c["args"] for c in counters}
    for i, ev in enumerate(shown):
        args = ev.get("args", {})
        # nearest counter emitted at/after this tick's start
        g = args or {}
        for ts, vals in gauges.items():
            if ts >= ev["ts"] - 1.0:
                g = {**vals, **args}
                break
        extras = " ".join(f"{k}={g[k]}" for k in ("active", "queue",
                                                  "free_slots") if k in g)
        out.append(f"  tick {args.get('tick', i):>4}  "
                   f"dur={ev.get('dur', 0.0) / 1000.0:8.3f}ms  {extras}")
    if len(ticks) > max_ticks:
        out.append(f"  ... {len(ticks) - max_ticks} more ticks")

    # --- per-request waterfall ------------------------------------------
    rows = _request_rows(events)
    starts = [r["start"] for r in rows.values() if r["start"] is not None]
    ends = [r["end"] for r in rows.values() if r["end"] is not None]
    if rows and starts and ends:
        span_start, span_end = min(starts), max(ends)
        scale = width / max(span_end - span_start, 1e-9)
        out.append("")
        out.append("request waterfall "
                   "(.=queued ==prefill #=decode ~=suspended)")
        for key in sorted(rows):
            row = rows[key]
            bar = [" "] * width
            for ts, dur, name in row["segments"]:
                lo = int((ts - span_start) * scale)
                hi = max(int((ts + dur - span_start) * scale), lo + 1)
                for j in range(lo, min(hi, width)):
                    bar[j] = _GLYPH[name]
            ph = row["phase_ms"]
            out.append(
                f"  {row['label']:>8} |{''.join(bar)}| "
                f"queued={ph['queued']:.1f}ms prefill={ph['prefill']:.1f}ms "
                f"decode={ph['decode']:.1f}ms tokens={row['tokens']}")

        # --- cause table ------------------------------------------------
        causes: Dict[str, int] = {}
        preempted = 0
        for row in rows.values():
            preempted += row["preempts"]
            if row["retire"]:
                causes[row["retire"]] = causes.get(row["retire"], 0) + 1
        out.append("")
        out.append("retire causes: " + (", ".join(
            f"{k}={v}" for k, v in sorted(causes.items())) or "none"))
        out.append(f"preemptions: {preempted}")
    if not problems:
        out.append("trace OK: all spans closed and nested")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.obs.report trace.json",
              file=sys.stderr)
        return 2
    events = load_trace(argv[0])
    print(summarize(events))
    return 1 if validate(events) else 0


if __name__ == "__main__":
    raise SystemExit(main())
