"""Render a serving trace: tick timeline, request waterfall, causes.

Usage::

    python -m repro.obs.report trace.json
    python -m repro.obs.report --diff before.json after.json [--out d.md]

The input is the JSON-array trace_event file written by
:class:`repro.obs.trace.Tracer` (also line-parseable — see that module).

``--diff`` compares two traces the way ``benchmarks/run.py report`` diffs
two benchmark JSONs: an aligned tick timeline (tick k of A against tick k
of B), per-phase queued/prefill/decode/suspended totals, and per-request-
class latency deltas, rendered as the same markdown table style so a diff
can be pasted into EXPERIMENTS.md next to the benchmark diffs.

Exit codes gate CI: 0 clean, 1 when structural validation fails on any
input (unclosed spans, bad nesting — the problems are printed either way),
2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

# Request-lifecycle phases in waterfall order, with 1-char bar glyphs.
_PHASES = ("queued", "prefill", "decode", "suspended")
_GLYPH = {"queued": ".", "prefill": "=", "decode": "#", "suspended": "~"}


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Read a trace file; tolerates both the array form and bare JSONL."""
    with open(path) as fh:
        text = fh.read()
    try:
        data = json.loads(text)
        if isinstance(data, list):
            return data
    except json.JSONDecodeError:
        pass
    events = []
    for line in text.splitlines():
        line = line.strip().rstrip(",")
        if not line or line in ("[", "]"):
            continue
        events.append(json.loads(line))
    return events


def validate(events: List[Dict[str, Any]]) -> List[str]:
    """Structural checks; returns a list of problems (empty == clean).

    - no span was force-closed (``unclosed`` flag from ``Tracer.close``)
    - complete spans on each (pid, tid) track nest properly: a span that
      starts inside another must end inside it too.
    """
    problems = []
    tracks: Dict[Any, List[Dict[str, Any]]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        if ev.get("args", {}).get("unclosed"):
            problems.append(f"unclosed span {ev['name']!r} on "
                            f"pid={ev.get('pid')} tid={ev.get('tid')}")
        tracks.setdefault((ev.get("pid"), ev.get("tid")), []).append(ev)
    for (pid, tid), spans in tracks.items():
        spans.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        stack: List[Dict[str, Any]] = []
        for ev in spans:
            start, end = ev["ts"], ev["ts"] + ev.get("dur", 0.0)
            while stack and start >= stack[-1]["ts"] + stack[-1]["dur"] - 1e-6:
                stack.pop()
            if stack:
                outer_end = stack[-1]["ts"] + stack[-1]["dur"]
                if end > outer_end + 1e-6:
                    problems.append(
                        f"span {ev['name']!r} overlaps {stack[-1]['name']!r} "
                        f"without nesting (pid={pid} tid={tid})")
            stack.append(ev)
    return problems


def _request_rows(events):
    """Aggregate per-request phase totals + lifecycle instants."""
    names = {}            # (pid, tid) -> track label
    rows: Dict[Any, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    for ev in events:
        pid, tid = ev.get("pid", 0), ev.get("tid", 0)
        if tid == 0:      # scheduler track
            continue
        key = (pid, tid)
        row = rows.setdefault(key, {
            "label": names.get(key, f"pid{pid}/tid{tid}"),
            "phase_ms": {p: 0.0 for p in _PHASES},
            "segments": [], "tokens": 0, "preempts": 0,
            "retire": None, "start": None, "end": None, "priority": 0,
        })
        ts = ev.get("ts", 0.0)
        if ev.get("ph") == "X":
            name, dur = ev["name"], ev.get("dur", 0.0)
            if name == "queued":
                row["priority"] = ev.get("args", {}).get("priority", 0)
            if name in row["phase_ms"]:
                row["phase_ms"][name] += dur / 1000.0
                row["segments"].append((ts, dur, name))
            row["start"] = ts if row["start"] is None else min(row["start"], ts)
            row["end"] = max(row["end"] or 0.0, ts + dur)
        elif ev.get("ph") == "i":
            if ev["name"] == "token":
                row["tokens"] += 1
            elif ev["name"] == "preempt":
                row["preempts"] += 1
            elif ev["name"] == "retire":
                row["retire"] = ev.get("args", {}).get("cause", "?")
    return rows


def summarize(events: List[Dict[str, Any]], *, width: int = 48,
              max_ticks: int = 40) -> str:
    out: List[str] = []
    problems = validate(events)
    if problems:
        out.append("TRACE PROBLEMS:")
        out.extend(f"  - {p}" for p in problems)

    # --- tick timeline --------------------------------------------------
    ticks = [ev for ev in events
             if ev.get("ph") == "X" and ev["name"] == "tick"]
    counters = [ev for ev in events
                if ev.get("ph") == "C" and ev["name"] == "sched"]
    out.append(f"tick timeline ({len(ticks)} ticks)")
    shown = ticks[:max_ticks]
    gauges = {round(c["ts"], 1): c["args"] for c in counters}
    for i, ev in enumerate(shown):
        args = ev.get("args", {})
        # nearest counter emitted at/after this tick's start
        g = args or {}
        for ts, vals in gauges.items():
            if ts >= ev["ts"] - 1.0:
                g = {**vals, **args}
                break
        extras = " ".join(f"{k}={g[k]}" for k in ("active", "queue",
                                                  "free_slots") if k in g)
        out.append(f"  tick {args.get('tick', i):>4}  "
                   f"dur={ev.get('dur', 0.0) / 1000.0:8.3f}ms  {extras}")
    if len(ticks) > max_ticks:
        out.append(f"  ... {len(ticks) - max_ticks} more ticks")

    # --- per-request waterfall ------------------------------------------
    rows = _request_rows(events)
    starts = [r["start"] for r in rows.values() if r["start"] is not None]
    ends = [r["end"] for r in rows.values() if r["end"] is not None]
    if rows and starts and ends:
        span_start, span_end = min(starts), max(ends)
        scale = width / max(span_end - span_start, 1e-9)
        out.append("")
        out.append("request waterfall "
                   "(.=queued ==prefill #=decode ~=suspended)")
        for key in sorted(rows):
            row = rows[key]
            bar = [" "] * width
            for ts, dur, name in row["segments"]:
                lo = int((ts - span_start) * scale)
                hi = max(int((ts + dur - span_start) * scale), lo + 1)
                for j in range(lo, min(hi, width)):
                    bar[j] = _GLYPH[name]
            ph = row["phase_ms"]
            out.append(
                f"  {row['label']:>8} |{''.join(bar)}| "
                f"queued={ph['queued']:.1f}ms prefill={ph['prefill']:.1f}ms "
                f"decode={ph['decode']:.1f}ms tokens={row['tokens']}")

        # --- cause table ------------------------------------------------
        causes: Dict[str, int] = {}
        preempted = 0
        for row in rows.values():
            preempted += row["preempts"]
            if row["retire"]:
                causes[row["retire"]] = causes.get(row["retire"], 0) + 1
        out.append("")
        out.append("retire causes: " + (", ".join(
            f"{k}={v}" for k, v in sorted(causes.items())) or "none"))
        out.append(f"preemptions: {preempted}")
    if not problems:
        out.append("trace OK: all spans closed and nested")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Trace diff: two traces, one markdown comparison.
# ---------------------------------------------------------------------------
def _tick_durs_ms(events) -> List[float]:
    """Tick durations in ms, in tick order."""
    ticks = [(ev.get("args", {}).get("tick", i), ev.get("dur", 0.0) / 1000.0)
             for i, ev in enumerate(events)
             if ev.get("ph") == "X" and ev.get("name") == "tick"]
    return [d for _, d in sorted(ticks, key=lambda t: t[0])]


def _percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (matches ``ServeReport``'s convention of
    never interpolating across raw samples)."""
    s = sorted(values)
    idx = min(int(round(q / 100.0 * (len(s) - 1))), len(s) - 1)
    return s[idx]


def _trace_stats(events) -> Dict[str, Any]:
    """The comparable aggregates of one trace."""
    rows = _request_rows(events)
    ticks = _tick_durs_ms(events)
    phases = {p: sum(r["phase_ms"][p] for r in rows.values())
              for p in _PHASES}
    classes: Dict[int, List[float]] = {}
    class_tokens: Dict[int, int] = {}
    for r in rows.values():
        if r["start"] is None or r["end"] is None:
            continue
        cls = r["priority"]
        classes.setdefault(cls, []).append((r["end"] - r["start"]) / 1000.0)
        class_tokens[cls] = class_tokens.get(cls, 0) + r["tokens"]
    return {
        "ticks": len(ticks),
        "tick_total_ms": sum(ticks),
        "tick_mean_ms": sum(ticks) / len(ticks) if ticks else 0.0,
        "tick_durs": ticks,
        "requests": len(rows),
        "tokens": sum(r["tokens"] for r in rows.values()),
        "preemptions": sum(r["preempts"] for r in rows.values()),
        "phases": phases,
        "classes": classes,
        "class_tokens": class_tokens,
    }


def _delta(a: float, b: float) -> str:
    if a == 0.0:
        return "—" if b == 0.0 else "+∞"
    return f"{(b - a) / a * 100.0:+.1f}%"


def diff(events_a, events_b, label_a: str = "A", label_b: str = "B", *,
         max_ticks: int = 40) -> str:
    """Markdown comparison of two traces (``run.py report`` house style):
    headline aggregates, the tick timeline aligned by tick index, and
    per-request-class latency deltas."""
    sa, sb = _trace_stats(events_a), _trace_stats(events_b)
    lines = [f"## Trace diff — {label_a} → {label_b}", ""]
    lines += [f"| metric | {label_a} | {label_b} | Δ% |",
              "|---|---:|---:|---:|"]
    scalar_rows: List[Tuple[str, float, float, str]] = [
        ("ticks", sa["ticks"], sb["ticks"], "d"),
        ("tick total ms", sa["tick_total_ms"], sb["tick_total_ms"], "f"),
        ("tick mean ms", sa["tick_mean_ms"], sb["tick_mean_ms"], "f"),
        ("requests", sa["requests"], sb["requests"], "d"),
        ("tokens", sa["tokens"], sb["tokens"], "d"),
        ("preemptions", sa["preemptions"], sb["preemptions"], "d"),
    ]
    for p in _PHASES:
        scalar_rows.append((f"{p} ms (Σ requests)",
                            sa["phases"][p], sb["phases"][p], "f"))
    for name, va, vb, kind in scalar_rows:
        fmt = (lambda v: f"{v:.0f}") if kind == "d" else (
            lambda v: f"{v:.3f}")
        lines.append(f"| {name} | {fmt(va)} | {fmt(vb)} | {_delta(va, vb)} |")

    # --- aligned tick timeline ------------------------------------------
    da, db = sa["tick_durs"], sb["tick_durs"]
    n = max(len(da), len(db))
    lines += ["", "### Aligned tick timeline (by tick index)", "",
              f"| tick | {label_a} ms | {label_b} ms | Δ% |",
              "|---:|---:|---:|---:|"]
    for i in range(min(n, max_ticks)):
        va = da[i] if i < len(da) else None
        vb = db[i] if i < len(db) else None
        fa = f"{va:.3f}" if va is not None else "—"
        fb = f"{vb:.3f}" if vb is not None else "—"
        d = _delta(va, vb) if va is not None and vb is not None else "—"
        lines.append(f"| {i} | {fa} | {fb} | {d} |")
    if n > max_ticks:
        lines.append(f"| … | {max(len(da) - max_ticks, 0)} more "
                     f"| {max(len(db) - max_ticks, 0)} more | |")

    # --- per-request-class latency deltas -------------------------------
    all_classes = sorted(set(sa["classes"]) | set(sb["classes"]))
    if all_classes:
        lines += ["", "### Per-request-class latency (request lifetime, "
                  "arrival → last span)", "",
                  f"| class | n {label_a}→{label_b} "
                  f"| mean ms {label_a} | mean ms {label_b} | Δ% "
                  f"| p95 ms {label_a} | p95 ms {label_b} | Δ% "
                  f"| tokens {label_a}→{label_b} |",
                  "|---:|---|---:|---:|---:|---:|---:|---:|---|"]
        for cls in all_classes:
            la = sa["classes"].get(cls, [])
            lb = sb["classes"].get(cls, [])
            if la and lb:
                ma, mb = sum(la) / len(la), sum(lb) / len(lb)
                pa, pb = _percentile(la, 95), _percentile(lb, 95)
                lines.append(
                    f"| {cls} | {len(la)}→{len(lb)} | {ma:.3f} | {mb:.3f} "
                    f"| {_delta(ma, mb)} | {pa:.3f} | {pb:.3f} "
                    f"| {_delta(pa, pb)} "
                    f"| {sa['class_tokens'].get(cls, 0)}"
                    f"→{sb['class_tokens'].get(cls, 0)} |")
            else:
                side = label_b if lb else label_a
                lines.append(f"| {cls} | {len(la)}→{len(lb)} | — | — | — "
                             f"| — | — | — | only in {side} |")
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize one trace, or --diff two.  Exits 1 when "
                    "structural validation fails on any input.")
    ap.add_argument("trace", nargs="?", metavar="trace.json",
                    help="trace file to summarize")
    ap.add_argument("--diff", nargs=2, metavar=("A.json", "B.json"),
                    default=None,
                    help="compare two traces (aligned ticks, phase totals, "
                         "per-class latency deltas) instead of summarizing")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="also write the diff markdown to PATH")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)
    if (args.trace is None) == (args.diff is None):
        ap.print_usage(sys.stderr)
        print("error: pass exactly one of trace.json or --diff A B",
              file=sys.stderr)
        return 2

    if args.diff is None:
        events = load_trace(args.trace)
        print(summarize(events))
        problems = validate(events)
        for p in problems:
            print(f"TRACE PROBLEM: {p}", file=sys.stderr)
        return 1 if problems else 0

    path_a, path_b = args.diff
    events_a, events_b = load_trace(path_a), load_trace(path_b)
    problems = []
    for path, events in ((path_a, events_a), (path_b, events_b)):
        problems += [f"{path}: {p}" for p in validate(events)]
    text = diff(events_a, events_b, label_a=path_a, label_b=path_b)
    sys.stdout.write(text)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text)
    for p in problems:
        print(f"TRACE PROBLEM: {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
