"""The process-wide clock seam.

All wall-clock reads in ``src/`` route through this module (the policy is
grep-enforced by ``tests/test_compat.py``): production code calls
:func:`monotonic` / :func:`perf_counter` / :func:`wall_time`, tests install
a :class:`VirtualClock` via :func:`set_clock` and advance it explicitly so
latency and phase assertions are exact instead of sleep-and-hope.
"""
from __future__ import annotations

import time as _time


class Clock:
    """Interface: three time sources, mirroring the stdlib names."""

    def monotonic(self) -> float:
        raise NotImplementedError

    def perf_counter(self) -> float:
        raise NotImplementedError

    def wall_time(self) -> float:
        """Epoch seconds (``time.time`` equivalent)."""
        raise NotImplementedError


class SystemClock(Clock):
    """The real thing — thin pass-through to :mod:`time`."""

    def monotonic(self) -> float:
        return _time.monotonic()

    def perf_counter(self) -> float:
        return _time.perf_counter()

    def wall_time(self) -> float:
        return _time.time()


class VirtualClock(Clock):
    """Deterministic clock for tests: time moves only via :meth:`advance`.

    All three sources read the same counter, so a span's monotonic
    duration and its wall timestamp agree exactly.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a clock backwards (dt={dt})")
        self._now += dt
        return self._now

    def monotonic(self) -> float:
        return self._now

    def perf_counter(self) -> float:
        return self._now

    def wall_time(self) -> float:
        return self._now


_current: Clock = SystemClock()


def get() -> Clock:
    """The currently installed process-wide clock."""
    return _current


def set_clock(clock: Clock) -> Clock:
    """Install ``clock`` process-wide; returns the previous one so tests
    can restore it in a ``finally``."""
    global _current
    prev = _current
    _current = clock
    return prev


def monotonic() -> float:
    return _current.monotonic()


def perf_counter() -> float:
    return _current.perf_counter()


def wall_time() -> float:
    return _current.wall_time()
