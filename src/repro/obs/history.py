"""Append-only benchmark history store: JSONL of ``run.py --json`` results.

One ``benchmarks/run.py --json`` run produces one *record* — its rows plus
the probed backend capabilities — and :class:`HistoryStore` appends it as a
single JSON line.  The store is the longitudinal memory the one-shot result
files lack: ``run.py check`` (see :mod:`repro.obs.regress`) compares a fresh
run against the last K records taken **on the same environment** and gates
CI on the verdict.

Env fingerprinting is the load-bearing part.  Bandwidth-bound comparisons
flip with problem size and hardware (the Two-Pass Softmax paper, arXiv
2001.04438, documents exactly this for softmax forms), so timings are only
comparable within one ``(backend, jax_version, device_count, pallas_native,
smoke)`` fingerprint — records from a different fingerprint are *invisible*
to the baseline window, never averaged in.

Path resolution: an explicit path beats the ``REPRO_BENCH_HISTORY``
environment variable, which beats the caller-supplied default (``run.py``
passes none for plain ``--json`` runs — recording is opt-in there — and
``bench_history.jsonl`` for ``check``, which exists to read one).

The file is append-only and tolerant: lines that do not parse (a crashed
writer, a merge artifact, a foreign schema) are counted in
:attr:`HistoryStore.skipped` and skipped, never fatal — a corrupt line
must not be able to take down the CI gate.

Stdlib-only, like every ``repro.obs`` module: no jax at import time.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional

from repro.obs import clock as _clock

HISTORY_ENV = "REPRO_BENCH_HISTORY"
DEFAULT_PATH = "bench_history.jsonl"
SCHEMA_VERSION = 1

# capability fields that shift timings: two records compare only when all
# of these (plus the smoke flag) agree
ENV_FIELDS = ("backend", "jax_version", "device_count", "pallas_native")


def history_path(explicit: Optional[str] = None, *,
                 default: Optional[str] = None) -> Optional[str]:
    """Resolve the store path: ``explicit`` → ``$REPRO_BENCH_HISTORY`` →
    ``default`` (``None`` means "no store": recording is skipped)."""
    if explicit:
        return explicit
    env = os.environ.get(HISTORY_ENV)
    if env:
        return env
    return default


def fingerprint(env: Dict[str, Any], *, smoke: bool = False) -> str:
    """Stable comparison key for an env/capability record.  Only records
    with an identical fingerprint feed a row's baseline window."""
    parts = [f"smoke={bool(smoke)}"]
    parts += [f"{k}={env.get(k)}" for k in ENV_FIELDS]
    return "|".join(parts)


def _normalize_rows(rows: Iterable) -> List[Dict[str, Any]]:
    """Accept both the ``--json`` dict form and the in-process
    ``(name, us, derived)`` tuple form."""
    out = []
    for r in rows:
        if isinstance(r, dict):
            out.append({"name": str(r["name"]),
                        "us_per_call": float(r["us_per_call"]),
                        "derived": str(r.get("derived") or "")})
        else:
            name, us, derived = r
            out.append({"name": str(name), "us_per_call": float(us),
                        "derived": str(derived)})
    return out


class HistoryStore:
    """One JSONL file of benchmark records, append-only.

    ``append`` writes one line per run; ``records`` reads them all back in
    file order (oldest first), skipping anything unparseable; ``samples``
    extracts one row's timing series for a given fingerprint — the input
    :mod:`repro.obs.regress` builds baselines from.
    """

    def __init__(self, path: str):
        self.path = path
        self.skipped = 0            # unparseable lines seen by records()

    def append(self, env: Dict[str, Any], rows: Iterable, *,
               smoke: bool = False, label: Optional[str] = None,
               ) -> Dict[str, Any]:
        """Append one record; returns the dict written."""
        rec: Dict[str, Any] = {
            "schema": SCHEMA_VERSION,
            "ts": round(_clock.wall_time(), 3),
            "fingerprint": fingerprint(env, smoke=smoke),
            "env": {k: env.get(k) for k in ENV_FIELDS},
            "smoke": bool(smoke),
            "rows": _normalize_rows(rows),
        }
        if label:
            rec["label"] = str(label)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        with open(self.path, "a") as fh:
            fh.write(json.dumps(rec, separators=(",", ":")) + "\n")
        return rec

    def records(self) -> List[Dict[str, Any]]:
        """All parseable records, oldest first.  Missing file → empty
        history (the first run of a fresh checkout)."""
        self.skipped = 0
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    self.skipped += 1
                    continue
                if not isinstance(rec, dict) or "rows" not in rec:
                    self.skipped += 1
                    continue
                out.append(rec)
        return out

    def samples(self, name: str, fp: str, *,
                k: Optional[int] = None) -> List[float]:
        """Row ``name``'s ``us_per_call`` series under fingerprint ``fp``,
        oldest first; ``k`` keeps only the most recent k."""
        vals = []
        for rec in self.records():
            if rec.get("fingerprint") != fp:
                continue
            for row in rec["rows"]:
                if row.get("name") == name:
                    vals.append(float(row["us_per_call"]))
                    break
        return vals[-k:] if k else vals


__all__ = ["HistoryStore", "history_path", "fingerprint",
           "HISTORY_ENV", "DEFAULT_PATH", "ENV_FIELDS", "SCHEMA_VERSION"]
