"""minicpm3-4b [dense/MLA] — 62L d_model=2560 40H d_ff=6400 vocab=73448 (padded
to 73728 = 288*256 for 16-way TP).  MLA dims per hf:openbmb/MiniCPM3-4B:
q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head_dim=64."""
from repro.configs.base import MLAConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="mla",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        head_dim=64, d_ff=6400, vocab_size=73728, real_vocab_size=73448,
        rope_theta=1e4, max_seq_len=32768, vocab_chunks=16,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke", family="mla",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512,
        max_seq_len=256, vocab_chunks=4, attn_chunk=32, dtype="float32",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
    )
