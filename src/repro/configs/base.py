"""Model / parallelism / run configuration dataclasses.

One ``ModelConfig`` instance per assigned architecture lives in
``repro.configs.<arch_id>`` (exact numbers from the assignment table), plus a
``smoke()`` reduction of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block configuration."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    # dt (timestep) softplus bias init range
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block configuration (mLSTM + sLSTM mix)."""
    num_heads: int = 4
    expand: int = 2          # mLSTM up-projection factor
    chunk: int = 256         # mLSTM chunked-parallel block length
    slstm_every: int = 6     # sLSTM at layer indices where i % slstm_every == 0
    conv_width: int = 4


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    experts_per_token: int = 2
    d_ff_expert: int = 0          # per-expert hidden dim
    d_ff_shared: int = 0          # shared-expert hidden dim (0 = none)
    capacity_factor: float = 1.25
    group_size: int = 512         # dispatch group (tokens) for one-hot einsum
    router_z_loss: float = 1e-3
    # "expert": shard expert axis over model (pad experts up if needed)
    # "ffn":    shard each expert's hidden dim over model
    shard_mode: str = "expert"
    pad_experts_to: int = 0       # 0 = no padding


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | mla | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int               # padded to a multiple of 256 (TP-friendly)
    real_vocab_size: int = 0      # 0 -> vocab_size (set when padding applied)
    head_dim: int = 0             # 0 -> d_model // num_heads
    max_seq_len: int = 8192
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"             # silu (SwiGLU) | gelu (plain MLP)
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    pos_embedding: str = "rope"   # rope | learned | sinusoidal
    dtype: str = "bfloat16"
    # family-specific sub-configs
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    moe: Optional[MoEConfig] = None
    # hybrid: attention block inserted every N ssm blocks (shared weights)
    hybrid_attn_every: int = 0
    # enc-dec
    encoder_layers: int = 0
    encoder_seq_len: int = 1536   # whisper: 1500 frames, padded to 1536
    # vlm
    num_patches: int = 0          # stub patch-embedding count prepended to text
    # --- paper-technique switches (the repo's contribution) ---------------
    attn_chunk: int = 1024        # KV chunk for online attention
    vocab_chunks: int = 16        # chunked online cross-entropy factor
    use_chunked_ce: bool = True
    use_online_attention: bool = True
    # §Perf levers (baseline off; flipped by the hillclimb)
    attn_causal_blocks: int = 0   # >1: causal chunk skipping (q-block unroll)
    kv_cache_dtype: str = ""      # "" = model dtype; "int8" = quantized cache
    use_pallas: bool = False      # True on real TPU: swap in kernels/
    # remat: "full" = recompute everything inside a block (layer inputs kept
    # by the scan carry — MaxText-style default for big models);
    # "block" = keep matmul outputs (dots_with_no_batch_dims); "none".
    remat: str = "full"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)
SHAPE_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh (derived per arch × mesh)."""
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # attention sharding: "heads" if head counts divide the model axis,
    # else "sequence" (context-parallel q, gathered KV) — see DESIGN.md.
    attn_mode: str = "heads"
    seq_sharded_norms: bool = True     # Megatron-style sequence parallelism
    grad_reduce_dtype: str = "bfloat16"
    microbatches: int = 1
    fsdp: bool = False                 # also shard params over the data axes


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    seed: int = 0
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
