"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) vocab=202048,
MoE 16 experts top-1 (d_ff_expert=8192) + one shared expert (8192), all layers
MoE.  Early-fusion multimodality is out of scope for the LM cells (text
backbone only). [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202048,
        rope_theta=5e5, max_seq_len=131072, vocab_chunks=16,
        moe=MoEConfig(num_experts=16, experts_per_token=1,
                      d_ff_expert=8192, d_ff_shared=8192,
                      capacity_factor=1.25, group_size=512,
                      shard_mode="expert"),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, max_seq_len=256,
        vocab_chunks=4, attn_chunk=32, dtype="float32",
        moe=MoEConfig(num_experts=4, experts_per_token=1,
                      d_ff_expert=96, d_ff_shared=96,
                      capacity_factor=1.25, group_size=32,
                      shard_mode="expert"),
    )
