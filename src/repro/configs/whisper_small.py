"""whisper-small [audio] — enc-dec, 12 encoder + 12 decoder layers,
d_model=768 12H d_ff=3072 vocab=51865 (padded to 51968 for 16-way TP).
Conv frontend is a STUB: input_specs() provides precomputed frame embeddings
[B, 1500->1536, 768].  LayerNorm+GELU, learned decoder positions, tied
embeddings. [arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        num_layers=12, encoder_layers=12, encoder_seq_len=1536,
        d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=51968, real_vocab_size=51865,
        act="gelu", norm_type="layernorm", pos_embedding="learned",
        tie_embeddings=True, max_seq_len=32768, vocab_chunks=16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke", family="encdec",
        num_layers=2, encoder_layers=2, encoder_seq_len=64,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, act="gelu", norm_type="layernorm",
        pos_embedding="learned", tie_embeddings=True, max_seq_len=256,
        vocab_chunks=4, attn_chunk=32, dtype="float32",
    )
