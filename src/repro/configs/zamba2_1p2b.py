"""zamba2-1.2b [hybrid] — 38L d_model=2048, Mamba2 backbone (ssm_state=64)
with a SHARED transformer block (32H kv=32, d_ff=8192) invoked every 6 mamba
blocks.  Zamba2's per-invocation LoRA deltas on the shared block are omitted
(weight sharing kept; noted in DESIGN.md). [arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=8192, vocab_size=32000, max_seq_len=1 << 20,
        vocab_chunks=16, hybrid_attn_every=6,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, max_seq_len=512,
        vocab_chunks=4, hybrid_attn_every=2, dtype="float32",
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16, chunk=16),
    )
