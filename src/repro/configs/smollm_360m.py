"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152,
llama-arch small, tied embeddings. [hf:HuggingFaceTB/SmolLM-360M; hf]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
        head_dim=64, d_ff=2560, vocab_size=49152,
        rope_theta=1e4, max_seq_len=8192, tie_embeddings=True,
        vocab_chunks=16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke", family="dense",
        num_layers=2, d_model=60, num_heads=3, num_kv_heads=1,
        head_dim=20, d_ff=128, vocab_size=512, tie_embeddings=True,
        max_seq_len=256, vocab_chunks=4, attn_chunk=32, dtype="float32",
    )
