"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H vocab=151936, 60 routed
experts top-4 (d_ff_expert=1408) + shared expert (5632 = 4x1408, matching the
"4 shared" description). Experts padded 60->64 so the expert axis shards
16-way (router never selects pads). [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, vocab_size=151936,
        rope_theta=1e6, max_seq_len=32768, vocab_chunks=16,
        moe=MoEConfig(num_experts=60, experts_per_token=4,
                      d_ff_expert=1408, d_ff_shared=5632,
                      capacity_factor=1.25, group_size=512,
                      shard_mode="expert", pad_experts_to=64),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=96, vocab_size=512, max_seq_len=256,
        vocab_chunks=4, attn_chunk=32, dtype="float32",
        moe=MoEConfig(num_experts=6, experts_per_token=2,
                      d_ff_expert=96, d_ff_shared=96,
                      capacity_factor=1.25, group_size=32,
                      shard_mode="expert", pad_experts_to=8),
    )
