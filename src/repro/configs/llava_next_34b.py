"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 (Yi-34B-class backbone).  The vision tower is a STUB per the
assignment: input_specs() provides precomputed patch embeddings; anyres
tiling fixed at 5 tiles x 576 = 2880 patches prepended to the text.
[hf:llava-hf/llava-v1.6-34b; unverified]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=20480, vocab_size=64000,
        rope_theta=5e6, max_seq_len=32768, vocab_chunks=16,
        num_patches=2880,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, max_seq_len=256,
        vocab_chunks=4, attn_chunk=32, dtype="float32", num_patches=16,
    )
