"""xlstm-125m [ssm] — 12L d_model=768 4H vocab=50304, d_ff=0 (blocks carry
their own projections).  sLSTM + mLSTM mix: sLSTM at every 6th block
(indices 5, 11), the rest mLSTM — the paper's 7:1-style sparse sLSTM
placement adapted to 12 layers. [arXiv:2405.04517; unverified]."""
from repro.configs.base import ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304, max_seq_len=1 << 20,
        vocab_chunks=16, tie_embeddings=False,
        xlstm=XLSTMConfig(num_heads=4, expand=2, chunk=256, slstm_every=6,
                          conv_width=4),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=512, max_seq_len=512,
        vocab_chunks=4, dtype="float32",
        xlstm=XLSTMConfig(num_heads=2, expand=2, chunk=16, slstm_every=2,
                          conv_width=4),
    )
