"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch. [arXiv:2401.14196; hf]. rope_theta=1e5 (code ctx)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense",
        num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=19200, vocab_size=32256,
        rope_theta=1e5, max_seq_len=16384, vocab_chunks=16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=512,
        max_seq_len=256, vocab_chunks=4, attn_chunk=32, dtype="float32",
    )
