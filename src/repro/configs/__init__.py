"""Architecture registry: one module per assigned architecture.

Each module exports ``config()`` (the exact assigned numbers) and ``smoke()``
(a reduced same-family config for CPU tests).  ``get(name)`` / ``ARCHS`` are
the public lookup API used by the launcher (``--arch <id>``).
"""
from __future__ import annotations

import importlib

ARCHS = (
    "mistral_nemo_12b",
    "minicpm3_4b",
    "smollm_360m",
    "deepseek_coder_33b",
    "xlstm_125m",
    "zamba2_1p2b",
    "llama4_scout_17b_a16e",
    "qwen2_moe_a2p7b",
    "llava_next_34b",
    "whisper_small",
)

_ALIASES = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "minicpm3-4b": "minicpm3_4b",
    "smollm-360m": "smollm_360m",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-1.2b": "zamba2_1p2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "llava-next-34b": "llava_next_34b",
    "whisper-small": "whisper_small",
}


def _module(name: str):
    name = _ALIASES.get(name, name).replace("-", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    """Full (assigned) config for ``--arch <name>``."""
    return _module(name).config()


def get_smoke(name: str):
    """Reduced same-family config for CPU smoke tests."""
    return _module(name).smoke()


from repro.configs.base import (  # noqa: E402,F401
    SHAPES,
    SHAPE_BY_NAME,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    ParallelConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
)
