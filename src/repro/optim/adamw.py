"""AdamW with decoupled weight decay, global-norm clipping, and LR schedules.

Hand-rolled (no optax in this environment — and the assignment asks for the
substrate to be built, not imported).  States are pytrees shaped like the
params, so they inherit the params' NamedSharding under pjit.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import OptimizerConfig

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=compat.tree_map(zeros, params),
                      nu=compat.tree_map(zeros, params))


def schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    """Warmup + {cosine, linear, constant} decay."""
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step_f - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in compat.tree_leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return compat.tree_map(lambda g: g * scale, grads), gnorm


def update(grads: PyTree, state: AdamWState, params: PyTree,
           cfg: OptimizerConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads = compat.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    mu = compat.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = compat.tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = compat.tree_map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
