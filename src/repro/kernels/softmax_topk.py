"""Pallas TPU kernel: fused Softmax+TopK — paper Algorithm 4, single pass.

One sweep over V-tiles per row-block carrying ``(m, d)`` *and* the running
top-K ``(u, p)`` in VMEM scratch.  Exactly one HBM load per input element and
O(K) output writes — the paper's 5→1 access reduction.

TPU adaptation of Alg. 4 lines 10–15 (per-element insertion sort): each tile
contributes its K largest candidates, found by K masked arg-max sweeps over
the VMEM-resident tile (VPU-friendly: iota + compare + reduce), which are then
merged with the running K by another K selection sweeps over the 2K candidate
set.  Ties break to the lowest index, matching ``jax.lax.top_k``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")
BIG_IDX = 2**30  # plain int: sentinel "no index", > any vocab size


def _select_topk(vals, idx, k):
    """K masked argmax sweeps; returns ([R,k] vals desc, [R,k] idx).

    Lowest-index tie-breaking via a min-reduction over an index lattice.
    """
    outs_v, outs_i = [], []
    work = vals
    for _ in range(k):
        cur = jnp.max(work, axis=-1, keepdims=True)                  # [R,1]
        hit = work == cur
        cand = jnp.where(hit, idx, BIG_IDX)
        cur_i = jnp.min(cand, axis=-1, keepdims=True)                # [R,1]
        outs_v.append(cur)
        outs_i.append(cur_i)
        work = jnp.where((idx == cur_i) & hit, NEG_INF, work)
    return jnp.concatenate(outs_v, -1), jnp.concatenate(outs_i, -1)


def _make_kernel(k: int, v_blk: int, n_v: int):
    def kernel(x_ref, vals_ref, idx_ref, lse_ref, m_sc, d_sc, u_sc, p_sc):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            m_sc[...] = jnp.full_like(m_sc, NEG_INF)
            d_sc[...] = jnp.zeros_like(d_sc)
            u_sc[...] = jnp.full_like(u_sc, NEG_INF)
            p_sc[...] = jnp.full_like(p_sc, BIG_IDX)

        x = x_ref[...].astype(jnp.float32)                 # [R_BLK, V_BLK]
        r_blk = x.shape[0]
        # --- (m, d) ⊕ update (Alg. 3 lines 4-5) ---------------------------
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1, keepdims=True))
        alpha = jnp.exp(jnp.where(m_prev == m_new, 0.0, m_prev - m_new))
        d_sc[...] = d_sc[...] * alpha + jnp.sum(jnp.exp(x - m_new), -1,
                                                keepdims=True)
        m_sc[...] = m_new
        # --- running top-k merge (Alg. 4 lines 8-15, tile-merge form) -----
        lane = jax.lax.broadcasted_iota(jnp.int32, (r_blk, v_blk), 1)
        gidx = lane + j * v_blk
        tv, ti = _select_topk(x, gidx, k)
        cand_v = jnp.concatenate([u_sc[...], tv], axis=-1)   # [R, 2K]
        cand_i = jnp.concatenate([p_sc[...], ti], axis=-1)
        u_new, p_new = _select_topk(cand_v, cand_i, k)
        u_sc[...] = u_new
        p_sc[...] = p_new

        @pl.when(j == n_v - 1)                               # Alg. 4 lines 17-19
        def _finalize():
            m = m_sc[...]
            d = d_sc[...]
            vals_ref[...] = (jnp.exp(u_sc[...] - m) / d).astype(vals_ref.dtype)
            idx_ref[...] = p_sc[...]
            lse_ref[...] = m + jnp.log(d)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("k", "r_blk", "v_blk", "interpret"))
def softmax_topk_pallas(x: jax.Array, k: int, *, r_blk: int = 256,
                        v_blk: int = 2048, interpret: bool = False):
    """Fused softmax+top-k over the last axis of [R, V].

    Returns ``(values [R,k] desc softmax probs, indices [R,k] int32,
    lse [R])`` — one HBM pass over ``x``.
    """
    r, v = x.shape
    r_blk = min(r_blk, r)
    v_blk = min(v_blk, v)
    assert r % r_blk == 0 and v % v_blk == 0, (x.shape, r_blk, v_blk)
    assert k <= v_blk
    n_v = v // v_blk
    vals, idx, lse = pl.pallas_call(
        _make_kernel(k, v_blk, n_v),
        grid=(r // r_blk, n_v),
        in_specs=[pl.BlockSpec((r_blk, v_blk), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((r_blk, k), lambda i, j: (i, 0)),
                   pl.BlockSpec((r_blk, k), lambda i, j: (i, 0)),
                   pl.BlockSpec((r_blk, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, k), x.dtype),
                   jax.ShapeDtypeStruct((r, k), jnp.int32),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((r_blk, 1), jnp.float32),
                        pltpu.VMEM((r_blk, 1), jnp.float32),
                        pltpu.VMEM((r_blk, k), jnp.float32),
                        pltpu.VMEM((r_blk, k), jnp.int32)],
        interpret=interpret,
    )(x)
    return vals, idx, lse[:, 0]
