"""Public jit'd entry points for the Pallas kernels.

Dispatch policy (MaxText-style fallback):
* On TPU: Pallas kernels with explicit VMEM tiling.
* On CPU (this container, and the multi-pod dry-run): ``interpret=True``
  executes the kernel body faithfully for correctness tests, while the model
  stack uses the semantically-identical XLA implementations in ``repro.core``
  (Pallas can't lower to the CPU target).

Execution mode comes from ``repro.compat.pallas_interpret()`` — the one place
that decides interpret-vs-compiled; path *selection* between Pallas and the
XLA forms lives in ``repro.kernels.dispatch``.  Vocab-axis block sizes
default to the dispatch registry's autotuned per-(backend, vocab, dtype)
choice, and attention tile shapes (``bq``/``bk``) resolve through the same
registry seam (``dispatch.attention_tiles``); pass them explicitly to pin a
shape (kernel tests do).

Autodiff:
* ``flash_attention`` (fresh prefill, ``q_offset``/``kv_valid_len`` unset) is
  differentiable: Pallas forward + Pallas backward via ``jax.custom_vjp``
  (the backward recomputes P from the forward's saved LSE — FlashAttention
  economics).  The *offset* form (cached chunked prefill: queries offset into
  a longer, partially-valid cache) is inference-only — the backward kernels
  have no offset operands yet, so the residual rule is never installed for it
  and a grad through it fails loudly instead of silently mis-masking.
* ``softmax_topk`` is differentiable on every path: the kernel forward saves
  ``(x, values, lse)`` and the backward recomputes the full softmax from the
  saved LSE — the paper's ``(m, d)`` in log form — in one extra pass
  (``softmax_j = e^{x_j - lse}``), so the forward stays single-pass and no
  [R, V] probability matrix is ever stored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import attention as core_attention
from repro.kernels.flash_attention import (
    flash_attention_offset_pallas,
    flash_attention_paged_pallas,
    flash_attention_pallas,
)
from repro.kernels.flash_decode import (
    flash_decode_paged_pallas,
    flash_decode_pallas,
)
from repro.kernels.online_softmax import (
    online_normalizer_pallas,
    online_softmax_pallas,
)
from repro.kernels.softmax_topk import softmax_topk_pallas

Array = jax.Array


def _v_blk(v: int, v_blk: int | None, dtype) -> int:
    if v_blk is None:
        from repro.kernels.dispatch import tuned_block
        v_blk = tuned_block(v, dtype)
    return _largest_divisor_block(v, v_blk)


def online_softmax(x: Array, *, r_blk: int = 256,
                   v_blk: int | None = None) -> Array:
    """Softmax over the last axis; any leading batch shape."""
    lead = x.shape[:-1]
    v = x.shape[-1]
    x2 = x.reshape(-1, v)
    r = x2.shape[0]
    y = online_softmax_pallas(x2, r_blk=_largest_divisor_block(r, r_blk),
                              v_blk=_v_blk(v, v_blk, x.dtype),
                              interpret=compat.pallas_interpret())
    return y.reshape(*lead, v)


def online_normalizer(x: Array, *, r_blk: int = 256,
                      v_blk: int | None = None):
    lead = x.shape[:-1]
    v = x.shape[-1]
    x2 = x.reshape(-1, v)
    m, d = online_normalizer_pallas(
        x2, r_blk=_largest_divisor_block(x2.shape[0], r_blk),
        v_blk=_v_blk(v, v_blk, x.dtype),
        interpret=compat.pallas_interpret())
    return m.reshape(lead), d.reshape(lead)


# ---------------------------------------------------------------------------
# Differentiable fused softmax+top-k: Pallas forward, recompute-from-LSE
# backward.  The custom_vjp lives on the 2-D core; the public wrapper only
# reshapes.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _softmax_topk2d(x, k, r_blk, v_blk):
    vals, idx, lse = softmax_topk_pallas(
        x, k, r_blk=r_blk, v_blk=v_blk, interpret=compat.pallas_interpret())
    return vals, idx, lse


def _softmax_topk2d_fwd(x, k, r_blk, v_blk):
    out = _softmax_topk2d(x, k, r_blk, v_blk)
    vals, idx, lse = out
    return out, (x, vals, idx, lse)


def _softmax_topk2d_bwd(k, r_blk, v_blk, res, dout):
    """∂/∂x of (values, lse): values_i = e^{x_{p_i} − lse}, lse = logsumexp.

    dx_j = softmax_j · (dlse − Σᵢ dvalᵢ·valᵢ) + [j = pᵢ]·dvalᵢ·valᵢ, with
    softmax recomputed from the saved LSE (one extra pass over x; nothing
    beyond (values, indices, lse) was stored by the forward).  ``indices``
    is integer-valued — its cotangent is discarded.
    """
    x, vals, idx, lse = res
    dvals, _, dlse = dout
    r = x.shape[0]
    xf = x.astype(jnp.float32)
    s = jnp.exp(xf - lse[:, None])                       # [R, V]
    dv_v = dvals.astype(jnp.float32) * vals.astype(jnp.float32)   # [R, K]
    coeff = dlse.astype(jnp.float32) - jnp.sum(dv_v, axis=-1)     # [R]
    dx = s * coeff[:, None]
    dx = dx.at[jnp.arange(r)[:, None], idx].add(dv_v)
    return (dx.astype(x.dtype),)


_softmax_topk2d.defvjp(_softmax_topk2d_fwd, _softmax_topk2d_bwd)


def softmax_topk(x: Array, k: int, *, r_blk: int = 256,
                 v_blk: int | None = None):
    lead = x.shape[:-1]
    v = x.shape[-1]
    x2 = x.reshape(-1, v)
    vals, idx, lse = _softmax_topk2d(
        x2, k, _largest_divisor_block(x2.shape[0], r_blk),
        _v_blk(v, v_blk, x.dtype))
    return (vals.reshape(*lead, k), idx.reshape(*lead, k), lse.reshape(lead))


def _largest_divisor_block(n: int, target: int) -> int:
    target = min(target, n)
    while n % target:
        target -= 1
    return target


# ---------------------------------------------------------------------------
# Differentiable flash attention: Pallas forward, XLA-chunked backward.
# Layout here matches the model stack: q [B,Tq,Hq,D]; k,v [B,Tk,Hkv,D].
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, bq, bk):
    out, _ = _flash_fwd_impl(q, k, v, causal, bq, bk)
    return out


def _flash_fwd_impl(q, k, v, causal, bq, bk):
    qh = jnp.swapaxes(q, 1, 2)       # [B,Hq,Tq,D]
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out, lse = flash_attention_pallas(qh, kh, vh, causal=causal, bq=bq, bk=bk,
                                      interpret=compat.pallas_interpret())
    return jnp.swapaxes(out, 1, 2), lse


def _flash_fwd(q, k, v, causal, bq, bk):
    out, lse = _flash_fwd_impl(q, k, v, causal, bq, bk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, bq, bk, res, dout):
    """Backward: Pallas dq/dkv kernels (interpret on CPU); recomputes P from
    the forward's saved LSE — the paper's (m, d) in log form.

    Only the fresh-prefill forward (self-aligned q/k, fully-valid KV) installs
    this rule; the backward kernels have no ``q_offset``/``kv_valid_len``
    operands, so the offset forward below stays out of the custom_vjp."""
    from repro.kernels.flash_attention_bwd import flash_attention_bwd_pallas
    q, k, v, out, lse = res
    b, tq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    oh = jnp.swapaxes(out, 1, 2)
    doh = jnp.swapaxes(dout, 1, 2)
    dq, dk_h, dv_h = flash_attention_bwd_pallas(
        qh, kh, vh, oh, lse, doh, causal=causal, bq=bq, bk=bk,
        interpret=compat.pallas_interpret())
    # reduce per-Q-head dk/dv into KV heads (GQA)
    tk = k.shape[1]
    dk = dk_h.reshape(b, hkv, g, tk, dh).sum(axis=2)
    dv = dv_h.reshape(b, hkv, g, tk, dh).sum(axis=2)
    return (jnp.swapaxes(dq, 1, 2).astype(q.dtype),
            jnp.swapaxes(dk, 1, 2).astype(k.dtype),
            jnp.swapaxes(dv, 1, 2).astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    bq: int | None = None, bk: int | None = None,
                    q_offset: Array | None = None,
                    kv_valid_len: Array | None = None) -> Array:
    """Online-softmax attention (Pallas fwd on TPU).

    q [B, Tq, Hq, D]; k, v [B, Tk, Hkv, D] (grouped-query: Hq a multiple
    of Hkv) → out [B, Tq, Hq, D].

    ``causal``: mask queries from keys after them (in absolute coordinates
    when the serving operands below are set); False runs full
    cross-attention over the valid prefix.

    ``bq``/``bk`` unset → the dispatch registry's resolved tiles (kernel
    tests pin explicit values; nothing here is hard-coded).

    ``q_offset``/``kv_valid_len`` unset → the fresh-prefill differentiable
    form (training path).  Set, they select the serving form: ``q_offset``
    (scalar or [B]) is the absolute position of query row 0 and
    ``kv_valid_len`` (scalar or [B]) the per-row valid cache prefix; causal
    masking runs in absolute coordinates and out-of-range KV columns are
    masked before the online update.  KV is padded up to a tile multiple
    (padded columns sit past ``kv_valid_len``, so the mask erases them) —
    this form is inference-only (no VJP installed).  This is the operand
    pair the serving stack threads per slot: the scheduler's chunked
    prefill passes ``q_offset = cache_len`` (a [B] vector under continuous
    batching, including a just-swapped-in sequence resuming at its
    pre-preemption length) and ``kv_valid_len = cache_len + chunk``."""
    if bq is None or bk is None:
        from repro.kernels.dispatch import attention_tiles
        offset_form = q_offset is not None or kv_valid_len is not None
        tiles = attention_tiles(
            "flash_attention_offset" if offset_form else "flash_attention",
            kv_len=k.shape[1], head_dim=q.shape[-1], dtype=q.dtype)
        bq = tiles["bq"] if bq is None else bq
        bk = tiles["bk"] if bk is None else bk
    bq = _largest_divisor_block(q.shape[1], bq)
    if q_offset is None and kv_valid_len is None:
        bk = _largest_divisor_block(k.shape[1], bk)
        return _flash(q, k, v, causal, bq, bk)
    return _flash_offset(q, k, v, q_offset, kv_valid_len, causal, bq, bk)


def _flash_offset(q, k, v, q_offset, kv_valid_len, causal, bq, bk):
    """Cached-prefill flash attention (model layout), inference-only."""
    b, tq, _, _ = q.shape
    tk = k.shape[1]
    if q_offset is None:
        q_offset = 0
    if kv_valid_len is None:
        kv_valid_len = tk
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    kv_valid_len = jnp.minimum(
        jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32), (b,)), tk)
    bk = min(bk, tk)
    pad_k = -tk % bk
    if pad_k:     # padded KV columns sit at positions ≥ kv_valid_len: masked
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out, _ = flash_attention_offset_pallas(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        q_offset, kv_valid_len, causal=causal, bq=bq, bk=bk,
        interpret=compat.pallas_interpret())
    return jnp.swapaxes(out, 1, 2)


def flash_decode(q: Array, k_cache: Array, v_cache: Array,
                 kv_valid_len: Array, *, bk: int | None = None) -> Array:
    """Decode attention: q [B,Hq,D] vs caches [B,S,Hkv,D] → [B,Hq,D].

    ``kv_valid_len`` [B] masks each row's cache tail independently — the
    per-slot length vector of the continuous-batching pool flows in here.
    ``bk`` unset → the registry's swept decode tile for this cache length."""
    kh = jnp.swapaxes(k_cache, 1, 2)   # [B,Hkv,S,D]
    vh = jnp.swapaxes(v_cache, 1, 2)
    if bk is None:
        from repro.kernels.dispatch import attention_tiles
        bk = attention_tiles("flash_decode", kv_len=k_cache.shape[1],
                             head_dim=q.shape[-1], dtype=q.dtype)["bk"]
    bk = _largest_divisor_block(kh.shape[2], bk)
    return flash_decode_pallas(q, kh, vh, kv_valid_len, bk=bk,
                               interpret=compat.pallas_interpret())


# ---------------------------------------------------------------------------
# Paged KV cache: block-pool + block-table forms (inference-only).
# Pool layout is the kernel-native [P, Hkv, BS, D] — one physical block is
# one KV tile, so the kernels gather pages with zero re-layout on the hot
# path.  Block tables are CONSUMED here; building them is the exclusive
# business of ``repro.serving.paged`` (grep-enforced).
# ---------------------------------------------------------------------------
def paged_flash_decode(q: Array, k_pool: Array, v_pool: Array,
                       block_tables: Array, kv_valid_len: Array, *,
                       k_scale_pool: Array | None = None,
                       v_scale_pool: Array | None = None) -> Array:
    """Paged decode attention: q [B,Hq,D]; pools [P,Hkv,BS,D]; block_tables
    [B,M]; kv_valid_len [B] → [B,Hq,D].

    The KV tile width is the pool block size (no free tile knob — paging
    fixes the gather granularity), so nothing resolves through
    ``attention_tiles`` here.  ``k_scale_pool``/``v_scale_pool`` [P,Hkv,BS]
    select the quantized (int8 pools + per-position scale pages) form."""
    return flash_decode_paged_pallas(q, k_pool, v_pool, block_tables,
                                     kv_valid_len,
                                     k_scale_pool=k_scale_pool,
                                     v_scale_pool=v_scale_pool,
                                     interpret=compat.pallas_interpret())


def paged_flash_attention(q: Array, k_pool: Array, v_pool: Array,
                          q_offset: Array, kv_valid_len: Array,
                          block_tables: Array, *, causal: bool = True,
                          bq: int | None = None,
                          k_scale_pool: Array | None = None,
                          v_scale_pool: Array | None = None) -> Array:
    """Paged cached-prefill flash attention (model layout), inference-only.

    q [B, Tq, Hq, D]; pools [P, Hkv, BS, D]; q_offset / kv_valid_len [B];
    block_tables [B, M] → out [B, Tq, Hq, D].  ``bq`` unset resolves through
    the registry's paged-prefill sweep; the KV tile is pinned to the pool
    block size.  ``k_scale_pool``/``v_scale_pool`` [P, Hkv, BS] select the
    quantized (int8 pools + per-position scale pages) form."""
    b, tq = q.shape[:2]
    bs = k_pool.shape[2]
    if bq is None:
        from repro.kernels.dispatch import attention_tiles
        bq = attention_tiles("flash_attention_paged",
                             kv_len=block_tables.shape[1] * bs,
                             head_dim=q.shape[-1], dtype=q.dtype)["bq"]
    bq = _largest_divisor_block(tq, bq)
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    kv_valid_len = jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32),
                                    (b,))
    out, _ = flash_attention_paged_pallas(
        jnp.swapaxes(q, 1, 2), k_pool, v_pool, q_offset, kv_valid_len,
        block_tables, causal=causal, bq=bq, k_scale_pool=k_scale_pool,
        v_scale_pool=v_scale_pool, interpret=compat.pallas_interpret())
    return jnp.swapaxes(out, 1, 2)
