"""Public jit'd entry points for the Pallas kernels.

Dispatch policy (MaxText-style fallback):
* On TPU: Pallas kernels with explicit VMEM tiling.
* On CPU (this container, and the multi-pod dry-run): ``interpret=True``
  executes the kernel body faithfully for correctness tests, while the model
  stack uses the semantically-identical XLA implementations in ``repro.core``
  (Pallas can't lower to the CPU target).

Execution mode comes from ``repro.compat.pallas_interpret()`` — the one place
that decides interpret-vs-compiled; path *selection* between Pallas and the
XLA forms lives in ``repro.kernels.dispatch``.  Vocab-axis block sizes
default to the dispatch registry's autotuned per-(backend, vocab, dtype)
choice, and attention tile shapes (``bq``/``bk``) resolve through the same
registry seam (``dispatch.attention_tiles``); pass them explicitly to pin a
shape (kernel tests do).

``flash_attention`` is differentiable: Pallas forward + the XLA chunked-online
backward from ``repro.core.attention`` via ``jax.custom_vjp`` (the backward
recomputes from the forward's saved LSE — FlashAttention economics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import attention as core_attention
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.online_softmax import (
    online_normalizer_pallas,
    online_softmax_pallas,
)
from repro.kernels.softmax_topk import softmax_topk_pallas

Array = jax.Array


def _v_blk(v: int, v_blk: int | None, dtype) -> int:
    if v_blk is None:
        from repro.kernels.dispatch import tuned_block
        v_blk = tuned_block(v, dtype)
    return _largest_divisor_block(v, v_blk)


def online_softmax(x: Array, *, r_blk: int = 256,
                   v_blk: int | None = None) -> Array:
    """Softmax over the last axis; any leading batch shape."""
    lead = x.shape[:-1]
    v = x.shape[-1]
    x2 = x.reshape(-1, v)
    r = x2.shape[0]
    y = online_softmax_pallas(x2, r_blk=_largest_divisor_block(r, r_blk),
                              v_blk=_v_blk(v, v_blk, x.dtype),
                              interpret=compat.pallas_interpret())
    return y.reshape(*lead, v)


def online_normalizer(x: Array, *, r_blk: int = 256,
                      v_blk: int | None = None):
    lead = x.shape[:-1]
    v = x.shape[-1]
    x2 = x.reshape(-1, v)
    m, d = online_normalizer_pallas(
        x2, r_blk=_largest_divisor_block(x2.shape[0], r_blk),
        v_blk=_v_blk(v, v_blk, x.dtype),
        interpret=compat.pallas_interpret())
    return m.reshape(lead), d.reshape(lead)


def softmax_topk(x: Array, k: int, *, r_blk: int = 256,
                 v_blk: int | None = None):
    lead = x.shape[:-1]
    v = x.shape[-1]
    x2 = x.reshape(-1, v)
    vals, idx, lse = softmax_topk_pallas(
        x2, k, r_blk=_largest_divisor_block(x2.shape[0], r_blk),
        v_blk=_v_blk(v, v_blk, x.dtype),
        interpret=compat.pallas_interpret())
    return (vals.reshape(*lead, k), idx.reshape(*lead, k), lse.reshape(lead))


def _largest_divisor_block(n: int, target: int) -> int:
    target = min(target, n)
    while n % target:
        target -= 1
    return target


# ---------------------------------------------------------------------------
# Differentiable flash attention: Pallas forward, XLA-chunked backward.
# Layout here matches the model stack: q [B,Tq,Hq,D]; k,v [B,Tk,Hkv,D].
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, bq, bk):
    out, _ = _flash_fwd_impl(q, k, v, causal, bq, bk)
    return out


def _flash_fwd_impl(q, k, v, causal, bq, bk):
    qh = jnp.swapaxes(q, 1, 2)       # [B,Hq,Tq,D]
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out, lse = flash_attention_pallas(qh, kh, vh, causal=causal, bq=bq, bk=bk,
                                      interpret=compat.pallas_interpret())
    return jnp.swapaxes(out, 1, 2), lse


def _flash_fwd(q, k, v, causal, bq, bk):
    out, lse = _flash_fwd_impl(q, k, v, causal, bq, bk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, bq, bk, res, dout):
    """Backward: Pallas dq/dkv kernels (interpret on CPU); recomputes P from
    the forward's saved LSE — the paper's (m, d) in log form."""
    from repro.kernels.flash_attention_bwd import flash_attention_bwd_pallas
    q, k, v, out, lse = res
    b, tq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    oh = jnp.swapaxes(out, 1, 2)
    doh = jnp.swapaxes(dout, 1, 2)
    dq, dk_h, dv_h = flash_attention_bwd_pallas(
        qh, kh, vh, oh, lse, doh, causal=causal, bq=bq, bk=bk,
        interpret=compat.pallas_interpret())
    # reduce per-Q-head dk/dv into KV heads (GQA)
    tk = k.shape[1]
    dk = dk_h.reshape(b, hkv, g, tk, dh).sum(axis=2)
    dv = dv_h.reshape(b, hkv, g, tk, dh).sum(axis=2)
    return (jnp.swapaxes(dq, 1, 2).astype(q.dtype),
            jnp.swapaxes(dk, 1, 2).astype(k.dtype),
            jnp.swapaxes(dv, 1, 2).astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    bq: int | None = None, bk: int | None = None) -> Array:
    """Differentiable online-softmax attention (Pallas fwd on TPU).

    ``bq``/``bk`` unset → the dispatch registry's resolved tiles (kernel
    tests pin explicit values; nothing here is hard-coded)."""
    if bq is None or bk is None:
        from repro.kernels.dispatch import attention_tiles
        tiles = attention_tiles("flash_attention", kv_len=k.shape[1],
                                head_dim=q.shape[-1], dtype=q.dtype)
        bq = tiles["bq"] if bq is None else bq
        bk = tiles["bk"] if bk is None else bk
    bq = _largest_divisor_block(q.shape[1], bq)
    bk = _largest_divisor_block(k.shape[1], bk)
    return _flash(q, k, v, causal, bq, bk)


def flash_decode(q: Array, k_cache: Array, v_cache: Array,
                 kv_valid_len: Array, *, bk: int | None = None) -> Array:
    """Decode attention: q [B,Hq,D] vs caches [B,S,Hkv,D] → [B,Hq,D].

    ``kv_valid_len`` [B] masks each row's cache tail independently — the
    per-slot length vector of the continuous-batching pool flows in here.
    ``bk`` unset → the registry's swept decode tile for this cache length."""
    kh = jnp.swapaxes(k_cache, 1, 2)   # [B,Hkv,S,D]
    vh = jnp.swapaxes(v_cache, 1, 2)
    if bk is None:
        from repro.kernels.dispatch import attention_tiles
        bk = attention_tiles("flash_decode", kv_len=k_cache.shape[1],
                             head_dim=q.shape[-1], dtype=q.dtype)["bk"]
    bk = _largest_divisor_block(kh.shape[2], bk)
    return flash_decode_pallas(q, kh, vh, kv_valid_len, bk=bk,
                               interpret=compat.pallas_interpret())
