"""Capability-probing kernel dispatch registry.

One registry maps each op (online-softmax, softmax+top-k, attention) to its
implementations by execution path:

* ``pallas``           — Pallas kernel compiled natively (TPU Mosaic)
* ``pallas-interpret`` — same kernel body, Pallas interpret mode (faithful
                         execution on backends without native lowering)
* ``xla``              — the semantically-identical XLA form from
                         ``repro.core`` (chunked/online; production CPU path)
* ``xla-naive``        — materializing reference (oracle; small shapes only)

Path selection happens once per (op, preference) pair: the first call probes
``repro.compat.capabilities()`` and the choice is cached for the process.
Model code states *preferences* (``cfg.use_pallas``, ``cfg.use_online_attention``)
and the registry resolves them against what the backend can actually do, so
a config asking for Pallas on a CPU host degrades to interpret mode instead
of crashing — the portability counterpart of the compat import shims.

Block sizes are not hard-coded either: ``block_decision`` runs a lightweight
autotune sweep over the ⊕-tree shape (``online_normalizer_blocked``'s
``block`` knob — §3.1 of the paper: any reduction tree gives the same
``(m, d)``, so the sweep is free to pick the fastest) and caches the winner
per (backend, vocab, dtype).  The second call for the same key is a pure
cache hit.  Decisions persist to an on-disk JSON cache (path overridable via
``REPRO_AUTOTUNE_CACHE``; set it empty to disable) loaded at import, so a
serving restart skips the sweep entirely.

Attention tile shapes go through the same seam: ``attention_tiles`` resolves
``bq``/``bk`` for the Pallas flash kernels — decode ``bk`` AND the prefill
forms (fresh, offset, paged ``bq``) are swept on native backends — so
``kernels/ops.py`` carries no hard-coded 512s.

Paged KV serving adds ``paged_attention`` / ``paged_decode_attention``:
block-pool K/V addressed through a ``[B, max_blocks]`` block table
(``sdpa(block_tables=...)`` routes them).  The Pallas paths gather pages in
kernel index maps; the XLA fallback gathers the table into a contiguous
cache and reuses the chunked online form.  Quantized (int8) pools carry
``k_scale``/``v_scale`` pages beside K/V; both paged paths dequantize
AFTER the gather — scale pages ride the same block table and the same
clamped page index, so the pool lifecycle never sees fp data.

Reduced-precision softmax forms (PAPERS.md 2201.04562 / 2111.10770) are
registry ops too — ``online_softmax_bf16`` (bf16 normalizer accumulator)
and ``online_softmax_exp2`` (exp2-based exponentials) — selected by a
process preference (``set_softmax_form`` / ``REPRO_SOFTMAX_FORM``); their
analytic error bounds live in ``repro.core.softmax_forms`` and
``tests/test_numerics.py`` pins every form inside them.
"""
from __future__ import annotations

import functools
import json
import os
from dataclasses import asdict, dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat
from repro import core
from repro.obs import clock as obs_clock
from repro.obs import kernels as obs_kernels

Array = jax.Array

PATH_PALLAS = "pallas"
PATH_PALLAS_INTERPRET = "pallas-interpret"
PATH_XLA = "xla"
PATH_XLA_NAIVE = "xla-naive"

_REGISTRY: dict[str, dict[str, Callable]] = {}


def register(op: str, *paths: str):
    """Decorator: register ``fn`` as the implementation of ``op`` on ``paths``."""
    def deco(fn: Callable) -> Callable:
        for path in paths:
            _REGISTRY.setdefault(op, {})[path] = fn
        return fn
    return deco


def available(op: str) -> tuple[str, ...]:
    return tuple(_REGISTRY.get(op, ()))


@functools.lru_cache(maxsize=None)
def select_path(op: str, prefer_pallas: bool = False) -> str:
    """Resolve the execution path for ``op`` on the probed backend (cached).

    Policy: native Pallas wins wherever it exists; a Pallas *preference* on a
    backend without native lowering resolves to interpret mode (kernel-body
    fidelity over speed — what the kernel test suite pins); otherwise the XLA
    form is the production path.
    """
    table = _REGISTRY[op]
    caps = compat.capabilities()
    if caps.pallas_native and PATH_PALLAS in table:
        return PATH_PALLAS
    if prefer_pallas and PATH_PALLAS_INTERPRET in table:
        return PATH_PALLAS_INTERPRET
    if PATH_XLA in table:
        return PATH_XLA
    return next(iter(table))


def lookup(op: str, prefer_pallas: bool = False) -> tuple[str, Callable]:
    path = select_path(op, prefer_pallas)
    # dict bookkeeping only (lookup runs at trace time, not per token):
    # repro.obs surfaces which path each op actually resolved to
    obs_kernels.record_path(op, path, prefer_pallas=prefer_pallas)
    return path, _REGISTRY[op][path]


# ---------------------------------------------------------------------------
# Block-size autotune: per-(backend, vocab, dtype), ⊕-tree-shape sweep.
# ---------------------------------------------------------------------------
BLOCK_CANDIDATES = (256, 512, 1024, 2048, 4096)
_TUNE_ROWS = 4           # sample batch height: enough to engage vectorization
_TUNE_REPS = 3

_BLOCK_CACHE: dict[tuple[str, int, str], "BlockDecision"] = {}
_TILE_CACHE: dict[tuple, "TileDecision"] = {}
_SWEEPS = 0              # number of real sweeps run (tests assert cache hits)

# Attention tile registry defaults (the former hard-coded ops.py values).
# On native Pallas backends every entry is swept: decode ``bk``, prefill
# ``bq``/``bk`` for the fresh and offset forms, and ``bq`` for the paged
# form (its KV tile is pinned to the pool block size).  Off-TPU these
# defaults stand in — an interpret-mode timing would only rank Python
# overhead.
ATTN_TILE_DEFAULTS = {
    "flash_attention": {"bq": 512, "bk": 512},
    "flash_attention_offset": {"bq": 512, "bk": 512},
    "flash_attention_paged": {"bq": 512},
    "flash_decode": {"bk": 512},
}
DECODE_BK_CANDIDATES = (128, 256, 512, 1024)
PREFILL_TILE_CANDIDATES = (256, 512, 1024)
_PAGED_TUNE_BLOCK = 128      # synthetic pool page size for the paged sweep


@dataclass(frozen=True)
class BlockDecision:
    backend: str
    vocab: int
    dtype: str
    block: int                       # winning ⊕-tree leaf width
    timings_us: tuple                # ((candidate, best_of_reps_us), ...)

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass(frozen=True)
class TileDecision:
    op: str                          # "flash_attention" | "flash_decode"
    backend: str
    kv_len: int
    head_dim: int
    dtype: str
    tiles: dict                      # resolved {"bq": ..} / {"bk": ..}
    timings_us: tuple                # ((candidate, best_of_reps_us), ...) or ()

    def to_dict(self) -> dict:
        return asdict(self)


# ---------------------------------------------------------------------------
# On-disk persistence: decisions survive the process so serving restarts skip
# the sweep.  Best-effort — an unwritable/corrupt cache never breaks dispatch.
# ---------------------------------------------------------------------------
AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
CACHE_SCHEMA_VERSION = 1       # stamped into every payload; mismatch → re-sweep
_DEFAULT_CACHE_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "autotune.json")
_CACHE_WARNED = False          # warn once per process, then stay quiet


def autotune_cache_path() -> str | None:
    """Resolved cache file path; ``REPRO_AUTOTUNE_CACHE=`` (empty) disables."""
    p = os.environ.get(AUTOTUNE_CACHE_ENV)
    if p is not None:
        return p or None
    return _DEFAULT_CACHE_PATH


def _warn_cache_once(path: str, why: str) -> None:
    global _CACHE_WARNED
    if _CACHE_WARNED:
        return
    _CACHE_WARNED = True
    import warnings
    warnings.warn(f"ignoring autotune cache {path!r} ({why}); decisions will "
                  "be re-swept and the file rewritten", stacklevel=3)


def _read_cache_payload(path: str) -> dict | None:
    """Parse + schema-check one cache file; None (with a one-time warning)
    on anything unusable.  This runs at import, so it must never raise."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        _warn_cache_once(path, f"unreadable: {e}")
        return None
    if not isinstance(data, dict):
        _warn_cache_once(path, f"top-level {type(data).__name__}, not an "
                               "object")
        return None
    if data.get("version") != CACHE_SCHEMA_VERSION:
        _warn_cache_once(path, f"schema version {data.get('version')!r} != "
                               f"{CACHE_SCHEMA_VERSION}")
        return None
    if not isinstance(data.get("blocks", []), list) \
            or not isinstance(data.get("tiles", []), list):
        _warn_cache_once(path, "blocks/tiles are not lists")
        return None
    return data


def load_persisted_decisions(path: str | None = None) -> int:
    """Merge on-disk decisions into the in-process caches (existing in-memory
    entries win).  Returns the number of entries loaded.  A corrupt or
    schema-mismatched file warns once and loads nothing — the sweeps run
    again and the next save rewrites the file with the current schema."""
    path = path if path is not None else autotune_cache_path()
    if not path or not os.path.exists(path):
        return 0
    data = _read_cache_payload(path)
    if data is None:
        return 0
    n = 0
    for d in data.get("blocks", ()):
        try:
            dec = BlockDecision(
                backend=str(d["backend"]), vocab=int(d["vocab"]),
                dtype=str(d["dtype"]), block=int(d["block"]),
                timings_us=tuple(tuple(t) for t in d["timings_us"]))
        except (KeyError, TypeError, ValueError):
            continue
        key = (dec.backend, dec.vocab, dec.dtype)
        if key not in _BLOCK_CACHE:
            _BLOCK_CACHE[key] = dec
            n += 1
    for d in data.get("tiles", ()):
        try:
            dec = TileDecision(
                op=str(d["op"]), backend=str(d["backend"]),
                kv_len=int(d["kv_len"]), head_dim=int(d["head_dim"]),
                dtype=str(d["dtype"]), tiles=dict(d["tiles"]),
                timings_us=tuple(tuple(t) for t in d["timings_us"]))
        except (KeyError, TypeError, ValueError):
            continue
        key = (dec.op, dec.backend, dec.kv_len, dec.head_dim, dec.dtype)
        if key not in _TILE_CACHE:
            _TILE_CACHE[key] = dec
            n += 1
    return n


def save_persisted_decisions(path: str | None = None) -> bool:
    """Write the merged (disk ∪ memory, memory wins) decision set to disk.
    A corrupt or schema-mismatched existing file contributes nothing to the
    merge and is simply overwritten with the current schema."""
    path = path if path is not None else autotune_cache_path()
    if not path:
        return False
    merged_blocks: dict[tuple, dict] = {}
    merged_tiles: dict[tuple, dict] = {}
    old = _read_cache_payload(path) if os.path.exists(path) else None
    if old is not None:
        try:
            for d in old.get("blocks", ()):
                merged_blocks[(d["backend"], int(d["vocab"]), d["dtype"])] = d
            for d in old.get("tiles", ()):
                merged_tiles[(d["op"], d["backend"], int(d["kv_len"]),
                              int(d["head_dim"]), d["dtype"])] = d
        except (KeyError, TypeError, ValueError):
            pass
    for key, dec in _BLOCK_CACHE.items():
        merged_blocks[key] = dec.to_dict()
    for key, dec in _TILE_CACHE.items():
        merged_tiles[key] = dec.to_dict()
    payload = {"version": CACHE_SCHEMA_VERSION,
               "blocks": list(merged_blocks.values()),
               "tiles": list(merged_tiles.values())}
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
        os.replace(tmp, path)
        return True
    except OSError:
        return False


def _time_blocked(x: Array, block: int) -> float:
    if compat.pallas_native():
        # time the thing being configured: the Pallas kernel at this tile
        # width (an XLA-scan proxy would not rank Mosaic VMEM tiles)
        from repro.kernels import ops
        fn = jax.jit(functools.partial(ops.online_normalizer, v_blk=block))
    else:
        fn = jax.jit(functools.partial(core.online_normalizer_blocked,
                                       block=block))
    jax.block_until_ready(fn(x))                       # compile + warm
    best = float("inf")
    for _ in range(_TUNE_REPS):
        t0 = obs_clock.perf_counter()
        jax.block_until_ready(fn(x))
        best = min(best, obs_clock.perf_counter() - t0)
    return best * 1e6


def block_decision(vocab: int, dtype=jnp.float32) -> BlockDecision:
    """Winning vocab-axis block for this (backend, vocab, dtype) — cached."""
    vocab = int(vocab)
    key = (compat.backend(), vocab, jnp.dtype(dtype).name)
    hit = _BLOCK_CACHE.get(key)
    if hit is not None:
        obs_kernels.record_autotune("block", key, hit.to_dict())
        return hit
    global _SWEEPS
    _SWEEPS += 1
    # The sweep may be triggered while an outer jax.jit is tracing (the
    # serving step jits decode); without this guard the candidates become
    # tracers, block_until_ready no-ops, and the "timings" are per-candidate
    # tracing overhead.  ensure_compile_time_eval suspends the outer trace so
    # the sweep runs (and measures) real execution; no-op when called eagerly.
    with jax.ensure_compile_time_eval():
        cands = sorted({min(b, vocab) for b in BLOCK_CANDIDATES})
        x = (jnp.arange(_TUNE_ROWS * vocab, dtype=jnp.float32) % 251.0
             ).reshape(_TUNE_ROWS, vocab).astype(dtype)
        timings = tuple((b, round(_time_blocked(x, b), 2)) for b in cands)
    winner = min(timings, key=lambda t: t[1])[0]
    decision = BlockDecision(backend=key[0], vocab=vocab, dtype=key[2],
                             block=winner, timings_us=timings)
    _BLOCK_CACHE[key] = decision
    obs_kernels.record_autotune("block", key, decision.to_dict())
    save_persisted_decisions()
    return decision


def tuned_block(vocab: int, dtype=jnp.float32) -> int:
    return block_decision(vocab, dtype).block


def _time_decode_bk(kv_len: int, head_dim: int, dtype, bk: int) -> float:
    from repro.kernels import ops
    q = jnp.ones((_TUNE_ROWS, 8, head_dim), dtype)
    kc = jnp.ones((_TUNE_ROWS, kv_len, 8, head_dim), dtype)
    vlen = jnp.full((_TUNE_ROWS,), kv_len, jnp.int32)
    fn = jax.jit(functools.partial(ops.flash_decode, bk=bk))
    jax.block_until_ready(fn(q, kc, kc, vlen))
    best = float("inf")
    for _ in range(_TUNE_REPS):
        t0 = obs_clock.perf_counter()
        jax.block_until_ready(fn(q, kc, kc, vlen))
        best = min(best, obs_clock.perf_counter() - t0)
    return best * 1e6


def _time_prefill_tiles(op: str, kv_len: int, head_dim: int, dtype,
                        bq: int, bk: int) -> float:
    """Time one (bq, bk) candidate of a prefill-form flash kernel.

    ``flash_attention`` times the fresh self-attention form;
    ``flash_attention_offset`` the cached-chunk form (queries offset halfway
    into the cache); ``flash_attention_paged`` a synthetic block pool of
    ``_PAGED_TUNE_BLOCK``-wide pages (bk is the page size there — only bq is
    a free knob)."""
    from repro.kernels import ops
    hq, hkv = 8, 8
    if op == "flash_attention_paged":
        bs = _PAGED_TUNE_BLOCK
        m = max(kv_len // bs, 1)
        tq = max(min(bq, kv_len), 1)
        q = jnp.ones((_TUNE_ROWS, tq, hq, head_dim), dtype)
        pool = jnp.ones((_TUNE_ROWS * m + 1, hkv, bs, head_dim), dtype)
        tables = (jnp.arange(_TUNE_ROWS * m, dtype=jnp.int32)
                  .reshape(_TUNE_ROWS, m) + 1)
        qoff = jnp.full((_TUNE_ROWS,), (m - 1) * bs, jnp.int32)
        vlen = jnp.full((_TUNE_ROWS,), m * bs, jnp.int32)
        fn = jax.jit(functools.partial(ops.paged_flash_attention, bq=bq))
        args = (q, pool, pool, qoff, vlen, tables)
    elif op == "flash_attention_offset":
        tq = max(kv_len // 2, 1)
        q = jnp.ones((_TUNE_ROWS, tq, hq, head_dim), dtype)
        kv = jnp.ones((_TUNE_ROWS, kv_len, hkv, head_dim), dtype)
        qoff = jnp.full((_TUNE_ROWS,), kv_len - tq, jnp.int32)
        vlen = jnp.full((_TUNE_ROWS,), kv_len, jnp.int32)
        fn = jax.jit(functools.partial(ops.flash_attention, bq=bq, bk=bk))
        args = (q, kv, kv)
        fn = functools.partial(fn, q_offset=qoff, kv_valid_len=vlen)
    else:
        q = jnp.ones((_TUNE_ROWS, kv_len, hq, head_dim), dtype)
        kv = jnp.ones((_TUNE_ROWS, kv_len, hkv, head_dim), dtype)
        fn = jax.jit(functools.partial(ops.flash_attention, bq=bq, bk=bk))
        args = (q, kv, kv)
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(_TUNE_REPS):
        t0 = obs_clock.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, obs_clock.perf_counter() - t0)
    return best * 1e6


_PREFILL_TILE_OPS = ("flash_attention", "flash_attention_offset",
                     "flash_attention_paged")


def attention_tiles(op: str, *, kv_len: int, head_dim: int,
                    dtype=jnp.float32) -> dict:
    """Resolved attention tile sizes for ``op`` — the one seam for bq/bk.

    On backends with native Pallas lowering every form is swept per
    (backend, kv_len, head_dim, dtype): decode ``bk``, prefill ``bq``/``bk``
    for the fresh and offset forms, and ``bq`` for the paged form (whose KV
    tile is the pool block size).  Elsewhere the registry defaults apply (a
    meaningless interpret-mode timing would just rank Python overhead).
    Decisions are cached in-process and persisted alongside the vocab-block
    decisions in the version-stamped ``REPRO_AUTOTUNE_CACHE``.
    """
    kv_len, head_dim = int(kv_len), int(head_dim)
    key = (op, compat.backend(), kv_len, head_dim, jnp.dtype(dtype).name)
    hit = _TILE_CACHE.get(key)
    if hit is not None:
        obs_kernels.record_autotune("tiles", key, hit.to_dict())
        return dict(hit.tiles)
    defaults = dict(ATTN_TILE_DEFAULTS[op])
    global _SWEEPS
    if op == "flash_decode" and compat.pallas_native():
        _SWEEPS += 1
        with jax.ensure_compile_time_eval():
            cands = sorted({min(b, kv_len) for b in DECODE_BK_CANDIDATES
                            if kv_len % min(b, kv_len) == 0})
            timings = tuple(
                (b, round(_time_decode_bk(kv_len, head_dim, dtype, b), 2))
                for b in cands)
        defaults["bk"] = min(timings, key=lambda t: t[1])[0]
    elif op in _PREFILL_TILE_OPS and compat.pallas_native():
        _SWEEPS += 1
        with jax.ensure_compile_time_eval():
            bqs = sorted({min(c, kv_len) for c in PREFILL_TILE_CANDIDATES})
            if op == "flash_attention_paged":   # bk pinned to the page size
                cands = [(bq, 0) for bq in bqs]
            else:
                bks = sorted({min(c, kv_len) for c in PREFILL_TILE_CANDIDATES
                              if kv_len % min(c, kv_len) == 0})
                cands = [(bq, bk) for bq in bqs for bk in bks]
            timings = tuple(
                ((bq, bk),
                 round(_time_prefill_tiles(op, kv_len, head_dim, dtype,
                                           bq, bk), 2))
                for bq, bk in cands)
        best_bq, best_bk = min(timings, key=lambda t: t[1])[0]
        defaults["bq"] = best_bq
        if "bk" in defaults:
            defaults["bk"] = best_bk
    else:
        timings = ()
    decision = TileDecision(op=op, backend=key[1], kv_len=kv_len,
                            head_dim=head_dim, dtype=key[4],
                            tiles=defaults, timings_us=timings)
    _TILE_CACHE[key] = decision
    obs_kernels.record_autotune("tiles", key, decision.to_dict())
    if timings:                      # defaults-only decisions aren't worth IO
        save_persisted_decisions()
    return dict(decision.tiles)


def autotune_stats() -> dict:
    return {"sweeps": _SWEEPS, "entries": len(_BLOCK_CACHE)}


def tile_stats() -> dict:
    return {"entries": len(_TILE_CACHE)}


def reset_autotune_cache() -> None:
    """Clear the in-process decision caches (the on-disk cache is untouched;
    it is only consulted at import via ``load_persisted_decisions``)."""
    global _SWEEPS, _CACHE_WARNED
    _BLOCK_CACHE.clear()
    _TILE_CACHE.clear()
    _SWEEPS = 0
    _CACHE_WARNED = False


# ---------------------------------------------------------------------------
# Registered implementations.  Pallas entries import lazily so the registry
# stays importable on hosts where jax.experimental.pallas cannot load.
# ---------------------------------------------------------------------------
@register("online_softmax", PATH_PALLAS, PATH_PALLAS_INTERPRET)
def _online_softmax_pallas(x: Array) -> Array:
    from repro.kernels import ops
    return ops.online_softmax(x)               # v_blk unset → tuned_block


@register("online_softmax", PATH_XLA)
def _online_softmax_xla(x: Array) -> Array:
    return core.online_softmax(x)


# Reduced-precision forms: same online (m, d) recurrence, cheaper arithmetic.
# XLA-only for now — the paper's associativity argument makes them drop-in
# for the kernels once a native backend wants them; the analytic bounds in
# core.softmax_forms (pinned by tests/test_numerics.py) are the gate.
@register("online_softmax_bf16", PATH_XLA)
def _online_softmax_bf16(x: Array) -> Array:
    from repro.core import softmax_forms
    return softmax_forms.softmax_bf16(x)


@register("online_softmax_exp2", PATH_XLA)
def _online_softmax_exp2(x: Array) -> Array:
    from repro.core import softmax_forms
    return softmax_forms.softmax_exp2(x)


@register("softmax_topk", PATH_PALLAS, PATH_PALLAS_INTERPRET)
def _softmax_topk_pallas(x: Array, k: int) -> "core.SoftmaxTopK":
    from repro.kernels import ops
    vals, idx, lse = ops.softmax_topk(x, k)    # v_blk unset → tuned_block
    return core.SoftmaxTopK(vals, idx, lse)


@register("softmax_topk", PATH_XLA)
def _softmax_topk_xla(x: Array, k: int,
                      block: int | None = None) -> "core.SoftmaxTopK":
    return core.softmax_topk(x, k, block=block)


@register("attention", PATH_PALLAS, PATH_PALLAS_INTERPRET)
def _attention_pallas(cfg, q, k, v, *, causal, q_offset, kv_valid_len, scale):
    from repro.kernels import ops
    if kv_valid_len is None and isinstance(q_offset, int) and q_offset == 0:
        # fresh (train / no-cache) self-attention: the differentiable form
        return ops.flash_attention(q, k, v, causal=causal)
    # cached (chunked) prefill: queries offset into a partially-valid cache —
    # absolute-coordinate causal masking + per-row valid-length masking on
    # the kernel (inference-only)
    return ops.flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_valid_len=kv_valid_len)


@register("attention", PATH_XLA)
def _attention_xla(cfg, q, k, v, *, causal, q_offset, kv_valid_len, scale):
    return core.online_attention(q, k, v, causal=causal, q_offset=q_offset,
                                 kv_valid_len=kv_valid_len,
                                 chunk_size=cfg.attn_chunk, scale=scale,
                                 causal_blocks=cfg.attn_causal_blocks)


@register("attention", PATH_XLA_NAIVE)
def _attention_naive(cfg, q, k, v, *, causal, q_offset, kv_valid_len, scale):
    return core.naive_attention(q, k, v, causal=causal, q_offset=q_offset,
                                kv_valid_len=kv_valid_len, scale=scale)


@register("decode_attention", PATH_PALLAS)
def _decode_attention_pallas(cfg, q, k, v, *, q_offset, kv_valid_len, scale):
    """Single-token decode on the Pallas streaming kernel.  ``kv_valid_len``
    [B] is the per-slot length vector — each cache slot masks its own tail,
    which is what lets continuous batching mix ragged sequences in one call.
    The kernel bakes in the default 1/sqrt(d) scale; a custom scale (MLA)
    falls back to the chunked XLA form."""
    if scale is not None and scale != q.shape[-1] ** -0.5:
        return _decode_attention_xla(cfg, q, k, v, q_offset=q_offset,
                                     kv_valid_len=kv_valid_len, scale=scale)
    from repro.kernels import ops
    return ops.flash_decode(q[:, 0], k, v, kv_valid_len)[:, None]


@register("decode_attention", PATH_XLA)
def _decode_attention_xla(cfg, q, k, v, *, q_offset, kv_valid_len, scale):
    return core.online_attention(q, k, v, causal=False, q_offset=q_offset,
                                 kv_valid_len=kv_valid_len,
                                 chunk_size=cfg.attn_chunk, scale=scale)


# ---------------------------------------------------------------------------
# Paged KV cache: block-pool + block-table attention.  The pools are
# [P, Hkv, BS, D]; ``block_tables`` [B, M] maps each row's logical blocks to
# physical pool blocks.  Pallas paths gather pages in the kernel's index
# maps; the XLA fallback gathers the table into a contiguous [B, M·BS] cache
# and runs the chunked online form — bit-identical to the contiguous
# slot-pool path for every valid position (masked columns update (m, d)
# exactly), which is what the paged serving equivalence tests pin.
# ---------------------------------------------------------------------------
def _gather_pages(pool: Array, block_tables: Array) -> Array:
    """[P, Hkv, BS, D] + [B, M] → contiguous [B, M·BS, Hkv, D] (model layout).

    Positions past a row's ``kv_valid_len`` gather stale or sentinel blocks —
    finite garbage the attention mask erases exactly."""
    g = pool[block_tables]                      # [B, M, Hkv, BS, D]
    g = jnp.swapaxes(g, 2, 3)                   # [B, M, BS, Hkv, D]
    return g.reshape(block_tables.shape[0], -1, pool.shape[1], pool.shape[3])


def _gather_scale_pages(pool: Array, block_tables: Array) -> Array:
    """Scale pages [P, Hkv, BS] + [B, M] → contiguous [B, M·BS, Hkv] — the
    ``k_scale``/``v_scale`` layout ``_chunked_fwd_impl`` dequantizes with.
    Same table, same ordering as ``_gather_pages``, so position i's scale
    lands exactly beside position i's int8 row."""
    g = pool[block_tables]                      # [B, M, Hkv, BS]
    g = jnp.swapaxes(g, 2, 3)                   # [B, M, BS, Hkv]
    return g.reshape(block_tables.shape[0], -1, pool.shape[1])


def _gathered_int8_chunked(cfg, q, k, v, *, causal, q_offset, kv_valid_len,
                           block_tables, scale, k_scale, v_scale):
    """Quantized paged fallback: gather int8 pages + scale pages through the
    table, then run the SAME dequantizing chunked form the unpaged int8
    cache uses (`_chunked_fwd_impl`).  The gathered length is M·BS =
    slot_len, so the chunk split, the dequant arithmetic, and the masking
    are identical to the unpaged call — which is what makes paged int8
    decode bit-exact against unpaged int8 decode."""
    from repro.core.attention import _chunked_fwd_impl
    kg = _gather_pages(k, block_tables)
    vg = _gather_pages(v, block_tables)
    b = q.shape[0]
    out, _ = _chunked_fwd_impl(
        q, kg, vg, jnp.asarray(q_offset, jnp.int32),
        jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32), (b,)),
        causal, min(cfg.attn_chunk, kg.shape[1]),
        scale if scale is not None else q.shape[-1] ** -0.5,
        k_scale=_gather_scale_pages(k_scale, block_tables),
        v_scale=_gather_scale_pages(v_scale, block_tables))
    return out


@register("paged_attention", PATH_PALLAS, PATH_PALLAS_INTERPRET)
def _paged_attention_pallas(cfg, q, k, v, *, causal, q_offset, kv_valid_len,
                            block_tables, scale, k_scale=None, v_scale=None):
    from repro.kernels import ops
    return ops.paged_flash_attention(q, k, v, q_offset, kv_valid_len,
                                     block_tables, causal=causal,
                                     k_scale_pool=k_scale,
                                     v_scale_pool=v_scale)


@register("paged_attention", PATH_XLA)
def _paged_attention_xla(cfg, q, k, v, *, causal, q_offset, kv_valid_len,
                         block_tables, scale, k_scale=None, v_scale=None):
    if k_scale is not None:
        return _gathered_int8_chunked(
            cfg, q, k, v, causal=causal, q_offset=q_offset,
            kv_valid_len=kv_valid_len, block_tables=block_tables,
            scale=scale, k_scale=k_scale, v_scale=v_scale)
    return core.online_attention(
        q, _gather_pages(k, block_tables), _gather_pages(v, block_tables),
        causal=causal, q_offset=q_offset, kv_valid_len=kv_valid_len,
        chunk_size=cfg.attn_chunk, scale=scale)


@register("paged_decode_attention", PATH_PALLAS)
def _paged_decode_attention_pallas(cfg, q, k, v, *, q_offset, kv_valid_len,
                                   block_tables, scale, k_scale=None,
                                   v_scale=None):
    """Single-token decode over paged KV on the Pallas streaming kernel.
    The kernel bakes in the default 1/sqrt(d) scale; a custom scale falls
    back to the gather + chunked XLA form.  Quantized pools pass their
    scale pages through — the kernel dequantizes tile-local."""
    if scale is not None and scale != q.shape[-1] ** -0.5:
        return _paged_decode_attention_xla(
            cfg, q, k, v, q_offset=q_offset, kv_valid_len=kv_valid_len,
            block_tables=block_tables, scale=scale, k_scale=k_scale,
            v_scale=v_scale)
    from repro.kernels import ops
    return ops.paged_flash_decode(q[:, 0], k, v, block_tables,
                                  kv_valid_len, k_scale_pool=k_scale,
                                  v_scale_pool=v_scale)[:, None]


@register("paged_decode_attention", PATH_XLA)
def _paged_decode_attention_xla(cfg, q, k, v, *, q_offset, kv_valid_len,
                                block_tables, scale, k_scale=None,
                                v_scale=None):
    if k_scale is not None:
        return _gathered_int8_chunked(
            cfg, q, k, v, causal=False, q_offset=q_offset,
            kv_valid_len=kv_valid_len, block_tables=block_tables,
            scale=scale, k_scale=k_scale, v_scale=v_scale)
    return core.online_attention(
        q, _gather_pages(k, block_tables), _gather_pages(v, block_tables),
        causal=False, q_offset=q_offset, kv_valid_len=kv_valid_len,
        chunk_size=cfg.attn_chunk, scale=scale)


def _paged_sdpa(cfg, q, k, v, *, causal, q_offset, kv_valid_len, scale,
                decode, block_tables, k_scale=None, v_scale=None):
    """Routing for block-table attention: mirrors the contiguous policy.

    Decode: Pallas paged streaming kernel where native under a Pallas
    preference, else the gather + chunked XLA form.  Prefill: Pallas
    (compiled or interpret) under a Pallas preference unless the shape is
    kernel-unrepresentable (custom scale, value-dim ≠ key-dim), else XLA.
    Quantized pools (``k_scale``/``v_scale`` pages set) ride the same
    routing — every path dequantizes after its gather.  Paged serving is
    single-host: an ambient ShardContext is a routing bug, not a fallback
    case."""
    from repro.distributed import context
    if context.get() is not None:
        raise NotImplementedError(
            "paged KV attention has no sharded ⊕-merge form yet; drop the "
            "ShardContext or serve unpaged")
    kernel_ok = ((scale is None or scale == q.shape[-1] ** -0.5)
                 and v.shape[-1] == q.shape[-1])
    if decode:
        if cfg.use_pallas and \
                select_path("paged_decode_attention") == PATH_PALLAS:
            fn = _REGISTRY["paged_decode_attention"][PATH_PALLAS]
        else:
            fn = _REGISTRY["paged_decode_attention"][PATH_XLA]
        return fn(cfg, q, k, v, q_offset=q_offset, kv_valid_len=kv_valid_len,
                  block_tables=block_tables, scale=scale, k_scale=k_scale,
                  v_scale=v_scale)
    if cfg.use_pallas and kernel_ok:
        path = select_path("paged_attention", prefer_pallas=True)
    else:
        path = PATH_XLA
    return _REGISTRY["paged_attention"][path](
        cfg, q, k, v, causal=causal, q_offset=q_offset,
        kv_valid_len=kv_valid_len, block_tables=block_tables, scale=scale,
        k_scale=k_scale, v_scale=v_scale)


# ---------------------------------------------------------------------------
# Public dispatched ops.
# ---------------------------------------------------------------------------
SOFTMAX_FORMS = ("exact", "bf16", "exp2")
_SOFTMAX_FORM = "exact"


def softmax_form() -> str:
    """The reduced-precision softmax form currently preferred ("exact" /
    "bf16" / "exp2")."""
    return _SOFTMAX_FORM


def set_softmax_form(form: str) -> str:
    """Set the process softmax-form preference; returns the previous form.

    "exact" is the registry's standard online form; "bf16" accumulates the
    normalizer in bfloat16; "exp2" computes exponentials as
    ``2^((x−m)·log2 e)`` (the hardware-exp2 menu of PAPERS.md 2201.04562 /
    2111.10770).  Every form's worst-case deviation from the fp32 two-pass
    reference is bounded analytically in ``core.softmax_forms`` and pinned
    by ``tests/test_numerics.py``.  Also settable via the
    ``REPRO_SOFTMAX_FORM`` environment variable (read at import).
    """
    global _SOFTMAX_FORM
    if form not in SOFTMAX_FORMS:
        raise ValueError(
            f"unknown softmax form {form!r}; expected one of {SOFTMAX_FORMS}")
    prev = _SOFTMAX_FORM
    _SOFTMAX_FORM = form
    return prev


def online_softmax(x: Array) -> Array:
    """Softmax over the last axis via the best path for this backend,
    honoring the process softmax-form preference (``set_softmax_form`` /
    ``REPRO_SOFTMAX_FORM``)."""
    if _SOFTMAX_FORM != "exact":
        _, fn = lookup(f"online_softmax_{_SOFTMAX_FORM}")
        return fn(x)
    _, fn = lookup("online_softmax")
    return fn(x)


def softmax_topk(x: Array, k: int,
                 differentiable: bool = False) -> "core.SoftmaxTopK":
    """Fused softmax+top-k (paper Algorithm 4) via the registry.

    Every path is differentiable: the Pallas kernel carries a custom VJP
    (recompute-the-softmax-from-LSE backward, mirroring ``flash_attention``'s
    recompute-from-(m, d) rule), so autodiff callers — the MoE router under
    ``value_and_grad`` — route through the same backend policy as everyone
    else.  ``differentiable`` is kept for caller compatibility; it no longer
    pins the XLA form.
    """
    del differentiable
    _, fn = lookup("softmax_topk")
    return fn(x, k)


def sdpa(cfg, q, k, v, *, causal, q_offset, kv_valid_len, scale=None,
         decode: bool = False, k_scale=None, v_scale=None,
         block_tables=None):
    """Attention dispatch — the single entry model layers call.

    Routing order: paged block-table attention (``block_tables`` set: K/V
    are block pools, see ``_paged_sdpa``) → sharded ⊕-merge decode (ambient
    ``ShardContext``) → int8-cache direct chunked decode → registry (pallas /
    pallas-interpret / xla-chunked / naive by config preference and backend
    capability).

    Arguments
    ---------
    cfg:
        Model config; ``cfg.use_pallas`` / ``cfg.use_online_attention``
        state the path preference, ``cfg.attn_chunk`` sizes the chunked
        XLA form.
    q, k, v:
        q [B, Tq, Hq, D].  Contiguous: k/v [B, S, Hkv, D] caches (or fresh
        prompt K/V).  Paged: k/v are block *pools* [P, Hkv, BS, D] shared
        by every sequence.
    causal:
        Causal masking in absolute coordinates (``k_pos ≤ q_offset + i``).
    q_offset:
        Absolute position of query row 0 — scalar, or [B] with one offset
        per slot (continuous batching; a resumed preempted sequence simply
        carries its pre-swap length here).
    kv_valid_len:
        Valid cache prefix per row (scalar or [B]); columns at or past it
        are masked to −inf before the online ``(m, d)`` update, which is
        exact — ragged slots, dead page entries, and pool padding cannot
        perturb numerics.
    scale:
        Softmax scale; None = 1/√D.  A custom scale (MLA) pins the chunked
        XLA form — the kernels bake the default in.
    decode:
        Single-token decode (Tq == 1 semantics): routes the streaming
        decode kernels / decode registry ops instead of the prefill forms.
    k_scale, v_scale:
        Per-position int8-cache dequant scales: contiguous [B, S, Hkv]
        (selects the direct dequantizing chunked path), or scale *pages*
        [P, Hkv, BS] when ``block_tables`` is set — gathered/prefetched
        alongside the int8 pools and applied after the read
        (inference-only).
    block_tables:
        [B, max_blocks] logical→physical block map (paged serving).  Built
        ONLY by ``repro.serving.paged``; consumed here.  Selects the paged
        registry ops with the gather + chunked-XLA fallback off-TPU.
    """
    if block_tables is not None:
        return _paged_sdpa(cfg, q, k, v, causal=causal, q_offset=q_offset,
                           kv_valid_len=kv_valid_len, scale=scale,
                           decode=decode, block_tables=block_tables,
                           k_scale=k_scale, v_scale=v_scale)
    from repro.distributed import context
    ctx = context.get()
    if decode and ctx is not None:
        from repro.distributed.decode_attention import sharded_decode_attention
        return sharded_decode_attention(
            q, k, v, kv_valid_len, mesh=ctx.mesh,
            seq_axes=ctx.cache_seq_axes, batch_axes=ctx.batch_axes,
            chunk_size=cfg.attn_chunk,
            scale=scale if scale is not None else q.shape[-1] ** -0.5,
            k_scale=k_scale, v_scale=v_scale)
    if k_scale is not None:
        # int8 cache, single-device decode: inference-only direct call
        from repro.core.attention import _chunked_fwd_impl
        b = q.shape[0]
        out, _ = _chunked_fwd_impl(
            q, k, v, jnp.asarray(q_offset, jnp.int32),
            jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32), (b,)),
            causal, min(cfg.attn_chunk, k.shape[1]),
            scale if scale is not None else q.shape[-1] ** -0.5,
            k_scale=k_scale, v_scale=v_scale)
        return out
    if decode:
        # single-token decode: per-row kv_valid_len masking (ragged slot
        # lengths under continuous batching).  Same preference semantics as
        # prefill — Pallas stays opt-in via cfg.use_pallas (streaming kernel
        # where native, chunked XLA otherwise), use_online_attention picks
        # chunked XLA, and neither keeps the naive oracle form.
        if cfg.use_pallas and select_path("decode_attention") == PATH_PALLAS:
            fn = _REGISTRY["decode_attention"][PATH_PALLAS]
        elif cfg.use_online_attention or cfg.use_pallas:
            fn = _REGISTRY["decode_attention"][PATH_XLA]
        else:
            return _REGISTRY["attention"][PATH_XLA_NAIVE](
                cfg, q, k, v, causal=False, q_offset=q_offset,
                kv_valid_len=kv_valid_len, scale=scale)
        return fn(cfg, q, k, v, q_offset=q_offset,
                  kv_valid_len=kv_valid_len, scale=scale)
    if (cfg.use_pallas and q.shape[1] > 1
            and (scale is None or scale == q.shape[-1] ** -0.5)
            and v.shape[-1] == q.shape[-1]):
        # prefill — fresh OR cached/chunked: the flash kernel carries
        # q_offset/kv_valid_len operands (absolute-coordinate causal mask,
        # per-row valid-length mask), so cached chunked prefill no longer
        # has to detour through the chunked XLA form on native backends.
        # Still XLA: custom-scale or value-dim≠key-dim attention (MLA's
        # absorbed decode), which the kernel does not model.
        path = select_path("attention", prefer_pallas=True)
    elif cfg.use_online_attention or cfg.use_pallas:
        # chunked XLA fallback (masks offset + valid length exactly) — also
        # the landing spot for the kernel-unrepresentable cases above
        path = PATH_XLA
    else:
        path = PATH_XLA_NAIVE
    return _REGISTRY["attention"][path](
        cfg, q, k, v, causal=causal, q_offset=q_offset,
        kv_valid_len=kv_valid_len, scale=scale)


# Import-time: merge persisted decisions so a serving restart skips the sweep.
load_persisted_decisions()
# Import-time: honor the softmax-form environment preference.
if os.environ.get("REPRO_SOFTMAX_FORM"):
    set_softmax_form(os.environ["REPRO_SOFTMAX_FORM"])
