"""Pallas TPU kernel: online softmax (paper Algorithm 3), tiled for VMEM.

Two sweeps over the vocabulary tiles, mirroring the two loops of Algorithm 3:

* ``_normalizer_kernel`` — lines 1–6: one pass over V-tiles per row-block,
  carrying ``(m, d)`` resident in the output VMEM blocks (they only spill to
  HBM once per row-block, when the output window changes).  1 HBM load/elem.
* ``_normalize_kernel`` — lines 7–9: elementwise ``e^{x−m}/d``.
  1 load + 1 store/elem.

Total: 3 HBM accesses per element vs safe softmax's 4 — the paper's reduction,
with "memory access" re-read as HBM↔VMEM transfer per DESIGN.md §2.

Tiling: rows map to sublanes (block R_BLK), vocab to lanes (block V_BLK,
a multiple of 128).  ``(m, d)`` are [R, 1] so each row-block's statistics
occupy one lane — the ⊕ update is a pure VPU op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")
DEFAULT_R_BLK = 256
DEFAULT_V_BLK = 2048


def _normalizer_kernel(x_ref, m_ref, d_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    x = x_ref[...].astype(jnp.float32)                 # [R_BLK, V_BLK]
    m_prev = m_ref[...]                                # [R_BLK, 1]
    m_tile = jnp.max(x, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_tile)                # Alg. 3 line 4
    alpha = jnp.exp(jnp.where(m_prev == m_new, 0.0, m_prev - m_new))
    d_tile = jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True)
    d_ref[...] = d_ref[...] * alpha + d_tile           # Alg. 3 line 5 (tile ⊕)
    m_ref[...] = m_new


def _normalize_kernel(x_ref, m_ref, d_ref, y_ref):
    x = x_ref[...].astype(jnp.float32)
    y = jnp.exp(x - m_ref[...]) / d_ref[...]           # Alg. 3 line 8
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("r_blk", "v_blk", "interpret"))
def online_softmax_pallas(x: jax.Array, *, r_blk: int = DEFAULT_R_BLK,
                          v_blk: int = DEFAULT_V_BLK,
                          interpret: bool = False) -> jax.Array:
    """Softmax over the last axis of a 2-D [R, V] array."""
    r, v = x.shape
    r_blk = min(r_blk, r)
    v_blk = min(v_blk, v)
    assert r % r_blk == 0 and v % v_blk == 0, (x.shape, r_blk, v_blk)
    grid = (r // r_blk, v // v_blk)

    m, d = pl.pallas_call(
        _normalizer_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((r_blk, v_blk), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((r_blk, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((r_blk, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, 1), jnp.float32),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)],
        interpret=interpret,
    )(x)

    y = pl.pallas_call(
        _normalize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((r_blk, v_blk), lambda i, j: (i, j)),
                  pl.BlockSpec((r_blk, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((r_blk, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((r_blk, v_blk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, v), x.dtype),
        interpret=interpret,
    )(x, m, d)
    return y


@functools.partial(jax.jit, static_argnames=("r_blk", "v_blk", "interpret"))
def online_normalizer_pallas(x: jax.Array, *, r_blk: int = DEFAULT_R_BLK,
                             v_blk: int = DEFAULT_V_BLK,
                             interpret: bool = False):
    """Just the (m, d) statistics — the paper's lines 1-6 as a kernel."""
    r, v = x.shape
    r_blk = min(r_blk, r)
    v_blk = min(v_blk, v)
    assert r % r_blk == 0 and v % v_blk == 0
    m, d = pl.pallas_call(
        _normalizer_kernel,
        grid=(r // r_blk, v // v_blk),
        in_specs=[pl.BlockSpec((r_blk, v_blk), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((r_blk, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((r_blk, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, 1), jnp.float32),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return m[:, 0], d[:, 0]
