"""Pallas TPU kernel: single-token decode attention over a KV cache.

The serving-side hot spot: one query token attends to a long cache.  This is
*pure* memory streaming — arithmetic intensity ~1 FLOP/byte — i.e. exactly the
regime the paper targets: the online ``(m, d)`` carry means the cache is read
ONCE (vs twice for a safe-softmax decode), and no [S]-sized score vector ever
round-trips to HBM.

Grid: (batch, kv_head, kv_block).  All G query heads of a KV group are
processed together so the score tile is [G, BK] (sublanes × lanes).  The valid
cache length is a scalar-prefetch operand (SMEM) used to mask the tail tile;
tiles entirely past ``valid_len`` are skipped.

``flash_decode_paged_pallas`` is the paged-KV form: the cache is a pool of
fixed-size blocks shared by every sequence and a scalar-prefetched
``[B, max_blocks]`` block table maps each row's logical block *j* to a
physical pool block.  The K/V index maps gather one pool block per grid step
(the paper's order-agnostic ``(m, d)`` update is what makes walking an
arbitrary page list in one pass safe), clamping dead table entries to the
row's last live block so they schedule no fetch — the paged twin of the
offset kernel's clamped index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _make_kernel(*, scale: float, g: int, bk: int, n_kv: int):
    def kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, m_sc, d_sc, acc_sc):
        b = pl.program_id(0)
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            m_sc[...] = jnp.full_like(m_sc, NEG_INF)
            d_sc[...] = jnp.zeros_like(d_sc)
            acc_sc[...] = jnp.zeros_like(acc_sc)

        vlen = vlen_ref[b]
        run = j * bk < vlen           # skip tiles wholly past the valid cache

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale     # [G, D]
            k = k_ref[0, 0].astype(jnp.float32)             # [BK, D]
            v = v_ref[0, 0].astype(jnp.float32)
            s = q @ k.T                                     # [G, BK]
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(k_pos < vlen, s, NEG_INF)
            m_prev = m_sc[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
            alpha = jnp.exp(jnp.where(m_prev == m_new, 0.0, m_prev - m_new))
            p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m_new))
            d_sc[...] = d_sc[...] * alpha + jnp.sum(p, -1, keepdims=True)
            acc_sc[...] = acc_sc[...] * alpha + p @ v
            m_sc[...] = m_new

        @pl.when(j == n_kv - 1)
        def _finalize():
            o_ref[0, 0] = (acc_sc[...] /
                           jnp.maximum(d_sc[...], 1e-30)).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode_pallas(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        kv_valid_len: jax.Array, *, bk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q [B, Hq, D]; caches [B, Hkv, S, D]; kv_valid_len [B] → out [B, Hq, D]."""
    b, hq, dh = q.shape
    _, hkv, s, _ = k_cache.shape
    g = hq // hkv
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    n_kv = s // bk
    qg = q.reshape(b, hkv, g, dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b_, h, j, vlen: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, j, vlen: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, j, vlen: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b_, h, j, vlen: (b_, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        _make_kernel(scale=dh ** -0.5, g=g, bk=bk, n_kv=n_kv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_valid_len, jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, hq, dh)


# ---------------------------------------------------------------------------
# Paged form: the cache is a block pool + per-row block table.  The quantized
# variant streams int8 K/V pages plus their bf16 scale pages (same table,
# same clamped page index) and dequantizes tile-local in VMEM — HBM traffic
# stays ~1 byte per cache element.
# ---------------------------------------------------------------------------
def _make_paged_kernel(*, scale: float, g: int, bs: int, n_blocks: int,
                       quantized: bool = False):
    def _update(j, vlen, q_ref, k, v, m_sc, d_sc, acc_sc):
        q = q_ref[0, 0].astype(jnp.float32) * scale         # [G, D]
        s = q @ k.T                                         # [G, BS]
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < vlen, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(jnp.where(m_prev == m_new, 0.0, m_prev - m_new))
        p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m_new))
        d_sc[...] = d_sc[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + p @ v
        m_sc[...] = m_new

    def _init(m_sc, d_sc, acc_sc):
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        d_sc[...] = jnp.zeros_like(d_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def _finalize(o_ref, m_sc, d_sc, acc_sc):
        o_ref[0, 0] = (acc_sc[...] /
                       jnp.maximum(d_sc[...], 1e-30)).astype(o_ref.dtype)

    if quantized:
        def kernel(tbl_ref, vlen_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                   o_ref, m_sc, d_sc, acc_sc):
            b = pl.program_id(0)
            j = pl.program_id(2)      # logical block of row b
            pl.when(j == 0)(lambda: _init(m_sc, d_sc, acc_sc))
            vlen = vlen_ref[b]

            @pl.when(j * bs < vlen)
            def _compute():
                # dequantize AFTER the HBM read: int8 page × per-position
                # scale column, both fetched through the same table entry
                k = (k_ref[0, 0].astype(jnp.float32)
                     * ks_ref[0, 0].astype(jnp.float32)[:, None])  # [BS, D]
                v = (v_ref[0, 0].astype(jnp.float32)
                     * vs_ref[0, 0].astype(jnp.float32)[:, None])
                _update(j, vlen, q_ref, k, v, m_sc, d_sc, acc_sc)

            pl.when(j == n_blocks - 1)(
                lambda: _finalize(o_ref, m_sc, d_sc, acc_sc))
    else:
        def kernel(tbl_ref, vlen_ref, q_ref, k_ref, v_ref, o_ref, m_sc, d_sc,
                   acc_sc):
            b = pl.program_id(0)
            j = pl.program_id(2)      # logical block of row b
            pl.when(j == 0)(lambda: _init(m_sc, d_sc, acc_sc))
            vlen = vlen_ref[b]

            @pl.when(j * bs < vlen)
            def _compute():
                k = k_ref[0, 0].astype(jnp.float32)             # [BS, D]
                v = v_ref[0, 0].astype(jnp.float32)
                _update(j, vlen, q_ref, k, v, m_sc, d_sc, acc_sc)

            pl.when(j == n_blocks - 1)(
                lambda: _finalize(o_ref, m_sc, d_sc, acc_sc))

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged_pallas(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, block_tables: jax.Array,
                              kv_valid_len: jax.Array, *,
                              k_scale_pool: jax.Array | None = None,
                              v_scale_pool: jax.Array | None = None,
                              interpret: bool = False) -> jax.Array:
    """q [B, Hq, D]; pools [P, Hkv, BS, D]; block_tables [B, M] (physical pool
    block per logical block, scalar-prefetched); kv_valid_len [B] →
    out [B, Hq, D].

    The KV tile width is the pool's block size: each grid step streams one
    physical block, addressed through the table.  Logical blocks at or past
    ``ceil(valid_len / BS)`` are dead — their table entries may be stale or
    the sentinel — so the index maps clamp to the row's last live block (no
    fetch scheduled, compute skipped via ``pl.when``), and the tail block's
    out-of-range columns are masked to −inf before the online update.

    ``k_scale_pool``/``v_scale_pool`` [P, Hkv, BS] set selects the quantized
    form: the pools are int8 and each grid step additionally streams the
    page's per-position scale column — through the SAME clamped table index —
    dequantizing in VMEM before the online update.
    """
    b, hq, dh = q.shape
    _, hkv, bs, _ = k_pool.shape
    m = block_tables.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dh)
    quantized = k_scale_pool is not None

    def page_index(tbl_ref, vlen_ref, b_, h, j):
        last = jnp.maximum((vlen_ref[b_] + bs - 1) // bs - 1, 0)
        return (tbl_ref[b_, jnp.minimum(j, last)], h, 0, 0)

    def scale_index(tbl_ref, vlen_ref, b_, h, j):
        return page_index(tbl_ref, vlen_ref, b_, h, j)[:3]

    in_specs = [
        pl.BlockSpec((1, 1, g, dh),
                     lambda b_, h, j, tbl, vl: (b_, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, dh),
                     lambda b_, h, j, tbl, vl: page_index(tbl, vl, b_,
                                                          h, j)),
        pl.BlockSpec((1, 1, bs, dh),
                     lambda b_, h, j, tbl, vl: page_index(tbl, vl, b_,
                                                          h, j)),
    ]
    operands = [qg, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, bs),
                         lambda b_, h, j, tbl, vl: scale_index(tbl, vl, b_,
                                                               h, j)),
            pl.BlockSpec((1, 1, bs),
                         lambda b_, h, j, tbl, vl: scale_index(tbl, vl, b_,
                                                               h, j)),
        ]
        operands += [k_scale_pool, v_scale_pool]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, m),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda b_, h, j, tbl, vl: (b_, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, dh), jnp.float32)],
    )
    out = pl.pallas_call(
        _make_paged_kernel(scale=dh ** -0.5, g=g, bs=bs, n_blocks=m,
                           quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(kv_valid_len, jnp.int32), *operands)
    return out.reshape(b, hq, dh)
