"""Pallas TPU kernels: FlashAttention backward (dq and dk/dv passes).

The backward recomputes probabilities from the forward's saved LSE — the
paper's (m, d) statistics in log form — so the [Tq, Tk] score matrix is
never stored, only re-derived tile by tile (FLOPs traded for HBM, the
paper's economics in reverse).

Two kernels, following the standard two-pass structure:
* ``_dq_kernel``   — grid (B, H, q_block, kv_block): accumulates dq per
  q-tile while streaming KV tiles (VMEM scratch carry).
* ``_dkv_kernel``  — grid (B, H, kv_block, q_block): accumulates dk, dv per
  KV-tile while streaming q tiles.

``delta = rowsum(dout ⊙ out)`` is precomputed outside (cheap elementwise).
GQA: dk/dv are produced per Q-head and summed into KV heads by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _make_dq_kernel(*, scale: float, causal: bool, bq: int, bk: int,
                    n_kv: int):
    def kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_sc):
        i = pl.program_id(2)
        j = pl.program_id(3)

        @pl.when(j == 0)
        def _init():
            acc_sc[...] = jnp.zeros_like(acc_sc)

        run = (not causal) or (j * bk <= i * bq + bq - 1)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale
            k = k_ref[0, 0].astype(jnp.float32)
            v = v_ref[0, 0].astype(jnp.float32)
            do = do_ref[0, 0].astype(jnp.float32)
            lse = lse_ref[0, 0]                        # [BQ, 1]
            delta = delta_ref[0, 0]                    # [BQ, 1]
            s = q @ k.T                                # [BQ, BK]
            if causal:
                q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 0)
                k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 1)
                s = jnp.where(k_pos <= q_pos, s, NEG_INF)
            p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - lse))
            dp = do @ v.T                              # [BQ, BK]
            ds = p * (dp - delta) * scale
            acc_sc[...] += ds @ k

        @pl.when(j == n_kv - 1)
        def _finalize():
            dq_ref[0, 0] = acc_sc[...].astype(dq_ref.dtype)

    return kernel


def _make_dkv_kernel(*, scale: float, causal: bool, bq: int, bk: int,
                     n_q: int):
    def kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dk_ref, dv_ref, dk_sc, dv_sc):
        j = pl.program_id(2)          # kv block (outer)
        i = pl.program_id(3)          # q block (inner stream)

        @pl.when(i == 0)
        def _init():
            dk_sc[...] = jnp.zeros_like(dk_sc)
            dv_sc[...] = jnp.zeros_like(dv_sc)

        run = (not causal) or (j * bk <= i * bq + bq - 1)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale
            k = k_ref[0, 0].astype(jnp.float32)
            v = v_ref[0, 0].astype(jnp.float32)
            do = do_ref[0, 0].astype(jnp.float32)
            lse = lse_ref[0, 0]
            delta = delta_ref[0, 0]
            s = q @ k.T
            if causal:
                q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 0)
                k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 1)
                s = jnp.where(k_pos <= q_pos, s, NEG_INF)
            p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - lse))
            dv_sc[...] += p.T @ do
            dp = do @ v.T
            ds = p * (dp - delta) * scale              # = scale·∂L/∂s
            dk_sc[...] += ds.T @ (q / scale)           # ds already carries scale

        @pl.when(i == n_q - 1)
        def _finalize():
            dk_ref[0, 0] = dk_sc[...].astype(dk_ref.dtype)
            dv_ref[0, 0] = dv_sc[...].astype(dv_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_bwd_pallas(q, k, v, out, lse, dout, *, causal: bool,
                               bq: int = 512, bk: int = 512,
                               interpret: bool = False):
    """q [B,H,Tq,D]; k,v [B,Hkv,Tk,D] (pre-expanded to H by the wrapper);
    out/dout [B,H,Tq,D]; lse [B,H,Tq,1].  Returns (dq, dk, dv) per Q-head —
    the wrapper reduces dk/dv over GQA groups."""
    b, h, tq, dh = q.shape
    tk = k.shape[2]
    g = h // k.shape[1]
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0
    n_q, n_kv = tq // bq, tk // bk
    scale = dh ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1, keepdims=True)            # [B,H,Tq,1]

    def kv_map(b_, h_, *_):
        return (b_, h_ // g)

    dq = pl.pallas_call(
        _make_dq_kernel(scale=scale, causal=causal, bq=bq, bk=bk, n_kv=n_kv),
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, i, j: kv_map(b_, h_) + (j, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, i, j: kv_map(b_, h_) + (j, 0)),
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b_, h_, i, j: (b_, h_, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    dk, dv = pl.pallas_call(
        _make_dkv_kernel(scale=scale, causal=causal, bq=bq, bk=bk, n_q=n_q),
        grid=(b, h, n_kv, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, j, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, j, i: kv_map(b_, h_) + (j, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, j, i: kv_map(b_, h_) + (j, 0)),
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, j, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b_, h_, j, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b_, h_, j, i: (b_, h_, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, j, i: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, j, i: (b_, h_, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, h, tk, dh), q.dtype),
                   jax.ShapeDtypeStruct((b, h, tk, dh), q.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, dh), jnp.float32),
                        pltpu.VMEM((bk, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk, dv
