"""Pallas TPU kernel: FlashAttention forward — the paper's Algorithm 3 carried
through attention (``(m, d)`` plus a weighted-value accumulator in VMEM).

Grid: (batch, q_head, q_block, kv_block), kv innermost.  GQA is handled by the
K/V index_map (``h // group``) — no materialized head repeat.  With
``causal=True``, KV tiles strictly above the diagonal are skipped via
``pl.when`` (compute never issued; the tile fetch is still scheduled by the
grid — see §Perf for the measured effect of tightening this).

Accumulators (m, d, acc) are fp32 VMEM scratch; output and LSE are written
once per q-block when the kv sweep finishes.

Two entry points share the masking math:

* ``flash_attention_pallas`` — the fresh-prefill / training form: queries and
  keys are self-aligned (query row i is absolute position i), every KV
  position is valid.  This is the differentiable path (``ops.flash_attention``
  wraps it in a custom VJP).
* ``flash_attention_offset_pallas`` — the serving form: ``q_offset`` [B] is
  the absolute position of query row 0 (per batch row, scalar-prefetched to
  SMEM) and ``kv_valid_len`` [B] is the number of valid cache positions per
  row.  Causal masking runs in absolute coordinates
  (``k_pos <= q_offset + i``), columns at or past ``kv_valid_len`` are masked
  to −inf before the online-softmax update, and KV tiles entirely past the
  valid length (or entirely above the causal diagonal) are skipped two ways:
  ``pl.when`` skips their compute, and the K/V index maps clamp the block
  index to the last live tile so the pipeline schedules no new fetch for
  them — ragged slots don't pay HBM traffic for dead tiles.  This is what
  lets cached chunked prefill (queries offset into a longer, partially-valid
  cache) run on the kernel instead of the chunked XLA fallback.
* ``flash_attention_paged_pallas`` — the paged-KV serving form: same masking
  math as the offset kernel, but K/V live in a shared pool of fixed-size
  blocks and a scalar-prefetched ``[B, max_blocks]`` block table maps each
  row's logical blocks to physical pool blocks.  The K/V index maps gather
  one pool block per grid step (the tile width IS the block size); dead
  table entries clamp to the last live block so they are never dereferenced.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _init_scratch(m_sc, d_sc, acc_sc):
    m_sc[...] = jnp.full_like(m_sc, NEG_INF)
    d_sc[...] = jnp.zeros_like(d_sc)
    acc_sc[...] = jnp.zeros_like(acc_sc)


def _online_update(s, v, m_sc, d_sc, acc_sc):
    """One ⊕ step of Algorithm 3 over a masked score tile ``s`` [BQ, BK]:
    rescale the carried (m, d, acc) and fold the tile in.  Shared verbatim by
    the offsetless and offset kernels so their numerics cannot drift."""
    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(jnp.where(m_prev == m_new, 0.0, m_prev - m_new))
    p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m_new))
    d_sc[...] = d_sc[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_sc[...] = acc_sc[...] * alpha + p @ v
    m_sc[...] = m_new


def _make_kernel(*, scale: float, causal: bool, bq: int, bk: int, n_kv: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_sc, d_sc, acc_sc):
        i = pl.program_id(2)          # q block
        j = pl.program_id(3)          # kv block

        @pl.when(j == 0)
        def _init():
            _init_scratch(m_sc, d_sc, acc_sc)

        # causal: skip tiles entirely above the diagonal
        run = (not causal) or (j * bk <= i * bq + bq - 1)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale      # [BQ, D]
            k = k_ref[0, 0].astype(jnp.float32)              # [BK, D]
            v = v_ref[0, 0].astype(jnp.float32)
            s = q @ k.T                                   # [BQ, BK] (MXU)
            if causal:
                q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 0)
                k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 1)
                s = jnp.where(k_pos <= q_pos, s, NEG_INF)
            _online_update(s, v, m_sc, d_sc, acc_sc)

        @pl.when(j == n_kv - 1)
        def _finalize():
            d = jnp.maximum(d_sc[...], 1e-30)
            o_ref[0, 0] = (acc_sc[...] / d).astype(o_ref.dtype)
            lse_ref[0, 0] = m_sc[...] + jnp.log(d)

    return kernel


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """q [B, Hq, Tq, D]; k, v [B, Hkv, Tk, D] → (out [B,Hq,Tq,D], lse [B,Hq,Tq,1]).

    Tq % bq == 0 and Tk % bk == 0 (pad upstream in ops.py).
    """
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    g = hq // hkv
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0
    n_kv = tk // bk
    grid = (b, hq, tq // bq, n_kv)
    scale = dh ** -0.5
    out, lse = pl.pallas_call(
        _make_kernel(scale=scale, causal=causal, bq=bq, bk=bk, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b_, h, i, j: (b_, h, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, hq, tq, dh), q.dtype),
                   jax.ShapeDtypeStruct((b, hq, tq, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Offset / valid-length form: cached (chunked) prefill on the kernel.
# ---------------------------------------------------------------------------
def _make_offset_kernel(*, scale: float, causal: bool, bq: int, bk: int,
                        n_kv: int):
    def kernel(qoff_ref, vlen_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
               m_sc, d_sc, acc_sc):
        b = pl.program_id(0)
        i = pl.program_id(2)          # q block
        j = pl.program_id(3)          # kv block

        @pl.when(j == 0)
        def _init():
            _init_scratch(m_sc, d_sc, acc_sc)

        qoff = qoff_ref[b]
        vlen = vlen_ref[b]
        # live tile: starts inside the valid cache, and (causal) at or below
        # the absolute diagonal of this q block's last row
        run = j * bk < vlen
        if causal:
            run = jnp.logical_and(run, j * bk <= qoff + i * bq + bq - 1)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale      # [BQ, D]
            k = k_ref[0, 0].astype(jnp.float32)              # [BK, D]
            v = v_ref[0, 0].astype(jnp.float32)
            s = q @ k.T                                      # [BQ, BK]
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = k_pos < vlen
            if causal:
                # absolute coordinates: query row i_local sits at qoff+i_local
                q_pos = qoff + i * bq + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 0)
                mask = jnp.logical_and(mask, k_pos <= q_pos)
            _online_update(jnp.where(mask, s, NEG_INF), v, m_sc, d_sc, acc_sc)

        @pl.when(j == n_kv - 1)
        def _finalize():
            d = jnp.maximum(d_sc[...], 1e-30)
            o_ref[0, 0] = (acc_sc[...] / d).astype(o_ref.dtype)
            lse_ref[0, 0] = jnp.where(d_sc[...] > 0,
                                      m_sc[...] + jnp.log(d), NEG_INF)

    return kernel


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_offset_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                                  q_offset: jax.Array,
                                  kv_valid_len: jax.Array, *,
                                  causal: bool = True, bq: int = 512,
                                  bk: int = 512, interpret: bool = False):
    """Cached-prefill flash attention: absolute-position causal masking plus
    per-row valid-length masking.

    q [B, Hq, Tq, D]; k, v [B, Hkv, Tk, D]; q_offset [B] (absolute position
    of query row 0 per batch row); kv_valid_len [B] (valid cache prefix per
    row) → (out [B,Hq,Tq,D], lse [B,Hq,Tq,1]).  Tq % bq == 0 and Tk % bk == 0
    (pad upstream in ops.py — padded KV columns sit at positions ≥
    ``kv_valid_len`` and are masked).

    Dead KV tiles (entirely past ``kv_valid_len``, or entirely above the
    causal diagonal) skip compute via ``pl.when`` AND skip their HBM→VMEM
    fetch: the K/V index maps clamp the block index to the last live tile of
    the row, so the pipeline re-addresses an already-resident block instead
    of scheduling a new copy.
    """
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    g = hq // hkv
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0
    n_kv = tk // bk
    scale = dh ** -0.5
    q_offset = jnp.asarray(q_offset, jnp.int32).reshape(b)
    kv_valid_len = jnp.asarray(kv_valid_len, jnp.int32).reshape(b)

    def last_live_tile(b_, i, qoff_ref, vlen_ref):
        # last tile index any row of this (b, i) block may touch
        last = jnp.maximum((vlen_ref[b_] + bk - 1) // bk - 1, 0)
        if causal:
            diag = (qoff_ref[b_] + i * bq + bq - 1) // bk
            last = jnp.minimum(last, jnp.maximum(diag, 0))
        return last

    def kv_index(qoff_ref, vlen_ref, b_, h, i, j):
        return (b_, h // g, jnp.minimum(j, last_live_tile(b_, i, qoff_ref,
                                                          vlen_ref)), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, tq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda b_, h, i, j, qo, vl: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h, i, j, qo, vl: kv_index(qo, vl, b_, h,
                                                              i, j)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h, i, j, qo, vl: kv_index(qo, vl, b_, h,
                                                              i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda b_, h, i, j, qo, vl: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1),
                         lambda b_, h, i, j, qo, vl: (b_, h, i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
    )
    out, lse = pl.pallas_call(
        _make_offset_kernel(scale=scale, causal=causal, bq=bq, bk=bk,
                            n_kv=n_kv),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hq, tq, dh), q.dtype),
                   jax.ShapeDtypeStruct((b, hq, tq, 1), jnp.float32)],
        interpret=interpret,
    )(q_offset, kv_valid_len, q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Paged form: offset/valid-length prefill over a block pool + block table.
# ---------------------------------------------------------------------------
def _make_paged_kernel(*, scale: float, causal: bool, bq: int, bs: int,
                       n_blocks: int, quantized: bool = False):
    def body(b, i, j, q_ref, load_kv, o_ref, lse_ref, m_sc, d_sc, acc_sc,
             qoff_ref, vlen_ref):
        pl.when(j == 0)(lambda: _init_scratch(m_sc, d_sc, acc_sc))
        qoff = qoff_ref[b]
        vlen = vlen_ref[b]
        # live block: starts inside the valid cache, and (causal) at or below
        # the absolute diagonal of this q block's last row
        run = j * bs < vlen
        if causal:
            run = jnp.logical_and(run, j * bs <= qoff + i * bq + bq - 1)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale      # [BQ, D]
            k, v = load_kv()                                 # [BS, D] fp32
            s = q @ k.T                                      # [BQ, BS]
            k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 1)
            mask = k_pos < vlen
            if causal:
                q_pos = qoff + i * bq + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bs), 0)
                mask = jnp.logical_and(mask, k_pos <= q_pos)
            _online_update(jnp.where(mask, s, NEG_INF), v, m_sc, d_sc, acc_sc)

        @pl.when(j == n_blocks - 1)
        def _finalize():
            d = jnp.maximum(d_sc[...], 1e-30)
            o_ref[0, 0] = (acc_sc[...] / d).astype(o_ref.dtype)
            lse_ref[0, 0] = jnp.where(d_sc[...] > 0,
                                      m_sc[...] + jnp.log(d), NEG_INF)

    if quantized:
        def kernel(qoff_ref, vlen_ref, tbl_ref, q_ref, k_ref, v_ref, ks_ref,
                   vs_ref, o_ref, lse_ref, m_sc, d_sc, acc_sc):
            del tbl_ref               # consumed by the index maps only
            b = pl.program_id(0)
            i = pl.program_id(2)      # q block
            j = pl.program_id(3)      # logical KV block of row b

            def load_kv():
                # dequantize AFTER the HBM read: int8 page × per-position
                # scale column, gathered through the same clamped table entry
                return ((k_ref[0, 0].astype(jnp.float32)
                         * ks_ref[0, 0].astype(jnp.float32)[:, None]),
                        (v_ref[0, 0].astype(jnp.float32)
                         * vs_ref[0, 0].astype(jnp.float32)[:, None]))

            body(b, i, j, q_ref, load_kv, o_ref, lse_ref, m_sc, d_sc, acc_sc,
                 qoff_ref, vlen_ref)
    else:
        def kernel(qoff_ref, vlen_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                   lse_ref, m_sc, d_sc, acc_sc):
            del tbl_ref               # consumed by the index maps only
            b = pl.program_id(0)
            i = pl.program_id(2)      # q block
            j = pl.program_id(3)      # logical KV block of row b

            def load_kv():
                return (k_ref[0, 0].astype(jnp.float32),
                        v_ref[0, 0].astype(jnp.float32))

            body(b, i, j, q_ref, load_kv, o_ref, lse_ref, m_sc, d_sc, acc_sc,
                 qoff_ref, vlen_ref)

    return kernel


@functools.partial(jax.jit, static_argnames=("causal", "bq", "interpret"))
def flash_attention_paged_pallas(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, q_offset: jax.Array,
                                 kv_valid_len: jax.Array,
                                 block_tables: jax.Array, *,
                                 k_scale_pool: jax.Array | None = None,
                                 v_scale_pool: jax.Array | None = None,
                                 causal: bool = True, bq: int = 512,
                                 interpret: bool = False):
    """Paged cached-prefill flash attention.

    q [B, Hq, Tq, D]; pools [P, Hkv, BS, D] (a shared pool of fixed-size KV
    blocks); q_offset [B]; kv_valid_len [B]; block_tables [B, M] (physical
    pool block per logical block, scalar-prefetched) →
    (out [B,Hq,Tq,D], lse [B,Hq,Tq,1]).  Tq % bq == 0 (pad upstream).

    The KV tile is one pool block, gathered through the table by the K/V
    index maps.  Dead logical blocks (entirely past ``kv_valid_len`` or
    entirely above the causal diagonal) clamp to the row's last live block —
    their table entries are never read as addresses and no fetch is
    scheduled — and partial tail blocks mask out-of-range columns to −inf
    before the online-softmax update, exactly like the contiguous offset
    kernel above.  The online ``(m, d)`` carry (paper Alg. 3) is what makes
    one pass over an arbitrary page list correct.

    ``k_scale_pool``/``v_scale_pool`` [P, Hkv, BS] set selects the quantized
    form: int8 pools plus per-position scale pages gathered through the SAME
    clamped table index and applied in VMEM before the online update.
    """
    b, hq, tq, dh = q.shape
    _, hkv, bs, _ = k_pool.shape
    m = block_tables.shape[1]
    g = hq // hkv
    bq = min(bq, tq)
    assert tq % bq == 0
    scale = dh ** -0.5
    q_offset = jnp.asarray(q_offset, jnp.int32).reshape(b)
    kv_valid_len = jnp.asarray(kv_valid_len, jnp.int32).reshape(b)
    quantized = k_scale_pool is not None

    def last_live_block(b_, i, qoff_ref, vlen_ref):
        last = jnp.maximum((vlen_ref[b_] + bs - 1) // bs - 1, 0)
        if causal:
            diag = (qoff_ref[b_] + i * bq + bq - 1) // bs
            last = jnp.minimum(last, jnp.maximum(diag, 0))
        return last

    def kv_index(qoff_ref, vlen_ref, tbl_ref, b_, h, i, j):
        jc = jnp.minimum(j, last_live_block(b_, i, qoff_ref, vlen_ref))
        return (tbl_ref[b_, jc], h // g, 0, 0)

    def scale_index(qoff_ref, vlen_ref, tbl_ref, b_, h, i, j):
        return kv_index(qoff_ref, vlen_ref, tbl_ref, b_, h, i, j)[:3]

    in_specs = [
        pl.BlockSpec((1, 1, bq, dh),
                     lambda b_, h, i, j, qo, vl, tbl: (b_, h, i, 0)),
        pl.BlockSpec((1, 1, bs, dh),
                     lambda b_, h, i, j, qo, vl, tbl: kv_index(
                         qo, vl, tbl, b_, h, i, j)),
        pl.BlockSpec((1, 1, bs, dh),
                     lambda b_, h, i, j, qo, vl, tbl: kv_index(
                         qo, vl, tbl, b_, h, i, j)),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, bs),
                         lambda b_, h, i, j, qo, vl, tbl: scale_index(
                             qo, vl, tbl, b_, h, i, j)),
            pl.BlockSpec((1, 1, bs),
                         lambda b_, h, i, j, qo, vl, tbl: scale_index(
                             qo, vl, tbl, b_, h, i, j)),
        ]
        operands += [k_scale_pool, v_scale_pool]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hq, tq // bq, m),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda b_, h, i, j, qo, vl, tbl: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1),
                         lambda b_, h, i, j, qo, vl, tbl: (b_, h, i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
    )
    out, lse = pl.pallas_call(
        _make_paged_kernel(scale=scale, causal=causal, bq=bq, bs=bs,
                           n_blocks=m, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hq, tq, dh), q.dtype),
                   jax.ShapeDtypeStruct((b, hq, tq, 1), jnp.float32)],
        interpret=interpret,
    )(q_offset, kv_valid_len, jnp.asarray(block_tables, jnp.int32),
      *operands)
    return out, lse
