"""Pallas TPU kernel: FlashAttention forward — the paper's Algorithm 3 carried
through attention (``(m, d)`` plus a weighted-value accumulator in VMEM).

Grid: (batch, q_head, q_block, kv_block), kv innermost.  GQA is handled by the
K/V index_map (``h // group``) — no materialized head repeat.  With
``causal=True``, KV tiles strictly above the diagonal are skipped via
``pl.when`` (compute never issued; the tile fetch is still scheduled by the
grid — see §Perf for the measured effect of tightening this).

Accumulators (m, d, acc) are fp32 VMEM scratch; output and LSE are written
once per q-block when the kv sweep finishes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _make_kernel(*, scale: float, causal: bool, bq: int, bk: int, n_kv: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_sc, d_sc, acc_sc):
        i = pl.program_id(2)          # q block
        j = pl.program_id(3)          # kv block

        @pl.when(j == 0)
        def _init():
            m_sc[...] = jnp.full_like(m_sc, NEG_INF)
            d_sc[...] = jnp.zeros_like(d_sc)
            acc_sc[...] = jnp.zeros_like(acc_sc)

        # causal: skip tiles entirely above the diagonal
        run = (not causal) or (j * bk <= i * bq + bq - 1)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale      # [BQ, D]
            k = k_ref[0, 0].astype(jnp.float32)              # [BK, D]
            v = v_ref[0, 0].astype(jnp.float32)
            s = q @ k.T                                   # [BQ, BK] (MXU)
            if causal:
                q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 0)
                k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bk), 1)
                s = jnp.where(k_pos <= q_pos, s, NEG_INF)
            m_prev = m_sc[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
            alpha = jnp.exp(jnp.where(m_prev == m_new, 0.0, m_prev - m_new))
            p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m_new))
            d_sc[...] = d_sc[...] * alpha + jnp.sum(p, -1, keepdims=True)
            acc_sc[...] = acc_sc[...] * alpha + p @ v
            m_sc[...] = m_new

        @pl.when(j == n_kv - 1)
        def _finalize():
            d = jnp.maximum(d_sc[...], 1e-30)
            o_ref[0, 0] = (acc_sc[...] / d).astype(o_ref.dtype)
            lse_ref[0, 0] = m_sc[...] + jnp.log(d)

    return kernel


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """q [B, Hq, Tq, D]; k, v [B, Hkv, Tk, D] → (out [B,Hq,Tq,D], lse [B,Hq,Tq,1]).

    Tq % bq == 0 and Tk % bk == 0 (pad upstream in ops.py).
    """
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    g = hq // hkv
    bq = min(bq, tq)
    bk = min(bk, tk)
    assert tq % bq == 0 and tk % bk == 0
    n_kv = tk // bk
    grid = (b, hq, tq // bq, n_kv)
    scale = dh ** -0.5
    out, lse = pl.pallas_call(
        _make_kernel(scale=scale, causal=causal, bq=bq, bk=bk, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b_, h, i, j: (b_, h, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, hq, tq, dh), q.dtype),
                   jax.ShapeDtypeStruct((b, hq, tq, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out, lse
