"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested against
(tests/kernels/* sweep shapes & dtypes and assert_allclose kernel vs oracle).
They are intentionally the *simple* formulations — safe softmax materializing
everything — so a kernel bug cannot hide in shared code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_ref(x: jax.Array) -> jax.Array:
    """Safe softmax over the last axis (paper Algorithm 2)."""
    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=-1, keepdims=True)
    e = jnp.exp(xf - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def normalizer_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(m, d) statistics over the last axis."""
    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=-1)
    d = jnp.sum(jnp.exp(xf - m[..., None]), axis=-1)
    return m, d


def softmax_topk_ref(x: jax.Array, k: int):
    """(top-k softmax probs desc, indices, lse) — paper Alg. 4 semantics."""
    y = softmax_ref(x.astype(jnp.float32))
    vals, idx = jax.lax.top_k(y, k)
    m, d = normalizer_ref(x)
    return vals.astype(x.dtype), idx.astype(jnp.int32), m + jnp.log(d)


def attention_ref(q, k, v, *, causal: bool, q_offset: int = 0,
                  kv_valid_len=None):
    """Full-score-matrix attention. q [B,Tq,Hq,D]; k,v [B,Tk,Hkv,D]."""
    b, tq, hq, dh = q.shape
    _, tk, hkv, _ = k.shape
    g = hq // hkv
    scale = dh ** -0.5
    qf = q.astype(jnp.float32).reshape(b, tq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(tq)[:, None] + q_offset
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((b, tq, tk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)[None]
    if kv_valid_len is not None:
        mask = mask & (k_pos[None] < jnp.asarray(kv_valid_len).reshape(-1, 1, 1))
    s = jnp.where(mask[:, None, None], s, float("-inf"))
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(jnp.isneginf(s), 0.0, jnp.exp(s - m))
    d = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p / d, v.astype(jnp.float32))
    return o.reshape(b, tq, hq, dh).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kv_valid_len):
    """Single-token decode: q [B,Hq,D] against cache [B,S,Hkv,D]."""
    o = attention_ref(q[:, None], k_cache, v_cache, causal=False,
                      kv_valid_len=kv_valid_len)
    return o[:, 0]
