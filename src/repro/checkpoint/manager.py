"""Checkpointing: async, atomic, resharding-on-restore.

Layout::

    <dir>/step_<N>/arrays.npz      flattened param+opt leaves ("/"-joined keys)
    <dir>/step_<N>/manifest.json   step, leaf index, config fingerprint
    <dir>/step_<N>/COMMITTED       written LAST → crash-safe commit marker

* **Async**: ``save`` snapshots to host memory synchronously (cheap), then a
  daemon thread serializes — training continues during the write.
* **Atomic**: writers stage into ``step_N.tmp`` and ``os.rename`` (atomic on
  POSIX) before dropping the COMMITTED marker; restore ignores uncommitted
  directories, so a crash mid-write can never corrupt the restore source.
* **Elastic**: arrays are saved in logical (unsharded) form; ``restore``
  ``device_put``s onto whatever shardings the *current* mesh prescribes —
  changing data-parallel width or the whole mesh shape between runs is a
  restore-time concern only.  (At true multi-host scale each host would write
  its shard + a global manifest; the format carries the leaf index needed for
  that extension.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro import compat

PyTree = Any
_SEP = "/"


_BF16_MARK = "__bf16__:"


def _flatten(tree: PyTree) -> dict:
    """Flatten to numpy; bfloat16 (not npz-serializable) is stored as a
    uint16 bit view under a marked key and re-viewed on restore."""
    flat = {}
    for path, leaf in compat.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            flat[_BF16_MARK + key] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, *, blocking: bool = False,
             extra: Optional[dict] = None):
        """Snapshot now, write in the background (or block if asked)."""
        self.wait()                      # one in-flight write at a time
        host_tree = compat.tree_map(lambda x: jax.device_get(x), tree)
        flat = _flatten(host_tree)

        def write():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                manifest = {"step": step, "leaves": sorted(flat),
                            "extra": extra or {}}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                shutil.rmtree(final, ignore_errors=True)
                os.rename(tmp, final)
                with open(os.path.join(final, "COMMITTED"), "w") as f:
                    f.write("ok")
                self._retention()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _retention(self):
        steps = sorted(self.committed_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def committed_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Load step's arrays into the structure of ``like``; if ``shardings``
        given, device_put each leaf (this is where elastic resharding
        happens — the stored arrays are mesh-agnostic)."""
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        leaves_like, treedef = compat.tree_flatten_with_path(like)
        out = []
        for pth, leaf in leaves_like:
            key = _SEP.join(_path_str(p) for p in pth)
            if _BF16_MARK + key in flat:
                import ml_dtypes
                arr = flat[_BF16_MARK + key].view(ml_dtypes.bfloat16)
            else:
                arr = flat[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            out.append(arr.astype(leaf.dtype))
        tree = compat.tree_unflatten(
            compat.tree_structure(like), out)
        if shardings is not None:
            tree = compat.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree
