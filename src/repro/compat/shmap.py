"""``shard_map`` across JAX versions.

The API moved twice:

* jax >= 0.6:   ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
                check_vma=...)`` — top-level export, ``check_vma`` kwarg.
* 0.4.x–0.5.x:  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs,
                out_specs, check_rep=...)`` — ``check_vma`` was then named
                ``check_rep`` (same semantics: verify per-axis replication
                invariants; False skips the check for ops the checker can't
                type, e.g. ragged all_gathers).

This module resolves the implementation and the kwarg name once at import and
exposes one stable signature.  All repo code must import ``shard_map`` from
``repro.compat`` — never from ``jax`` directly.
"""
from __future__ import annotations

import inspect
from typing import Callable

import jax


def _resolve() -> tuple[Callable, str | None, str]:
    impl = getattr(jax, "shard_map", None)
    source = "jax"
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl  # type: ignore
        source = "jax.experimental.shard_map"
    params = inspect.signature(impl).parameters
    if "check_vma" in params:
        rep_kw = "check_vma"
    elif "check_rep" in params:
        rep_kw = "check_rep"
    else:                                   # future removal: just drop it
        rep_kw = None
    return impl, rep_kw, source


_IMPL, _REP_KW, SHARD_MAP_SOURCE = _resolve()


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True) -> Callable:
    """Version-portable ``shard_map``; mirrors the modern keyword API."""
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if _REP_KW is not None:
        kwargs[_REP_KW] = check_vma
    return _IMPL(f, **kwargs)
