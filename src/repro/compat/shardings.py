"""``NamedSharding`` construction across JAX versions.

``jax.sharding.NamedSharding`` is the stable home on current JAX; before
0.4.30-era releases the class lived under ``jax.experimental.sharding``
(earliest as ``MeshPspecSharding``, with a positional-spec constructor).
``named_sharding(mesh, spec)`` is the one constructor the rest of the repo
calls — probe-resolved, never version-compared — so a pinned older JAX keeps
working without every call site growing a try/except (grep-enforced by
``tests/test_compat.py``: no module outside ``repro.compat`` constructs a
``NamedSharding`` raw).
"""
from __future__ import annotations

from jax.sharding import PartitionSpec


def _resolve():
    try:
        from jax.sharding import NamedSharding
        return NamedSharding, "jax.sharding"
    except ImportError:
        pass
    try:
        from jax.experimental.sharding import NamedSharding  # 0.4.x interim
        return NamedSharding, "jax.experimental.sharding"
    except ImportError:
        from jax.experimental.sharding import MeshPspecSharding
        return MeshPspecSharding, "jax.experimental.sharding.MeshPspecSharding"


NamedShardingImpl, NAMED_SHARDING_SOURCE = _resolve()


def named_sharding(mesh, spec=None):
    """Version-portable ``NamedSharding(mesh, spec)``.

    ``spec`` may be a ``PartitionSpec``, a tuple/list of axis entries (wrapped
    into one), or ``None`` (replicated)."""
    if spec is None:
        spec = PartitionSpec()
    elif not isinstance(spec, PartitionSpec):
        spec = PartitionSpec(*spec)
    return NamedShardingImpl(mesh, spec)
