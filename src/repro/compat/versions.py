"""JAX version parsing and feature probing.

Everything here is import-time cheap (no device state is touched): probing is
done by attribute/signature inspection, never by compiling anything.
"""
from __future__ import annotations

import jax


def jax_version_str() -> str:
    return jax.__version__


def jax_version() -> tuple[int, ...]:
    """``jax.__version__`` as a comparable int tuple (dev/rc suffixes dropped)."""
    parts = []
    for p in jax.__version__.split("."):
        digits = ""
        for ch in p:
            if not ch.isdigit():
                break
            digits += ch
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts) if parts else (0,)


def has_api(obj, name: str) -> bool:
    """True when ``obj.name`` exists — the probe-don't-version-check idiom.

    Prefer this over ``jax_version() >= (x, y)`` gates: vendored/backported
    builds carry APIs their version string denies.
    """
    return getattr(obj, name, None) is not None
