"""Pallas execution-mode detection.

Pallas kernels lower natively only on TPU/GPU Mosaic/Triton targets; on the
CPU backend ``interpret=True`` runs the kernel body faithfully (correctness
tests) while production paths fall back to the XLA implementations.  This is
the single place the repo decides interpret-vs-compiled — kernels take it as
an explicit parameter, everything above them asks here.

``REPRO_PALLAS_INTERPRET=0|1`` overrides the probe (e.g. forcing interpret on
a TPU host to debug a kernel, or asserting compiled mode in CI).
"""
from __future__ import annotations

import os

import jax

_ENV = "REPRO_PALLAS_INTERPRET"
_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

# Backends whose Pallas lowering is native (Mosaic).  The CPU backend only
# interprets; GPU lowering (Triton) exists upstream but is not exercised by
# this repo's kernels, so it stays conservative until a later PR validates it.
_NATIVE_BACKENDS = ("tpu",)


def backend() -> str:
    return jax.default_backend()


def pallas_native() -> bool:
    """True when Pallas kernels compile to the current default backend."""
    return backend() in _NATIVE_BACKENDS


def pallas_interpret() -> bool:
    """Whether Pallas calls should run in interpret mode on this backend."""
    env = os.environ.get(_ENV)
    if env is not None:
        if env.lower() in _TRUTHY:
            return True
        if env.lower() in _FALSY:
            return False
        raise ValueError(f"{_ENV}={env!r}: expected one of "
                         f"{_TRUTHY + _FALSY}")
    return not pallas_native()
