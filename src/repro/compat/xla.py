"""Compiled-artifact introspection across JAX/XLA versions.

``Compiled.cost_analysis()`` has returned, depending on version:

* a dict of ``{metric: value}``                     (modern jax)
* a list with one such dict per partition/program   (0.4.x: ``[{...}]``)
* ``None`` / raise ``NotImplementedError``          (some backends)

``cost_analysis`` below always returns a plain (possibly empty) dict so
callers can ``.get()`` without version branches.  This is the only place in
the repo allowed to call the raw method.
"""
from __future__ import annotations


def cost_analysis(compiled) -> dict:
    """Normalized per-device cost analysis of a ``jax`` ``Compiled`` object."""
    try:
        ca = compiled.cost_analysis()
    except Exception:                                   # backend w/o support
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    return dict(ca)


def memory_analysis(compiled):
    """``Compiled.memory_analysis()`` or None where the backend lacks it."""
    try:
        return compiled.memory_analysis()
    except Exception:
        return None
