"""Mesh construction across JAX versions.

``jax.make_mesh`` appeared in 0.4.34; older versions build a ``Mesh`` from
``mesh_utils.create_device_mesh``.  One entry point, probe-based.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes: tuple, axis_names: tuple, *, devices=None):
    """Version-portable ``jax.make_mesh(axis_shapes, axis_names)``."""
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        try:
            return mk(axis_shapes, axis_names, devices=devices)
        except TypeError:                   # older signature without devices=
            if devices is None:
                return mk(axis_shapes, axis_names)
            raise
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    dev = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return Mesh(dev, axis_names)
