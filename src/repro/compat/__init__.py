"""Version-portability layer: every version-sensitive JAX surface, one import.

The seed suite broke at the JAX API boundary three different ways (missing
``jax.shard_map`` export, ``cost_analysis()`` list-vs-dict, the
``check_vma``/``check_rep`` kwarg rename) while the paper's math passed
untouched.  The policy that prevents a recurrence:

* **No module outside ``repro.compat`` imports ``shard_map``, calls
  ``cost_analysis()`` / ``make_mesh`` raw, decides Pallas interpret mode
  itself, touches the ``jax.tree``/``jax.tree_util`` namespaces directly, or
  constructs a ``NamedSharding`` raw.**  Grep-enforced by
  ``tests/test_compat.py``.
* Probes are attribute/signature/behavior based, never version-string
  comparisons — backports and vendored builds lie about versions.
* ``capabilities()`` snapshots the probe results once per process; the kernel
  dispatch registry (``repro.kernels.dispatch``), the dry-run env record, and
  the test env report all read that one snapshot.
"""
from repro.compat.capabilities import Capabilities, capabilities
from repro.compat.meshes import make_mesh
from repro.compat.pallas import backend, pallas_interpret, pallas_native
from repro.compat.shardings import NAMED_SHARDING_SOURCE, named_sharding
from repro.compat.shmap import SHARD_MAP_SOURCE, shard_map
from repro.compat.trees import (
    TREE_SOURCE,
    tree_flatten,
    tree_flatten_with_path,
    tree_leaves,
    tree_map,
    tree_map_with_path,
    tree_reduce,
    tree_structure,
    tree_unflatten,
)
from repro.compat.versions import has_api, jax_version, jax_version_str
from repro.compat.xla import cost_analysis, memory_analysis

__all__ = [
    "Capabilities", "capabilities",
    "make_mesh",
    "backend", "pallas_interpret", "pallas_native",
    "NAMED_SHARDING_SOURCE", "named_sharding",
    "SHARD_MAP_SOURCE", "shard_map",
    "TREE_SOURCE", "tree_flatten", "tree_flatten_with_path",
    "tree_leaves", "tree_map", "tree_map_with_path", "tree_reduce",
    "tree_structure", "tree_unflatten",
    "has_api", "jax_version", "jax_version_str",
    "cost_analysis", "memory_analysis",
]
