"""Pytree API aliasing across JAX versions.

The ``jax.tree`` namespace (``jax.tree.map``, ``.leaves``, ``.structure``,
``.flatten``, ``.unflatten``, ``.reduce``) only exists on newer JAX; older
releases spell the same operations ``jax.tree_util.tree_map`` etc., and the
oldest ones deprecate-warn on the ``jax.tree_map`` top-level aliases.  One
probe, one set of names — nothing outside ``repro.compat`` should care which
spelling the installed JAX uses (grep-enforced by ``tests/test_compat.py``).

Probe is attribute-based, not version-string-based, per the compat policy.
"""
from __future__ import annotations

import jax
from jax import tree_util as _tree_util

if hasattr(jax, "tree") and hasattr(jax.tree, "map"):
    TREE_SOURCE = "jax.tree"
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
    tree_structure = jax.tree.structure
    tree_flatten = jax.tree.flatten
    tree_unflatten = jax.tree.unflatten
    tree_reduce = jax.tree.reduce
else:                                      # pre-jax.tree releases
    TREE_SOURCE = "jax.tree_util"
    tree_map = _tree_util.tree_map
    tree_leaves = _tree_util.tree_leaves
    tree_structure = _tree_util.tree_structure
    tree_flatten = _tree_util.tree_flatten
    tree_unflatten = _tree_util.tree_unflatten
    tree_reduce = _tree_util.tree_reduce


def _with_path(new_name: str, old_name: str):
    # the path-aware APIs joined jax.tree later than the plain ones — probe
    # each individually rather than assuming the namespace is all-or-nothing
    mod = getattr(jax, "tree", None)
    fn = getattr(mod, new_name, None) if mod is not None else None
    return fn if fn is not None else getattr(_tree_util, old_name)


tree_flatten_with_path = _with_path("flatten_with_path",
                                    "tree_flatten_with_path")
tree_map_with_path = _with_path("map_with_path", "tree_map_with_path")
