"""One-shot capability snapshot of the installed JAX + backend.

``capabilities()`` is probed lazily on first call and cached for the process:
the kernel dispatch registry, the dry-run env record, and the test env report
all read the same snapshot, so every layer agrees on what the runtime can do.

Probes are behavioral where cheap (a trivial jit compile classifies the
``cost_analysis()`` return shape) and attribute-based otherwise — never
version-string comparisons.
"""
from __future__ import annotations

import functools
from dataclasses import asdict, dataclass

import jax

from repro.compat import shmap, versions
from repro.compat.pallas import backend, pallas_interpret, pallas_native


@dataclass(frozen=True)
class Capabilities:
    jax_version: str
    backend: str
    device_count: int
    shard_map_source: str            # "jax" | "jax.experimental.shard_map"
    cost_analysis_shape: str         # "dict" | "list" | "unavailable"
    has_make_mesh: bool              # native jax.make_mesh
    pallas_native: bool              # Pallas compiles to this backend
    pallas_interpret: bool           # interpret mode for Pallas calls

    def to_dict(self) -> dict:
        return asdict(self)


def _probe_cost_analysis_shape() -> str:
    import jax.numpy as jnp
    try:
        compiled = jax.jit(lambda x: x + 1.0).lower(
            jax.ShapeDtypeStruct((2,), jnp.float32)).compile()
        ca = compiled.cost_analysis()
    except Exception:
        return "unavailable"
    if isinstance(ca, (list, tuple)):
        return "list"
    if isinstance(ca, dict):
        return "dict"
    return "unavailable"


@functools.lru_cache(maxsize=None)
def capabilities() -> Capabilities:
    """Probe once, then serve the cached snapshot."""
    return Capabilities(
        jax_version=versions.jax_version_str(),
        backend=backend(),
        device_count=jax.device_count(),
        shard_map_source=shmap.SHARD_MAP_SOURCE,
        cost_analysis_shape=_probe_cost_analysis_shape(),
        has_make_mesh=versions.has_api(jax, "make_mesh"),
        pallas_native=pallas_native(),
        pallas_interpret=pallas_interpret(),
    )
