"""Deterministic synthetic token pipeline.

Batches are a pure function of ``(seed, step)`` via counter-based Philox —
any host can regenerate any step's shard independently, which is what makes
checkpoint-restart and elastic re-sharding exact: after a crash, the loop
resumes at step N and the pipeline re-emits step N's batch bit-identically,
regardless of how many hosts now exist.

The stream is Zipf-distributed tokens with a simple Markov structure so CE
loss has learnable signal (examples/train_lm.py shows it decreasing).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    markov_period: int = 16      # learnable periodic structure


class SyntheticDataset:
    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self._probs = p / p.sum()

    def batch(self, step: int) -> dict:
        """{tokens [GB, T] int32, labels [GB, T] int32} for this step."""
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=c.seed, counter=[0, 0, 0, step]))
        base = rng.choice(c.vocab_size, size=(c.global_batch, c.seq_len + 1),
                          p=self._probs).astype(np.int32)
        # periodic copy structure: token t depends on token t-period
        period = c.markov_period
        if c.seq_len + 1 > period:
            mix = rng.random((c.global_batch, c.seq_len + 1)) < 0.5
            base[:, period:] = np.where(mix[:, period:],
                                        base[:, :-period], base[:, period:])
        return {"tokens": base[:, :-1], "labels": base[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class HostShardedLoader:
    """Wraps SyntheticDataset for multi-host: each host materializes only its
    batch rows, then ``jax.device_put`` with the global batch sharding
    reassembles the logical array (single-host here, but the slicing logic is
    the multi-host one)."""

    def __init__(self, ds: SyntheticDataset, host_id: int = 0,
                 num_hosts: int = 1):
        self.ds = ds
        self.host_id = host_id
        self.num_hosts = num_hosts

    def local_batch(self, step: int) -> dict:
        full = self.ds.batch(step)
        gb = self.ds.cfg.global_batch
        per = gb // self.num_hosts
        lo = self.host_id * per
        return {k: v[lo:lo + per] for k, v in full.items()}
