"""Byte-level text corpus pipeline (the real-data counterpart of synthetic.py).

Same stateless contract: ``batch(step)`` is a pure function of
(corpus, seed, step) via strided window addressing, so checkpoint-restart and
elastic re-sharding stay exact.  Byte-level tokenization (vocab 256 + BOS) —
no external tokenizer dependency.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

BOS = 256
VOCAB = 257


@dataclass
class TextConfig:
    path: str
    seq_len: int
    global_batch: int
    seed: int = 0


class ByteCorpus:
    def __init__(self, cfg: TextConfig):
        self.cfg = cfg
        with open(cfg.path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        if len(self.data) < cfg.seq_len + 2:
            raise ValueError(f"corpus too small: {len(self.data)} bytes")
        self.n_windows = len(self.data) - cfg.seq_len - 1

    def fingerprint(self) -> str:
        return hashlib.sha256(self.data[:1 << 20].tobytes()).hexdigest()[:16]

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=c.seed, counter=[0, 0, 1, step]))   # stream 1 ≠ synthetic's 0
        starts = rng.integers(0, self.n_windows, size=c.global_batch)
        tok = np.stack([self.data[s:s + c.seq_len + 1].astype(np.int32)
                        for s in starts])
        tokens = np.concatenate(
            [np.full((c.global_batch, 1), BOS, np.int32), tok[:, :-2]], axis=1)
        return {"tokens": tokens, "labels": tok[:, :-1].astype(np.int32)}
