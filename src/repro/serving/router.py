"""Router layer: spread Poisson traffic over N ``Engine`` replicas.

One engine replica serves one KV pool; "millions of users" (ROADMAP) means
several.  ``ReplicaRouter`` owns N independent ``Engine``s and decides, per
request, which replica's pool the prompt lands in:

* **Prefix affinity** (paged replicas, default on) — a prompt's identity for
  routing is its block chain hashed exactly the way ``PrefixIndex`` keys
  physical blocks: ``key_i = (key_{i-1}, tokens of block i)``.  The router
  first *probes* every replica (``Engine.cache_probe`` — read-only) and
  sends the request to the replica whose resident cache already covers the
  most prompt tokens; failing a live hit, it falls back to the replica its
  own routing history assigned the deepest chain key to (the blocks may
  still be cached there, or arrive shortly — requests routed earlier to
  that replica will mint them); failing both, least-loaded.  Same-prefix
  requests therefore converge on one replica, where PR 5's persistent LRU
  prefix cache turns their shared blocks into real reuse instead of N cold
  copies.
* **Least-loaded fallback / ``affinity=False``** — no-prefix traffic (and
  the hash-free baseline the benchmarks diff) spreads by ``Engine.load``
  (affinity off: pure round-robin), which keeps pools evenly busy.
* **Backpressure** — when EVERY replica is starved for the request
  (``Engine.starved``: queue a full pool deep and not enough free+cached
  blocks to ever place the prompt now), ``submit`` REJECTS the request
  instead of queueing it into a pool that cannot serve it; the caller sees
  ``None`` and the reject is counted in the report.  One live replica is
  enough to accept.
* **Global accounting** — per-replica ``ServeReport``s combine through
  ``ServeReport.merge``: raw latency lists concatenate (percentiles are
  computed over the union, never averaged), counters sum, occupancy is
  decode-step-weighted, and the merged report carries a ``router`` dict
  (assignments, affinity routes, backpressure rejects).

Determinism: every replica shares the same ``base_rng``, and sample streams
are keyed (base_rng, request id, token index) — so WHERE a request lands
never changes WHAT it generates.  ``tests/test_serving_router.py`` pins
bit-identity to solo decode for replica counts {1, 2, 4}.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, Optional

from repro.obs import metrics as obs_metrics
from repro.serving.engine_api import Engine
from repro.serving.paged import PrefixIndex
from repro.serving.scheduler import Request, ServeReport


class ReplicaRouter:
    """N-replica front-end with prefix-affinity routing and admission
    backpressure.

    ``ReplicaRouter(params, cfg, replicas=4, num_slots=..., ...)`` builds
    N identical engines from the shared ``**engine_kwargs`` (all replicas
    see the same ``base_rng``, keeping streams solo-identical).  Affinity
    requires paged engines; it degrades to round-robin otherwise.
    ``backpressure`` defaults to on for multi-replica routers and off for
    N=1, where rejecting would change single-engine CLI behaviour."""

    def __init__(self, params, cfg, *, replicas: int = 1,
                 affinity: bool = True, backpressure: Optional[bool] = None,
                 **engine_kwargs):
        if replicas < 1:
            raise ValueError(f"replicas must be ≥ 1 (got {replicas})")
        # one shared Tracer, one Perfetto pid per replica — its request and
        # scheduler tracks land under "process i" in the combined trace.
        # ``tracers`` instead gives each replica its own Tracer (same pid
        # scheme), for per-replica files that repro.obs.merge re-combines.
        tracer = engine_kwargs.pop("tracer", None)
        tracers = engine_kwargs.pop("tracers", None)
        if tracers is not None:
            if tracer is not None:
                raise ValueError("pass tracer= or tracers=, not both")
            if len(tracers) != replicas:
                raise ValueError(f"tracers has {len(tracers)} entries for "
                                 f"{replicas} replicas")
        self.engines = [
            Engine(params, cfg,
                   tracer=tracers[i] if tracers is not None else tracer,
                   trace_pid=i, **engine_kwargs)
            for i in range(replicas)]
        self.block_size = int(engine_kwargs.get("block_size", 8))
        self.affinity = bool(affinity) and self.engines[0].paged
        self.backpressure = (replicas > 1 if backpressure is None
                             else bool(backpressure))
        self._affinity_map: dict = {}      # chain key → replica index
        self._rr = 0                       # round-robin cursor
        self.assignments: dict[int, int] = {}   # rid → replica index
        self.rejected: list[int] = []      # rids refused by backpressure
        self.backpressure_rejects = 0
        self.affinity_routes = 0           # routed by probe/history hit
        self.tick_count = 0

    @property
    def replicas(self) -> int:
        return len(self.engines)

    # -- routing ------------------------------------------------------------
    def route(self, req: Request) -> int:
        """Pick a replica for ``req`` (no submission).  Affinity order:
        deepest live cache probe → deepest remembered chain key →
        least-loaded.  Affinity off: round-robin."""
        n = len(self.engines)
        if not self.affinity:
            choice = self._rr % n
            self._rr += 1
            return choice
        keys = PrefixIndex.chain_keys(req.prompt, self.block_size)
        probes = [e.cache_probe(req.prompt) for e in self.engines]
        loads = [e.load for e in self.engines]
        best = max(range(n), key=lambda i: (probes[i], -loads[i], -i))
        if probes[best] > 0:
            choice = best
            self.affinity_routes += 1
        else:
            choice = None
            for key in reversed(keys):     # deepest remembered prefix wins
                if key in self._affinity_map:
                    choice = self._affinity_map[key]
                    self.affinity_routes += 1
                    break
            if choice is None:
                choice = min(range(n), key=lambda i: (loads[i], i))
        for key in keys:                   # future same-prefix → same place
            self._affinity_map[key] = choice
        return choice

    # -- the narrow surface -------------------------------------------------
    def submit(self, req: Request) -> Optional[int]:
        """Route and enqueue ``req``.  Returns the replica index, or None
        when backpressure rejects it (every replica starved)."""
        if (self.backpressure
                and all(e.starved(len(req.prompt)) for e in self.engines)):
            self.rejected.append(req.rid)
            self.backpressure_rejects += 1
            if obs_metrics.enabled():
                obs_metrics.counter("router.backpressure_rejects").inc()
            return None
        choice = self.route(req)
        self.engines[choice].submit(req)
        self.assignments[req.rid] = choice
        return choice

    def step(self) -> bool:
        """Advance every replica one tick.  Returns True while any is
        busy."""
        self.tick_count += 1
        busy = False
        for e in self.engines:
            busy = e.step() or busy
        if obs_metrics.enabled():
            # mirrors only: the plain ints above stay the report inputs
            obs_metrics.gauge("router.affinity_routes").set(
                self.affinity_routes)
            for i, e in enumerate(self.engines):
                obs_metrics.gauge(f"router.r{i}.load").set(e.load)
        return busy

    def serve(self, requests: Optional[Iterable[Request]] = None, *,
              max_ticks: int = 100_000) -> ServeReport:
        """Drive the full workload: requests are submitted as their
        ``arrival_tick`` comes due — routing sees the cache/load state of
        that moment, exactly as live traffic would — and every replica
        ticks in lockstep until all are idle."""
        for e in self.engines:
            e.begin()
        pending = deque(sorted(list(requests or ()),
                               key=lambda r: r.arrival_tick))
        while pending or any(e.busy for e in self.engines):
            if self.tick_count >= max_ticks:
                raise RuntimeError(f"router wedged after {max_ticks} ticks")
            next_tick = self.tick_count + 1
            while pending and pending[0].arrival_tick <= next_tick:
                self.submit(pending.popleft())
            self.step()
        return self.report()

    def report(self) -> ServeReport:
        """Merged global report (raw latencies concatenated, counters
        summed) carrying the router's own accounting."""
        per_replica = [0] * len(self.engines)
        for rep in self.assignments.values():
            per_replica[rep] += 1
        return ServeReport.merge(
            [e.report() for e in self.engines],
            router={"replicas": len(self.engines),
                    "affinity": self.affinity,
                    "assignments": dict(self.assignments),
                    "per_replica": per_replica,
                    "affinity_routes": self.affinity_routes,
                    "backpressure_rejects": self.backpressure_rejects,
                    "rejected": list(self.rejected)})

    def stats(self) -> list[dict]:
        return [e.stats() for e in self.engines]


__all__ = ["ReplicaRouter"]
