"""Serving: KV-cache management, prefill, and decode with fused top-k sampling.

The decode step ends in the paper's §4 scenario verbatim: a projection to the
full vocabulary followed by TopK — served by ``core.topk_sample`` (Algorithm 4,
single pass over the vocab, or the Pallas ``softmax_topk`` kernel on TPU).

Cache layout mirrors the model's segment structure: one stacked cache pytree
per segment (leading axis = layers in the segment).  Attention caches have a
static ``max_len``; validity is tracked per sequence.  Two serving shapes sit
on top of that layout:

* **Lockstep batch** (``prefill`` + ``decode_step``): one scalar ``cache_len``
  shared by every row — the drain-and-refill baseline, still what the dry-run
  and the whisper path drive.
* **Slot pool** (``chunked_prefill`` / ``write_slot`` / ``decode_step_slots``):
  the batch axis is a pool of independent cache *slots*, each with its own
  length in a ``[B]`` vector that flows through ``kv_valid_len`` into the
  attention masks.  A finished slot is overwritten in place by the next
  request's prefilled cache — continuous batching, orchestrated by
  ``repro.serving.scheduler`` — so decode always runs at full batch occupancy
  with ragged sequence lengths.  Sampling keys are per-slot
  (``sample_per_slot``), which makes a slot's token stream independent of its
  batch neighbours: the scheduler-equivalence guarantee the tests pin.
* **Paged pool** (``init_paged_cache`` / ``prefill_chunk_paged`` /
  ``decode_step_paged`` / ``copy_paged_block``): KV memory is a shared pool
  of fixed-size blocks with per-sequence **block tables** ([B, max_blocks])
  mapping logical to physical blocks — capacity scales with tokens actually
  held rather than worst-case slot length, and identical prompt prefixes
  share physical blocks (copy-on-write on divergence).  Allocation, prefix
  hashing, and table construction live in ``repro.serving.paged``; these
  primitives only run model steps through tables they are handed.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import compat, core
from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.serving import cache_family

Array = jax.Array
PyTree = Any


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Build the contiguous cache pytree (zeros) — layout owned by the
    config's cache family (``serving.cache_family``)."""
    return cache_family.resolve(cfg).init_cache(batch, max_len)


def prefill(params: PyTree, tokens: Array, cfg: ModelConfig, *,
            max_len: int, patch_embeds: Optional[Array] = None):
    """Run the prompt through the model, filling a fresh cache.

    Returns (last_hidden [B, D], caches, cache_len scalar)."""
    b, t = tokens.shape
    caches = init_cache(cfg, b, max_len)
    hidden, new_caches, _ = transformer.forward(
        params, tokens, cfg, patch_embeds=patch_embeds, caches=caches,
        cache_len=jnp.asarray(0, jnp.int32))
    return hidden[:, -1], new_caches, jnp.asarray(
        t + (cfg.num_patches if patch_embeds is not None else 0), jnp.int32)


def logits_from_hidden(params: PyTree, last_hidden: Array,
                       cfg: ModelConfig) -> Array:
    """LM-head logits [B, V] from the last-position hidden state [B, D],
    with padded vocab rows masked to -inf."""
    logits = transformer.logits_last(params, last_hidden[:, None], cfg)
    if cfg.real_vocab_size and cfg.real_vocab_size < cfg.vocab_size:
        mask = jnp.arange(cfg.vocab_size) < cfg.real_vocab_size
        logits = jnp.where(mask, logits, float("-inf"))
    return logits


def decode_step(params: PyTree, caches: list, cache_len: Array,
                tokens: Array, cfg: ModelConfig, *, rng: Array,
                top_k: int = 5, temperature: float = 1.0):
    """One lockstep decode step: tokens [B, 1] → (next_token [B], new caches).

    The final vocab softmax+topk+sample is the fused single-pass form.
    """
    hidden, new_caches, _ = transformer.forward(
        params, tokens, cfg, caches=caches, cache_len=cache_len)
    logits = logits_from_hidden(params, hidden[:, -1], cfg)
    from repro.distributed import context
    ctx = context.get()
    if ctx is not None:
        from repro.distributed.decode_attention import sharded_topk_sample
        next_tok, _ = sharded_topk_sample(
            rng, logits, top_k, mesh=ctx.mesh, batch_axes=ctx.batch_axes,
            vocab_axis=ctx.par.model_axis, temperature=temperature)
    else:
        # single-pass block width: the autotuned ⊕-tree choice for this
        # (backend, vocab, dtype), not a hard-coded chunk heuristic
        from repro.kernels import dispatch
        block = dispatch.tuned_block(logits.shape[-1], logits.dtype)
        next_tok, _ = core.topk_sample(rng, logits, top_k,
                                       temperature=temperature,
                                       block=min(block, logits.shape[-1]))
    return next_tok, new_caches, cache_len + 1


# ---------------------------------------------------------------------------
# Continuous batching: slot-pool primitives.
# ---------------------------------------------------------------------------
def prefill_schedule(t: int, chunk: int) -> list:
    """Chunk widths for a ``t``-token prompt: full ``chunk``s, then a binary
    (power-of-two) decomposition of the remainder.

    A jitted per-chunk forward compiles once per distinct width; naive
    ``t % chunk`` tails would recompile the whole model for nearly every
    prompt length mid-serving, so the tail is capped at O(log chunk) widths
    shared by all prompts instead."""
    sizes = []
    rem = int(t)
    while rem >= chunk:
        sizes.append(chunk)
        rem -= chunk
    p = 1
    while p * 2 <= rem:
        p *= 2
    while rem:
        if p <= rem:
            sizes.append(p)
            rem -= p
        p //= 2
    return sizes


def chunked_prefill(params: PyTree, tokens: Array, cfg: ModelConfig, *,
                    max_len: int, chunk: int = 0):
    """Prefill a prompt in chunks against a fresh cache.

    ``chunk=0`` (or ≥ the prompt) degenerates to single-shot prefill.  This is
    the canonical single-sequence prefill of the slot pool: the scheduler runs
    the same per-chunk step (``prefill_chunk``) over the same
    ``prefill_schedule`` interleaved with decode, so a request's cache
    contents are identical whether it prefilled alone or while the pool was
    busy.  Every chunk after the first runs at ``q_offset > 0`` against the
    partially-valid cache — the case the offset-aware flash kernel serves
    natively (``dispatch.sdpa`` routes it; XLA chunked elsewhere).
    Returns (last_hidden [B, D], caches, length)."""
    b, t = tokens.shape
    if cache_family.resolve(cfg).single_shot_prefill:
        # the family's prefill would drop information chunked (int8 prefill
        # computes on the current chunk's exact fp tensors only; SSM/xLSTM
        # chunked prefill does not thread prefix state) — go in whole
        chunk = 0
    caches = init_cache(cfg, b, max_len)
    length = jnp.asarray(0, jnp.int32)
    last = None
    pos = 0
    for c in prefill_schedule(t, chunk or t):
        last, caches, length = prefill_chunk(
            params, caches, length, tokens[:, pos:pos + c], cfg)
        pos += c
    return last, caches, length


def prefill_chunk(params: PyTree, caches: list, cache_len: Array,
                  tokens: Array, cfg: ModelConfig):
    """Advance a prefill by one chunk: tokens [B, c] are written into the
    cache at ``cache_len`` and attended causally against everything before
    them.  Returns (last_hidden [B, D], new caches, new length).

    ``cache_len`` is a scalar (one sequence, or a lockstep batch) or a [B]
    vector (per-slot offsets).  Either way it threads through the model as
    ``q_offset`` with ``kv_valid_len = cache_len + c``, which is exactly the
    operand pair the Pallas flash kernel masks on — so chunked prefill at
    ``q_offset > 0`` runs the kernel on native backends instead of detouring
    through the chunked XLA form (the PR-2 routing pin, now lifted)."""
    hidden, new_caches, _ = transformer.forward(
        params, tokens, cfg, caches=caches, cache_len=cache_len)
    return hidden[:, -1], new_caches, cache_len + tokens.shape[1]


def write_slot(cfg: ModelConfig, pool: list, seq: list, slot) -> list:
    """Overwrite slot ``slot`` of the pool cache with a batch-1 sequence cache.

    Both pytrees come from ``init_cache`` with the same ``max_len``; the whole
    per-slot slice is replaced, so whatever a retired sequence left behind is
    gone.  Stacked segment leaves carry batch on axis 1 (after the layer
    axis); Zamba2's shared block is stored unstacked, batch on axis 0."""
    slot = jnp.asarray(slot, jnp.int32)
    out: list = []
    for (kind, _), pc, sc in zip(transformer.block_pattern(cfg), pool, seq):
        axis = 0 if kind == "shared_attn" else 1
        out.append(compat.tree_map(
            lambda p, s, a=axis: jax.lax.dynamic_update_slice_in_dim(
                p, s.astype(p.dtype), slot, axis=a), pc, sc))
    return out


def sample_per_slot(rngs: Array, logits: Array, top_k: int,
                    temperature: float = 1.0) -> Array:
    """Fused softmax+top-k sampling with one PRNG key per row.

    ``rngs`` [B, 2]: independent keys, so row b's token depends only on its
    own logits and key — a slot samples the same stream at batch size 1 or N,
    which is what makes continuous batching reproduce single-sequence decode
    token-for-token.  The single vocab pass (paper Alg. 4) goes through the
    dispatch registry (Pallas kernel on TPU); only the Gumbel draw is
    per-row."""
    if temperature != 1.0:
        logits = logits / temperature
    from repro.kernels import dispatch
    out = dispatch.softmax_topk(logits, top_k)
    k = out.values.shape[-1]
    g = jax.vmap(lambda r: jax.random.gumbel(r, (k,), jnp.float32))(rngs)
    return core.gumbel_pick(out, g)


def decode_step_slots(params: PyTree, caches: list, slot_lens: Array,
                      tokens: Array, cfg: ModelConfig, *, rngs: Array,
                      top_k: int = 5, temperature: float = 1.0):
    """One decode step over the whole slot pool: tokens [B, 1], per-slot
    lengths [B] → (next_token [B], new caches, slot_lens + 1).

    Every slot advances by one position at its own offset; masking comes from
    the ``kv_valid_len`` vector, so ragged sequences coexist in one fused
    batch — the full-occupancy regime where the single-pass softmax's memory
    savings actually pay (ISSUE 2 / Dukhan & Ablavatski 2020)."""
    hidden, new_caches, _ = transformer.forward(
        params, tokens, cfg, caches=caches, cache_len=slot_lens)
    logits = logits_from_hidden(params, hidden[:, -1], cfg)
    next_tok = sample_per_slot(rngs, logits, top_k, temperature)
    return next_tok, new_caches, slot_lens + 1


# ---------------------------------------------------------------------------
# Paged KV cache: block-pool primitives.  Allocation, prefix sharing, and
# block-TABLE construction live exclusively in ``repro.serving.paged``
# (grep-enforced); this module only initializes pools and runs model steps
# through tables it is handed.
# ---------------------------------------------------------------------------
def paged_supported(cfg: ModelConfig) -> bool:
    """Paged serving covers every config whose cache family implements the
    block-pool layout: dense token blocks, quantized dense blocks (int8 K/V
    pools beside bfloat16 scale pages, dequantized in the gather —
    ``cache_family.DenseInt8Family.dequantize_block`` states the arithmetic),
    fixed-size state rows, enc-dec cross/self blocks.  MLA latent caches are
    the registered follow-up."""
    return cache_family.resolve(cfg).paged_serveable


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     slot_len: Optional[int] = None) -> PyTree:
    """Build the block-pool cache pytree (zeros) for the config's family.

    Dense leaves are [n_layers, P, Hkv, BS, D] — kernel-native page layout,
    NO batch axis: the pool is shared by every sequence and block tables
    carry the per-sequence mapping.  State/enc-dec families size per-block
    rows by ``slot_len``.  Every family puts the physical-block axis at leaf
    position 1; ``num_blocks`` counts physical blocks including the sentinel
    block 0 (see ``serving.paged.PagedPool``)."""
    return cache_family.resolve(cfg).init_paged_cache(
        num_blocks, block_size, slot_len)


def copy_paged_block(pools: list, src, dst) -> list:
    """Copy physical block ``src`` over block ``dst`` in every layer's pool —
    the copy-on-write primitive behind prefix-sharing divergence."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    return compat.tree_map(
        lambda x: jax.lax.dynamic_update_slice_in_dim(
            x, jax.lax.dynamic_slice_in_dim(x, src, 1, axis=1), dst, axis=1),
        pools)


def write_paged_block(pools: list, block: list, dst) -> list:
    """Write one physical block's content into slot ``dst`` of every layer
    pool — the swap-in restore primitive behind preempt-and-swap.

    ``block`` is the pytree ``compat.tree_map(lambda x: x[:, bid], pools)``
    produces (leaves [n_layers, Hkv, BS, D] — one pool entry, no block axis),
    round-tripped through the host by ``serving.paged.PagedPool.swap_out``.
    The write is a full-slot replacement in the same dtype, so a
    swap-out/swap-in cycle is bit-exact."""
    dst = jnp.asarray(dst, jnp.int32)
    return compat.tree_map(
        lambda x, b: jax.lax.dynamic_update_slice_in_dim(
            x, jnp.asarray(b, x.dtype)[:, None], dst, axis=1), pools, block)


def prefill_chunk_paged(params: PyTree, pools: list, block_tables: Array,
                        cache_len: Array, tokens: Array, cfg: ModelConfig):
    """Advance a paged prefill by one chunk: tokens [1, c] are scattered into
    pool blocks through ``block_tables`` [1, M] at offset ``cache_len`` and
    attended causally (absolute coordinates) against the already-valid
    prefix — which may include blocks shared from another request's
    identical prompt prefix.  Returns (last_hidden [1, D], new pools, new
    length)."""
    hidden, new_pools, _ = transformer.forward(
        params, tokens, cfg, caches=pools, cache_len=cache_len,
        block_tables=block_tables)
    return hidden[:, -1], new_pools, cache_len + tokens.shape[1]


def decode_step_paged(params: PyTree, pools: list, block_tables: Array,
                      slot_lens: Array, tokens: Array, cfg: ModelConfig, *,
                      rngs: Array, top_k: int = 5, temperature: float = 1.0):
    """One decode step over the paged pool: tokens [B, 1], block_tables
    [B, M], per-slot lengths [B] → (next_token [B], new pools, lens + 1).

    Identical sampling scheme to ``decode_step_slots`` (per-slot keys), and
    — because the gather fallback masks exactly and pool values equal what a
    contiguous slot would hold — identical token streams, which is the
    equivalence ``tests/test_serving_paged.py`` pins."""
    hidden, new_pools, _ = transformer.forward(
        params, tokens, cfg, caches=pools, cache_len=slot_lens,
        block_tables=block_tables)
    logits = logits_from_hidden(params, hidden[:, -1], cfg)
    next_tok = sample_per_slot(rngs, logits, top_k, temperature)
    return next_tok, new_pools, slot_lens + 1


# ---------------------------------------------------------------------------
# Fixed-state (SSM / xLSTM / hybrid) paged serving: one block = one
# sequence's entire state row.  The pool layout is the contiguous slot-cache
# layout with the batch axis serving as the block axis (shared-attention
# segments carry a unit layer axis so every leaf keeps the block axis at
# position 1 — the pool contract in ``serving.cache_family``).
# ---------------------------------------------------------------------------
def gather_state_rows(cfg: ModelConfig, pools: list, rows: Array) -> list:
    """Gather pool rows ``rows`` [B] into a contiguous batch-B cache list —
    the exact pytree ``init_cache(cfg, B, slot_len)`` produces, so the
    ordinary slot-pool decode step runs on it unchanged."""
    rows = jnp.asarray(rows, jnp.int32)
    out: list = []
    for (kind, _), c in zip(transformer.block_pattern(cfg), pools):
        if kind == "shared_attn":
            out.append(compat.tree_map(
                lambda x: jnp.take(x[0], rows, axis=0), c))
        else:
            out.append(compat.tree_map(
                lambda x: jnp.take(x, rows, axis=1), c))
    return out


def scatter_state_rows(cfg: ModelConfig, pools: list, caches: list,
                       rows: Array) -> list:
    """Write a contiguous batch-B cache list back into pool rows ``rows``
    [B].  Out-of-range row indices are dropped — the scheduler routes
    inactive slots out of bounds so a gather/decode over garbage rows never
    writes anything back."""
    rows = jnp.asarray(rows, jnp.int32)
    out: list = []
    for (kind, _), p, c in zip(transformer.block_pattern(cfg), pools, caches):
        if kind == "shared_attn":
            out.append(compat.tree_map(
                lambda x, v: x.at[0, rows].set(v.astype(x.dtype),
                                               mode="drop"), p, c))
        else:
            out.append(compat.tree_map(
                lambda x, v: x.at[:, rows].set(v.astype(x.dtype),
                                               mode="drop"), p, c))
    return out


def decode_step_state(params: PyTree, pools: list, rows: Array,
                      active: Array, slot_lens: Array, tokens: Array,
                      cfg: ModelConfig, *, rngs: Array, top_k: int = 5,
                      temperature: float = 1.0):
    """One decode step over a fixed-state block pool: gather each active
    slot's state row, run the ordinary slot-pool decode, scatter the new
    state back.  Inactive slots gather the (zero-initialized) sentinel row
    and their writes are dropped, so their compute is discarded without
    touching live state — and because rows are independent through the whole
    network, the active slots' streams are bit-identical to solo decode."""
    rows = jnp.asarray(rows, jnp.int32)
    active = jnp.asarray(active, bool)
    num_rows = compat.tree_leaves(pools)[0].shape[1]
    caches = gather_state_rows(cfg, pools, jnp.where(active, rows, 0))
    next_tok, new_caches, new_lens = decode_step_slots(
        params, caches, slot_lens, tokens, cfg, rngs=rngs, top_k=top_k,
        temperature=temperature)
    new_pools = scatter_state_rows(
        cfg, pools, new_caches, jnp.where(active, rows, num_rows))
    return next_tok, new_pools, new_lens


# ---------------------------------------------------------------------------
# Encoder–decoder (whisper) serving.
# ---------------------------------------------------------------------------
def encdec_prefill(params: PyTree, frames: Array, bos_tokens: Array,
                   cfg: ModelConfig, *, max_len: int):
    """Encode audio-frame embeddings and prime the decoder cache."""
    b = frames.shape[0]
    enc_out = encdec.encode(params, frames, cfg)
    dt = jnp.dtype(cfg.dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    n = cfg.num_layers
    caches = {
        "self": {"k": jnp.zeros((n, b, max_len, hkv, hd), dt),
                 "v": jnp.zeros((n, b, max_len, hkv, hd), dt)},
        "cross": {"k": jnp.zeros((n, b, enc_out.shape[1], hkv, hd), dt),
                  "v": jnp.zeros((n, b, enc_out.shape[1], hkv, hd), dt)},
    }
    hidden, new_caches = encdec.decode_hidden(
        params, bos_tokens, enc_out, cfg, caches=caches,
        cache_len=jnp.asarray(0, jnp.int32))
    return hidden[:, -1], new_caches, jnp.asarray(bos_tokens.shape[1], jnp.int32)


def encdec_decode_step(params: PyTree, caches: PyTree, cache_len: Array,
                       tokens: Array, cfg: ModelConfig, *, rng: Array,
                       top_k: int = 5):
    hidden, new_caches = encdec.decode_hidden(
        params, tokens, None, cfg, caches=caches, cache_len=cache_len)
    logits = transformer.logits_last(params, hidden, cfg)
    next_tok, _ = core.topk_sample(rng, logits, top_k)
    return next_tok, new_caches, cache_len + 1


# ---------------------------------------------------------------------------
# Enc-dec paged serving: the prompt is the audio (frame ids); the encoder
# output's cross-K/V projection is sliced into immutable, shareable pool
# blocks, and each sequence additionally owns one growing decoder self-K/V
# row block.  The scheduler's key property: a repeated same-audio request
# adopts the cross blocks refcount++ and the encoder NEVER re-runs.
# ---------------------------------------------------------------------------
#: Decoder start token for served enc-dec requests.  Fixed — the prompt is
#: the audio; every decoder row begins at the same BOS, so two same-audio
#: requests differ only in their (rid, token-index) sample keys.
ENCDEC_BOS = 0


def encdec_frames_from_ids(ids, cfg: ModelConfig) -> Array:
    """Deterministic stand-in audio features for serving workloads: frame id
    ``i`` maps to the ``i``-th row of a sinusoidal table, so identical id
    sequences are identical audio.  Returns frames [1, S_enc, D]."""
    table = encdec.sinusoidal(cfg.vocab_size, cfg.d_model)
    return table[jnp.asarray(ids, jnp.int32)][None]


def encdec_prefill_cached(params: PyTree, cross: PyTree, bos_tokens: Array,
                          cfg: ModelConfig, *, max_len: int):
    """Prime a decoder cache from an already-computed cross-K/V projection
    ``{k, v: [n, B, S_enc, Hkv, D]}`` — the zero-encoder-recompute path a
    whole-audio prefix hit takes.  Bit-identical to ``encdec_prefill`` of
    the same audio: the stored K/V are exactly what the fresh encode
    produced, and attention over given K/V is the same computation either
    way.  Returns (last_hidden [B, D], caches, length)."""
    b = bos_tokens.shape[0]
    caches = dict(cache_family.resolve(cfg).init_cache(b, max_len))
    caches["cross"] = cross
    hidden, new_caches = encdec.decode_hidden(
        params, bos_tokens, None, cfg, caches=caches,
        cache_len=jnp.asarray(0, jnp.int32))
    return hidden[:, -1], new_caches, jnp.asarray(
        bos_tokens.shape[1], jnp.int32)


def encdec_decode_step_slots(params: PyTree, caches: PyTree,
                             slot_lens: Array, tokens: Array,
                             cfg: ModelConfig, *, rngs: Array,
                             top_k: int = 5, temperature: float = 1.0):
    """One continuous-batching decode step for enc-dec: tokens [B, 1],
    per-slot decoder lengths [B] → (next_token [B], new caches, lens + 1).
    Per-slot sampling keys, so streams are independent of batch neighbours —
    the same scheduler-equivalence guarantee as ``decode_step_slots``."""
    hidden, new_caches = encdec.decode_hidden(
        params, tokens, None, cfg, caches=caches, cache_len=slot_lens)
    logits = logits_from_hidden(params, hidden[:, -1], cfg)
    next_tok = sample_per_slot(rngs, logits, top_k, temperature)
    return next_tok, new_caches, slot_lens + 1


def gather_encdec_rows(pools: PyTree, cross_tables: Array,
                       self_rows: Array) -> PyTree:
    """Assemble contiguous decoder caches from the block pool:
    ``cross_tables`` [B, S_enc // BS] gathers and re-flattens the encoder
    blocks, ``self_rows`` [B] picks each sequence's self-K/V row."""
    cross_tables = jnp.asarray(cross_tables, jnp.int32)
    self_rows = jnp.asarray(self_rows, jnp.int32)
    b = cross_tables.shape[0]

    def flat_cross(x):
        g = x[:, cross_tables]                  # [n, B, nc, BS, Hkv, D]
        n, _, nc, bs = g.shape[:4]
        return g.reshape((n, b, nc * bs) + g.shape[4:])

    return {
        "self": compat.tree_map(lambda x: x[:, self_rows], pools["self"]),
        "cross": compat.tree_map(flat_cross, pools["cross"]),
    }


def gather_encdec_cross(pools: PyTree, cross_bids: Array) -> PyTree:
    """Re-flatten shared encoder blocks ``cross_bids`` [nc] into one
    contiguous batch-1 cross projection ``{k, v: [n, 1, S_enc, Hkv, D]}`` —
    the operand a whole-audio prefix hit hands ``encdec_prefill_cached``."""
    bids = jnp.asarray(cross_bids, jnp.int32)

    def flat(x):
        g = x[:, bids]                          # [n, nc, BS, Hkv, D]
        n, nc, bs = g.shape[:3]
        return g.reshape((n, 1, nc * bs) + g.shape[3:])

    return compat.tree_map(flat, pools["cross"])


def install_encdec_row(pools: PyTree, caches: PyTree, cross_bids: Array,
                       self_row: Array) -> PyTree:
    """Scatter a freshly-prefilled batch-1 decoder cache into the pool:
    the cross projection sliced into blocks ``cross_bids`` [nc] and the
    self row into block ``self_row``.  Out-of-range indices are dropped —
    a prefix-hit install passes out-of-range cross bids so the shared
    (identical) blocks are simply not rewritten."""
    cross_bids = jnp.asarray(cross_bids, jnp.int32)
    self_row = jnp.asarray(self_row, jnp.int32).reshape((1,))
    nc = cross_bids.shape[0]

    def put_cross(x, v):
        n, _, s_enc = v.shape[:3]
        blocks = v.reshape((n, nc, s_enc // nc) + v.shape[3:])
        return x.at[:, cross_bids].set(blocks.astype(x.dtype), mode="drop")

    return {
        "self": compat.tree_map(
            lambda x, v: x.at[:, self_row].set(v.astype(x.dtype),
                                               mode="drop"),
            pools["self"], caches["self"]),
        "cross": compat.tree_map(put_cross, pools["cross"],
                                 caches["cross"]),
    }


def decode_step_encdec_paged(params: PyTree, pools: PyTree,
                             cross_tables: Array, self_rows: Array,
                             active: Array, slot_lens: Array, tokens: Array,
                             cfg: ModelConfig, *, rngs: Array,
                             top_k: int = 5, temperature: float = 1.0):
    """One enc-dec decode step through the block pool: gather cross + self
    rows, run the slot decode, scatter ONLY the self rows back (cross blocks
    are immutable — possibly shared — and a decode step never changes
    them).  Inactive slots gather the sentinel row and their writes drop."""
    self_rows = jnp.asarray(self_rows, jnp.int32)
    active = jnp.asarray(active, bool)
    num_rows = compat.tree_leaves(pools)[0].shape[1]
    caches = gather_encdec_rows(
        pools, cross_tables, jnp.where(active, self_rows, 0))
    next_tok, new_caches, new_lens = encdec_decode_step_slots(
        params, caches, slot_lens, tokens, cfg, rngs=rngs, top_k=top_k,
        temperature=temperature)
    rows = jnp.where(active, self_rows, num_rows)
    new_self = compat.tree_map(
        lambda x, v: x.at[:, rows].set(v.astype(x.dtype), mode="drop"),
        pools["self"], new_caches["self"])
    return next_tok, {"self": new_self, "cross": pools["cross"]}, new_lens
