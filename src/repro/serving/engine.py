"""Serving: KV-cache management, prefill, and decode with fused top-k sampling.

The decode step ends in the paper's §4 scenario verbatim: a projection to the
full vocabulary followed by TopK — served by ``core.topk_sample`` (Algorithm 4,
single pass over the vocab, or the Pallas ``softmax_topk`` kernel on TPU).

Cache layout mirrors the model's segment structure: one stacked cache pytree
per segment (leading axis = layers in the segment).  Attention caches have a
static ``max_len``; ``cache_len`` tracks validity (continuous batching keeps
one shared length per batch — the standard serving simplification).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import core
from repro.configs.base import ModelConfig
from repro.models import encdec, ssm, transformer
from repro.models import xlstm as xlstm_mod

Array = jax.Array
PyTree = Any


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Build the per-segment stacked cache pytree (zeros)."""
    dt = jnp.dtype(cfg.dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def attn_cache(n):
        if cfg.kv_cache_dtype == "int8":
            return {"attn": {
                "k": jnp.zeros((n, batch, max_len, hkv, hd), jnp.int8),
                "v": jnp.zeros((n, batch, max_len, hkv, hd), jnp.int8),
                "k_scale": jnp.zeros((n, batch, max_len, hkv), jnp.bfloat16),
                "v_scale": jnp.zeros((n, batch, max_len, hkv), jnp.bfloat16)}}
        return {"attn": {
            "k": jnp.zeros((n, batch, max_len, hkv, hd), dt),
            "v": jnp.zeros((n, batch, max_len, hkv, hd), dt)}}

    caches: list = []
    layer_idx = 0
    for kind, count in transformer.block_pattern(cfg):
        if kind in ("dense", "moe"):
            caches.append(attn_cache(count))
        elif kind == "shared_attn":
            c = attn_cache(1)
            caches.append(jax.tree.map(lambda x: x[0], c))
        elif kind == "mla":
            m = cfg.mla
            caches.append({"attn": {
                "c_kv": jnp.zeros((count, batch, max_len, m.kv_lora_rank), dt),
                "k_rope": jnp.zeros((count, batch, max_len,
                                     m.qk_rope_head_dim), dt)}})
        elif kind == "mamba":
            one = ssm.mamba2_cache_init(cfg, batch, dt)
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape), one))
        elif kind in ("mlstm", "slstm"):
            one = xlstm_mod.xlstm_cache_init(
                cfg, layer_idx if kind == "slstm" else layer_idx, batch, dt)
            # pick representative layer of right kind
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape), one))
        else:
            raise ValueError(kind)
        layer_idx += count
    return caches


def prefill(params: PyTree, tokens: Array, cfg: ModelConfig, *,
            max_len: int, patch_embeds: Optional[Array] = None):
    """Run the prompt through the model, filling a fresh cache.

    Returns (last_hidden [B, D], caches, cache_len scalar)."""
    b, t = tokens.shape
    caches = init_cache(cfg, b, max_len)
    hidden, new_caches, _ = transformer.forward(
        params, tokens, cfg, patch_embeds=patch_embeds, caches=caches,
        cache_len=jnp.asarray(0, jnp.int32))
    return hidden[:, -1], new_caches, jnp.asarray(
        t + (cfg.num_patches if patch_embeds is not None else 0), jnp.int32)


def decode_step(params: PyTree, caches: list, cache_len: Array,
                tokens: Array, cfg: ModelConfig, *, rng: Array,
                top_k: int = 5, temperature: float = 1.0):
    """One decode step: tokens [B, 1] → (next_token [B], new caches).

    The final vocab softmax+topk+sample is the fused single-pass form.
    """
    hidden, new_caches, _ = transformer.forward(
        params, tokens, cfg, caches=caches, cache_len=cache_len)
    logits = transformer.logits_last(params, hidden, cfg)
    if cfg.real_vocab_size and cfg.real_vocab_size < cfg.vocab_size:
        mask = jnp.arange(cfg.vocab_size) < cfg.real_vocab_size
        logits = jnp.where(mask, logits, float("-inf"))
    from repro.distributed import context
    ctx = context.get()
    if ctx is not None:
        from repro.distributed.decode_attention import sharded_topk_sample
        next_tok, _ = sharded_topk_sample(
            rng, logits, top_k, mesh=ctx.mesh, batch_axes=ctx.batch_axes,
            vocab_axis=ctx.par.model_axis, temperature=temperature)
    else:
        # single-pass block width: the autotuned ⊕-tree choice for this
        # (backend, vocab, dtype), not a hard-coded chunk heuristic
        from repro.kernels import dispatch
        block = dispatch.tuned_block(logits.shape[-1], logits.dtype)
        next_tok, _ = core.topk_sample(rng, logits, top_k,
                                       temperature=temperature,
                                       block=min(block, logits.shape[-1]))
    return next_tok, new_caches, cache_len + 1


# ---------------------------------------------------------------------------
# Encoder–decoder (whisper) serving.
# ---------------------------------------------------------------------------
def encdec_prefill(params: PyTree, frames: Array, bos_tokens: Array,
                   cfg: ModelConfig, *, max_len: int):
    """Encode audio-frame embeddings and prime the decoder cache."""
    b = frames.shape[0]
    enc_out = encdec.encode(params, frames, cfg)
    dt = jnp.dtype(cfg.dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    n = cfg.num_layers
    caches = {
        "self": {"k": jnp.zeros((n, b, max_len, hkv, hd), dt),
                 "v": jnp.zeros((n, b, max_len, hkv, hd), dt)},
        "cross": {"k": jnp.zeros((n, b, enc_out.shape[1], hkv, hd), dt),
                  "v": jnp.zeros((n, b, enc_out.shape[1], hkv, hd), dt)},
    }
    hidden, new_caches = encdec.decode_hidden(
        params, bos_tokens, enc_out, cfg, caches=caches,
        cache_len=jnp.asarray(0, jnp.int32))
    return hidden[:, -1], new_caches, jnp.asarray(bos_tokens.shape[1], jnp.int32)


def encdec_decode_step(params: PyTree, caches: PyTree, cache_len: Array,
                       tokens: Array, cfg: ModelConfig, *, rng: Array,
                       top_k: int = 5):
    hidden, new_caches = encdec.decode_hidden(
        params, tokens, None, cfg, caches=caches, cache_len=cache_len)
    logits = transformer.logits_last(params, hidden, cfg)
    next_tok, _ = core.topk_sample(rng, logits, top_k)
    return next_tok, new_caches, cache_len + 1
