"""Continuous-batching scheduler: request queue, slot-based KV pool, and an
interleaved prefill/decode loop.

The decode hot path — vocab projection + fused online-softmax top-k (paper
§4) — only realizes its memory-access savings when decode steps run at full
batch occupancy.  A lockstep batch can't do that: it drains until its longest
member finishes, leaving slots idle.  This scheduler keeps the batch full:

* **SlotPool** — a fixed pool of KV-cache slots (one batch row each) with a
  per-slot length vector.  Finished slots are overwritten in place by the
  next request's prefilled cache; nothing ever waits for the batch to drain.
* **Admission** — by (priority, arrival tick), ties broken by submission
  order; with every request at the default priority this degenerates to the
  PR-2 FIFO.  A request is admitted when (a) it has arrived, (b) a slot is
  free, and (c) no
  other prefill is in flight (one prefill at a time bounds the decode stall a
  new request can inflict — the latency-aware part).  Its prompt then prefills **chunked**,
  interleaved with decode: the per-tick chunk budget scales with the number
  of idle slots (a nearly-full pool prefills one chunk per decode step to
  bound the stall; idle slots cost more tokens than a longer stall, so a
  drained pool prefills faster), and runs flat out when nothing is decoding.
  Time-to-first-token for queued work thus overlaps token generation for
  running work.  Each chunk prefills at its slot's running offset
  (``q_offset = cache_len``, ``kv_valid_len = cache_len + chunk``), operands
  the Pallas flash kernel now masks natively — chunked prefill is no longer
  pinned to the chunked XLA form on TPU serving.
* **Eviction** — a sequence is retired when it has produced its
  ``max_new_tokens``, emits ``eos_id``, or its slot is full
  (``len == slot_len``; recorded as ``evicted`` — the capacity backstop).
  Retirement frees the slot in the same tick, so the next queued request is
  admitted without interrupting anyone else.

* **Paged mode** (``paged=True``) — KV memory is a pool of fixed-size blocks
  with per-slot block tables (``repro.serving.paged``).  Admission is gated
  on free *blocks* after prefix matching (a request sharing another's prompt
  prefix adopts its physical blocks and prefills only from the divergence
  point), prefill chunks and decode tokens write straight into the pool
  through the table, and retirement — including the new out-of-blocks
  eviction backstop, which fires *before* a decode step the pool cannot
  back — returns every non-shared block to the free list in the same tick.
  Retired prompt blocks the prefix index still maps park in the pool's
  persistent LRU cache instead (entries outlive their last sequence);
  admission/decode pressure reclaims them coldest-first.

* **Priorities, SLOs, preemption** — requests carry a ``priority`` class
  (smaller = more urgent) and an optional ``slo_ms`` completion deadline
  that ``ServeReport`` scores per class.  Admission is deadline-aware
  within a class: candidates order by (priority, deadline slack, arrival),
  where slack = ``slo_ms`` minus time already waited — tighter deadlines
  place first, deadline-bearing requests outrank deadline-free peers, and
  uniform-SLO workloads keep their arrival order.  In paged mode with
  ``preempt=True``, a request that cannot be
  placed — no free row, or out of blocks *after* the pool reclaimed its cold
  prefix-cache blocks — swaps out the lowest-priority active decode,
  preferring deadline-free then loosest-slack then longest-remaining
  victims (``PagedPool.swap_out``: exclusive blocks to a host-side
  store, shared prefix blocks kept resident by reference).  The victim
  resumes later with no re-prefill and, because sample keys are
  (request id, token index), a token stream bit-identical to the
  never-preempted run.  The same swap runs before the out-of-blocks
  eviction backstop: live low-priority work yields before anyone is killed.
  While suspended work waits, each decode tick prefetches the next
  resume's host blocks back onto the device (``PagedPool.
  prefetch_swap_in``) concurrently with the step already in flight.

* **Engine layer** — the driving loop lives in ``repro.serving.engine_api``:
  ``Engine`` owns a scheduler instance and exposes the narrow
  ``submit / step / drain / stats / cache_probe`` surface that
  ``launch/serve.py``, the benchmarks, and ``repro.serving.router``'s
  multi-replica ``ReplicaRouter`` drive.  ``ContinuousScheduler.run``
  survives as a thin compatibility wrapper over ``Engine.serve``.

Determinism: a request's sample stream is keyed by (base_rng, request id,
token index) and sampling is per-slot (``engine.sample_per_slot``), so the
tokens a request produces are identical to running it alone through the
single-sequence decode path — regardless of arrival order, batch neighbours,
how its prefill was chunked, or whether its cache was contiguous or paged.
``tests/test_serving_continuous.py`` and ``tests/test_serving_paged.py`` pin
these equivalences.
"""
from __future__ import annotations

import contextlib
import functools
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.obs import clock as obs_clock
from repro.obs import kernels as obs_kernels
from repro.obs import metrics as obs_metrics
from repro.serving import cache_family, engine

Array = jax.Array


# ---------------------------------------------------------------------------
# Requests and results.
# ---------------------------------------------------------------------------
@dataclass(eq=False)                    # identity semantics: ndarray fields
class Request:                          # make generated __eq__ a crash hazard
    """One generation request.

    ``arrival_tick``: the scheduler tick at which the request becomes
    visible (0 = already waiting).  ``priority``: admission class, smaller
    is more urgent (default 0); admission orders by (priority, arrival) and
    — in paged mode with preemption on — a request that cannot be placed may
    swap out a strictly-lower-priority running decode.  ``slo_ms``: optional
    completion deadline in milliseconds measured from arrival; it does not
    change scheduling directly, but ``ServeReport.slo_attainment`` scores it
    and the serve CLI reports attainment per priority class."""
    rid: int
    prompt: np.ndarray                  # [T] token ids
    max_new_tokens: int
    arrival_tick: int = 0
    priority: int = 0                   # smaller = more urgent
    slo_ms: Optional[float] = None      # completion deadline from arrival


@dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: list = field(default_factory=list)
    arrival_time: float = 0.0           # wall-clock when first seen arrived
    admitted_time: Optional[float] = None   # queue wait ends (prefill starts)
    first_token_time: Optional[float] = None
    finish_time: float = 0.0
    evicted: bool = False               # retired by the slot-capacity backstop
    priority: int = 0                   # copied from the request
    slo_ms: Optional[float] = None      # copied from the request
    preempted: int = 0                  # times this request was swapped out
    dropped_latencies: int = 0          # per-token samples beyond the cap
    dropped_sum: float = 0.0
    _latencies: list = field(default_factory=list)

    # Per-token latency samples kept per request; percentile math stays
    # exact below the cap, and beyond it only count+sum are accumulated —
    # long-running streams no longer grow result memory without bound.
    MAX_RECORDED_LATENCIES = 8192

    def record_latency(self, latency: float) -> None:
        if len(self._latencies) < self.MAX_RECORDED_LATENCIES:
            self._latencies.append(latency)
        else:
            self.dropped_latencies += 1
            self.dropped_sum += latency

    @property
    def latencies(self) -> list:
        """Per-token latency: first token end-to-end from arrival, rest
        inter-token (capped — see ``record_latency``)."""
        return self._latencies

    @property
    def queued_ms(self) -> Optional[float]:
        """Queue wait: arrival → admission (prefill start)."""
        if self.admitted_time is None:
            return None
        return (self.admitted_time - self.arrival_time) * 1e3

    @property
    def prefill_ms(self) -> Optional[float]:
        """Prefill compute: admission → first token out."""
        if self.admitted_time is None or self.first_token_time is None:
            return None
        return (self.first_token_time - self.admitted_time) * 1e3

    @property
    def decode_ms(self) -> Optional[float]:
        """Decode: first token → finish (includes any suspended time)."""
        if self.first_token_time is None:
            return None
        return (self.finish_time - self.first_token_time) * 1e3

    @property
    def slo_met(self) -> Optional[bool]:
        """Whether the request finished inside its deadline (None: no SLO)."""
        if self.slo_ms is None:
            return None
        return (self.finish_time - self.arrival_time) * 1e3 <= self.slo_ms


@dataclass
class ServeReport:
    results: list                       # RequestResult, by completion order
    decode_steps: int
    prefill_chunks: int
    occupancy: float                    # mean active-slot fraction per decode step
    wall_time: float
    paged: Optional[dict] = None        # PagedPool.stats() when serving paged
    preemptions: int = 0                # swap-outs performed by the scheduler
    router: Optional[dict] = None       # ReplicaRouter stats (merged reports)
    started_at: Optional[float] = None  # serve-loop start (engine clock)
    ended_at: Optional[float] = None    # serve-loop end

    @property
    def total_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.results)

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_time, 1e-9)

    def latency_percentiles(self, qs=(50, 95)) -> dict:
        lats = [l for r in self.results for l in r.latencies]
        if not lats:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}

    def latency_percentiles_by_class(self, qs=(50, 95)) -> dict:
        """Per-token latency percentiles keyed by priority class — the
        p95-by-class view the SLO work is judged on."""
        out = {}
        for pr in sorted({r.priority for r in self.results}):
            lats = [l for r in self.results if r.priority == pr
                    for l in r.latencies]
            out[pr] = {f"p{q}": (float(np.percentile(lats, q)) if lats
                                 else 0.0) for q in qs}
        return out

    def slo_attainment(self) -> Optional[float]:
        """Fraction of SLO-bearing requests that finished inside their
        deadline (None when no request carried one)."""
        bearing = [r for r in self.results if r.slo_ms is not None]
        if not bearing:
            return None
        return sum(1 for r in bearing if r.slo_met) / len(bearing)

    def slo_counts_by_class(self) -> dict:
        """{priority: (met, bearing)} over deadline-carrying requests.
        Counts — unlike percentiles — combine across replicas by plain
        summation, so this is the per-class SLO view ``merge`` preserves
        exactly."""
        out: dict = {}
        for r in self.results:
            if r.slo_ms is None:
                continue
            met, bearing = out.get(r.priority, (0, 0))
            out[r.priority] = (met + (1 if r.slo_met else 0), bearing + 1)
        return out

    @classmethod
    def merge(cls, reports, *, router: Optional[dict] = None) -> "ServeReport":
        """Combine per-replica reports into one global report.

        Percentile inputs stay RAW: the per-request results (each carrying
        its latency samples) concatenate, so ``latency_percentiles`` and
        the by-class/SLO views run over the union of raw latencies — never
        an average of per-replica p95s, which would understate the tail.
        Counters (decode steps, prefill chunks, preemptions, the paged
        accounting incl. per-replica free/min-free capacities) sum;
        occupancy weights each replica by its decode steps.  Wall time is
        the true overlapped interval ``max(ended_at) - min(started_at)``
        when every report carries its serve start/end stamps (replicas
        serve concurrently but need not start together); reports without
        stamps fall back to ``max(wall_time)``."""
        reports = list(reports)
        if not reports:
            raise ValueError("merge needs at least one report")
        steps = sum(r.decode_steps for r in reports)
        occ = (reports[0].occupancy if len(reports) == 1
               else (sum(r.occupancy * r.decode_steps for r in reports)
                     / steps if steps else 0.0))
        paged_dicts = [r.paged for r in reports if r.paged is not None]
        paged = None
        if paged_dicts:
            paged = {k: (paged_dicts[0][k] if k == "block_size"
                         else sum(d[k] for d in paged_dicts))
                     for k in paged_dicts[0]}
        stamped = all(r.started_at is not None and r.ended_at is not None
                      for r in reports)
        started = min(r.started_at for r in reports) if stamped else None
        ended = max(r.ended_at for r in reports) if stamped else None
        wall = (ended - started if stamped
                else max(r.wall_time for r in reports))
        return cls(
            results=[res for r in reports for res in r.results],
            decode_steps=steps,
            prefill_chunks=sum(r.prefill_chunks for r in reports),
            occupancy=occ,
            wall_time=wall,
            paged=paged,
            preemptions=sum(r.preemptions for r in reports),
            router=router,
            started_at=started,
            ended_at=ended)

    def baseline_occupancy(self, num_slots: int) -> float:
        """Drain-and-refill bound on THIS workload, batched in the recorded
        arrival order (completion order would regroup similar lengths and
        misstate the bound — every report consumer should call this rather
        than re-deriving the ordering)."""
        ordered = sorted(self.results,
                         key=lambda r: (r.arrival_time, r.rid))
        return drain_and_refill_occupancy(
            [len(r.tokens) for r in ordered], num_slots)


def drain_and_refill_occupancy(decode_lens, num_slots: int) -> float:
    """Slot-step occupancy of the lockstep baseline on the same workload:
    batches of up to ``num_slots`` requests (pass ``decode_lens`` in ARRIVAL
    order — completion order would regroup similar lengths and misstate the
    bound) decode until the LONGEST member finishes, then the whole batch is
    swapped.  This is the bound the continuous scheduler has to beat."""
    decode_lens = list(decode_lens)
    if not decode_lens:
        return 0.0
    steps = 0
    for i in range(0, len(decode_lens), num_slots):
        steps += max(decode_lens[i:i + num_slots])
    return sum(decode_lens) / float(steps * num_slots)


# ---------------------------------------------------------------------------
# Compiled step functions — shared across scheduler instances via lru_cache
# (ModelConfig is frozen/hashable), so a fresh scheduler (or a benchmark's
# warmup instance) reuses already-compiled code instead of re-jitting.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _jitted_write(cfg: ModelConfig):
    return jax.jit(
        lambda pool, seq, slot: engine.write_slot(cfg, pool, seq, slot),
        donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg: ModelConfig, top_k: int, temperature: float):
    def decode(params, caches, lens, tokens, rids, produced, base_rng):
        # per-slot keys folded INSIDE the jit: one dispatch per tick instead
        # of 2B host-side fold_ins (bit-identical to the eager fold_in the
        # single-sequence reference path uses)
        keys = jax.vmap(lambda r, p: jax.random.fold_in(
            jax.random.fold_in(base_rng, r), p))(rids, produced)
        return engine.decode_step_slots(params, caches, lens, tokens, cfg,
                                        rngs=keys, top_k=top_k,
                                        temperature=temperature)

    return (jax.jit(decode, donate_argnums=(1,)),
            jax.jit(functools.partial(engine.prefill_chunk, cfg=cfg),
                    donate_argnums=(1,)),
            jax.jit(functools.partial(engine.logits_from_hidden, cfg=cfg)),
            jax.jit(functools.partial(engine.sample_per_slot, top_k=top_k,
                                      temperature=temperature)))


@functools.lru_cache(maxsize=None)
def _jitted_paged_steps(cfg: ModelConfig, top_k: int, temperature: float):
    """Paged-mode step functions: decode over (pools, block tables) and the
    block-table prefill chunk.  Same per-slot PRNG fold as the slot-pool
    decode, so a request's stream is independent of the cache layout."""
    def decode(params, pools, tables, lens, tokens, rids, produced, base_rng):
        keys = jax.vmap(lambda r, p: jax.random.fold_in(
            jax.random.fold_in(base_rng, r), p))(rids, produced)
        return engine.decode_step_paged(params, pools, tables, lens, tokens,
                                        cfg, rngs=keys, top_k=top_k,
                                        temperature=temperature)

    return (jax.jit(decode, donate_argnums=(1,)),
            jax.jit(functools.partial(engine.prefill_chunk_paged, cfg=cfg),
                    donate_argnums=(1,)))


@functools.lru_cache(maxsize=None)
def _jitted_state_steps(cfg: ModelConfig, top_k: int, temperature: float):
    """Fixed-state paged decode: gather each active slot's state row, run
    the ordinary slot decode, scatter back.  Same per-slot PRNG fold — the
    stream is independent of where the state physically lives."""
    def decode(params, pools, rows, active, lens, tokens, rids, produced,
               base_rng):
        keys = jax.vmap(lambda r, p: jax.random.fold_in(
            jax.random.fold_in(base_rng, r), p))(rids, produced)
        return engine.decode_step_state(params, pools, rows, active, lens,
                                        tokens, cfg, rngs=keys, top_k=top_k,
                                        temperature=temperature)

    return jax.jit(decode, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _jitted_encdec_steps(cfg: ModelConfig, slot_len: int, top_k: int,
                         temperature: float):
    """Enc-dec paged steps: (decode, fresh prefill, prefix-hit prefill,
    cross gather).  The two prefill forms produce bit-identical decoder
    caches for the same audio — the cached one just skips the encoder."""
    def decode(params, pools, cross_tables, self_rows, active, lens, tokens,
               rids, produced, base_rng):
        keys = jax.vmap(lambda r, p: jax.random.fold_in(
            jax.random.fold_in(base_rng, r), p))(rids, produced)
        return engine.decode_step_encdec_paged(
            params, pools, cross_tables, self_rows, active, lens, tokens,
            cfg, rngs=keys, top_k=top_k, temperature=temperature)

    return (jax.jit(decode, donate_argnums=(1,)),
            jax.jit(functools.partial(engine.encdec_prefill, cfg=cfg,
                                      max_len=slot_len)),
            jax.jit(functools.partial(engine.encdec_prefill_cached, cfg=cfg,
                                      max_len=slot_len)),
            jax.jit(engine.gather_encdec_cross))


# ---------------------------------------------------------------------------
# Slot pool.
# ---------------------------------------------------------------------------
class SlotPool:
    """Fixed pool of per-sequence KV-cache slots with a [num_slots] length
    vector — the thing that replaces the lockstep batch's shared scalar."""

    def __init__(self, cfg: ModelConfig, num_slots: int, slot_len: int):
        self.cfg = cfg
        self.num_slots = num_slots
        self.slot_len = slot_len
        self.caches = engine.init_cache(cfg, num_slots, slot_len)
        self.lens = jnp.zeros((num_slots,), jnp.int32)
        self._free = deque(range(num_slots))
        self._write = _jitted_write(cfg)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        return self._free.popleft() if self._free else None

    def release(self, slot: int) -> None:
        self.lens = self.lens.at[slot].set(0)
        self._free.append(slot)

    def insert(self, slot: int, seq_caches: list, length: int) -> None:
        """Overwrite ``slot`` with a prefilled batch-1 cache of ``length``."""
        self.caches = self._write(self.caches, seq_caches, jnp.int32(slot))
        self.lens = self.lens.at[slot].set(length)


# ---------------------------------------------------------------------------
# The scheduler.
# ---------------------------------------------------------------------------
@dataclass
class _InFlight:
    req: Request
    result: RequestResult
    slot: int = -1
    produced: int = 0                   # tokens sampled so far (keys the rng)
    remaining: int = 0
    last_token_time: float = 0.0        # inter-token latency baseline
    span: object = None                 # open lifecycle span (tracing only)


@dataclass
class _Suspended:
    """A preempted in-flight request parked off-pool: the flight keeps its
    produced/remaining counters (they key the PRNG stream) and ``token`` is
    the last sampled token, re-fed to decode on resume."""
    flight: _InFlight
    token: int


class ContinuousScheduler:
    """Drives the slot pool: admission → chunked prefill → pooled decode.

    One ``tick()`` = admit what fits, advance the in-flight prefill by one
    chunk, run one decode step over every slot.  ``run()`` loops until the
    queue, the prefill, the pool, and the suspended store are all empty.

    Keyword arguments
    -----------------
    num_slots:
        KV slots / batch rows in the pool (the decode batch width).
    slot_len:
        Per-sequence cache capacity in tokens (paged mode: must be a
        multiple of ``block_size``).
    prefill_chunk:
        Prompt tokens prefilled per scheduler tick while decodes are in
        flight (the latency/occupancy knob; see ``_advance_prefill``).
    top_k / temperature:
        Sampling parameters for the fused softmax+top-k draw.
    base_rng:
        PRNG key the per-(request id, token index) sample keys fold out of.
    eos_id:
        Token id that retires a sequence early (None: length-only).
    paged:
        Use the block-pool KV cache (``repro.serving.paged``) instead of
        contiguous slots; enables prefix sharing, the persistent prefix
        cache, and preempt-and-swap.
    block_size / num_blocks:
        Paged-mode pool geometry (tokens per block / usable blocks;
        ``num_blocks=None`` sizes the pool for every slot at full length).
    preempt:
        Paged mode only: allow a request that cannot be placed (no free
        row, or out of blocks even after LRU cache reclamation) to swap out
        a strictly-lower-priority running decode (``PagedPool.swap_out``).
        The victim resumes later bit-identically; ``False`` makes priorities
        ordering-only, the preemption-off baseline the benchmarks diff.
    clock:
        Time source for every latency/SLO stamp (default: the process-wide
        ``repro.obs.clock``).  Tests inject a ``VirtualClock`` here and
        advance it per tick for exact latency assertions.
    tracer / trace_pid:
        Optional ``repro.obs.trace.Tracer``: request-lifecycle spans go to
        track ``rid + 1``, scheduler ticks to track 0, under process id
        ``trace_pid`` (the replica index).  ``None`` — the default — keeps
        the hot path free of any tracing work.
    """

    def __init__(self, params, cfg: ModelConfig, *, num_slots: int,
                 slot_len: int, prefill_chunk: int = 32, top_k: int = 5,
                 temperature: float = 1.0, base_rng: Optional[Array] = None,
                 eos_id: Optional[int] = None, paged: bool = False,
                 block_size: int = 8, num_blocks: Optional[int] = None,
                 preempt: bool = True, clock: Optional[obs_clock.Clock] = None,
                 tracer=None, trace_pid: int = 0):
        self.params = params
        self.cfg = cfg
        self.family = cache_family.resolve(cfg)
        self.paged = paged
        if self.family.requires_paged and not paged:
            raise ValueError(
                f"{cfg.name!r} serves only in paged mode: the encoder output "
                "pages as immutable shared blocks "
                f"(family={self.family.name!r})")
        self.preempt = preempt
        self.clock = clock or obs_clock.get()
        self.tracer = tracer
        self._pid = trace_pid
        self._queued_spans: dict[int, object] = {}     # rid → open queued span
        self._metrics = (self._build_metrics(trace_pid)
                         if obs_metrics.enabled() else None)
        self._profiled = False          # one cost-analysis per scheduler
        if paged:
            from repro.serving import paged as paged_mod
            self.pool = paged_mod.PagedPool(cfg, num_slots, slot_len,
                                            block_size, num_blocks)
        else:
            self.pool = SlotPool(cfg, num_slots, slot_len)
        self.prefill_chunk = max(1, prefill_chunk)
        # a family whose prefill drops information when chunked (quantized
        # caches, recurrent state) sends its prompts in whole
        self._single_shot_prefill = self.family.single_shot_prefill
        self.top_k = top_k
        self.temperature = temperature
        self.base_rng = (base_rng if base_rng is not None
                         else jax.random.PRNGKey(0))
        self.eos_id = eos_id

        self.queue: deque[Request] = deque()
        self.active: dict[int, _InFlight] = {}         # slot → in-flight
        self._suspended: dict[int, _Suspended] = {}    # rid → preempted
        self.preemptions = 0
        self._prefill: Optional[dict] = None           # in-progress prefill
        self._arrival_times: dict[int, float] = {}     # rid → wall-clock seen
        self._seen_rids: set[int] = set()
        self.finished: list[RequestResult] = []
        self.tick_count = 0
        self.decode_steps = 0
        self.prefill_chunks = 0
        self._occupancy_sum = 0.0
        self.tokens = jnp.zeros((num_slots,), jnp.int32)
        (self._decode, self._prefill_step, self._logits,
         self._sample) = _jitted_steps(cfg, top_k, float(temperature))
        if paged and self.family.kind == "token":
            (self._decode_paged, self._prefill_paged) = _jitted_paged_steps(
                cfg, top_k, float(temperature))
        elif paged and self.family.kind == "state":
            self._decode_state = _jitted_state_steps(cfg, top_k,
                                                     float(temperature))
        elif paged and self.family.kind == "encdec":
            (self._decode_encdec, self._encdec_prefill,
             self._encdec_prefill_cached,
             self._encdec_gather_cross) = _jitted_encdec_steps(
                cfg, slot_len, top_k, float(temperature))

    # -- rng ----------------------------------------------------------------
    def _key(self, rid: int, token_index: int) -> Array:
        return jax.random.fold_in(
            jax.random.fold_in(self.base_rng, rid), token_index)

    # -- observability --------------------------------------------------------
    # The plain counters (decode_steps, preemptions, pool stats, …) stay the
    # authoritative inputs to ServeReport — they must read the same whether
    # the registry is on or off.  The registry only MIRRORS them (plus
    # distributions the report cannot hold), so disabling it changes nothing.
    def _build_metrics(self, pid: int) -> dict:
        prefix = f"serving.r{pid}" if pid else "serving"
        m = {
            "tokens": obs_metrics.counter(f"{prefix}.tokens"),
            "preemptions": obs_metrics.counter(f"{prefix}.preemptions"),
            "occupancy": obs_metrics.histogram(f"{prefix}.occupancy"),
            "tick_ms": obs_metrics.histogram(f"{prefix}.tick_ms"),
            "active": obs_metrics.gauge(f"{prefix}.active"),
            "queue_depth": obs_metrics.gauge(f"{prefix}.queue_depth"),
            "free_slots": obs_metrics.gauge(f"{prefix}.free_slots"),
        }
        if self.paged:
            # free_blocks is a Gauge, so its .min IS the low-water mark
            for k in ("free_blocks", "cached_blocks", "prefix_cache_hits",
                      "swapped_bytes_out", "swapped_bytes_in"):
                m[k] = obs_metrics.gauge(f"{prefix}.{k}")
        return m

    def _update_metrics(self) -> None:
        m = self._metrics
        m["active"].set(len(self.active))
        m["queue_depth"].set(len(self.queue) + len(self._suspended))
        if self.paged:
            m["free_slots"].set(self.pool.free_slots)
            m["free_blocks"].set(self.pool.free_blocks)
            m["cached_blocks"].set(self.pool.cached_blocks)
            m["prefix_cache_hits"].set(self.pool.prefix_cache_hits)
            m["swapped_bytes_out"].set(self.pool.swapped_bytes_out)
            m["swapped_bytes_in"].set(self.pool.swapped_bytes_in)
        else:
            m["free_slots"].set(self.pool.free_slots)

    @staticmethod
    def _tid(rid: int) -> int:
        """Trace track for a request (track 0 is the scheduler's)."""
        return rid + 1

    def _span(self, name: str, *, tid: int = 0, args=None):
        """Scheduler-side span context; a no-op without a tracer."""
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, tid=tid, pid=self._pid, args=args)

    def _begin_phase(self, flight: _InFlight, name: str, args=None) -> None:
        """Close the flight's current lifecycle span and open ``name``."""
        if self.tracer is None:
            return
        if flight.span is not None:
            self.tracer.end(flight.span)
        flight.span = self.tracer.begin(
            name, tid=self._tid(flight.req.rid), pid=self._pid, args=args)

    def _end_phase(self, flight: _InFlight) -> None:
        if self.tracer is not None and flight.span is not None:
            self.tracer.end(flight.span)
            flight.span = None

    # -- API ----------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be ≥ 1 "
                             f"(got {req.max_new_tokens})")
        try:
            # family-specific admissibility: dense/state prompts must leave
            # decode room in the slot; enc-dec prompts are audio frames that
            # must fill the encoder window exactly
            self.family.validate_prompt(len(req.prompt), self.pool.slot_len)
        except ValueError as e:
            raise ValueError(f"request {req.rid}: {e}") from None
        if self.paged and not self.pool.fits(len(req.prompt)):
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} can never "
                "be admitted — its block need exceeds the whole pool")
        if req.rid in self._seen_rids:
            raise ValueError(f"duplicate request id {req.rid}: rids key the "
                             "sample streams and result bookkeeping")
        self._seen_rids.add(req.rid)
        self.queue.append(req)

    def tick(self) -> None:
        self.tick_count += 1
        now = self.clock.monotonic()
        for r in self.queue:           # stamp arrivals BEFORE admission, so
            if (r.arrival_tick <= self.tick_count     # queue wait is counted
                    and r.rid not in self._arrival_times):
                self._arrival_times[r.rid] = now
                if self.tracer is not None:
                    self.tracer.thread_name(self._tid(r.rid), f"req {r.rid}",
                                            pid=self._pid)
                    self._queued_spans[r.rid] = self.tracer.begin(
                        "queued", tid=self._tid(r.rid), pid=self._pid,
                        args={"rid": r.rid, "priority": r.priority})
        # section spans only when the section has work — idle sections are
        # trace noise and, at ~3 spans/tick, a measurable share of overhead
        with self._span("tick", args={"tick": self.tick_count}):
            if self.queue:
                with self._span("admit"):
                    self._admit()
            else:
                self._admit()
            if self._prefill is not None:
                with self._span("prefill"):
                    self._advance_prefill()
            else:
                self._advance_prefill()
            if self.active:
                with self._span("decode"):
                    self._decode_tick()
            else:
                self._decode_tick()
        if self.tracer is not None:
            self.tracer.counter("sched", {
                "active": len(self.active), "queue": len(self.queue),
                "free_slots": self.pool.free_slots}, pid=self._pid)
            if self.paged:
                self.tracer.counter("blocks", {
                    "free": self.pool.free_blocks,
                    "cached": self.pool.cached_blocks}, pid=self._pid)
        if self._metrics is not None:
            self._update_metrics()
            self._metrics["tick_ms"].observe(
                (self.clock.monotonic() - now) * 1e3)

    @property
    def busy(self) -> bool:
        """Work remains: queued (incl. future arrivals), prefilling,
        decoding, or suspended."""
        return bool(self.queue or self.active or self._prefill
                    or self._suspended)

    def run(self, requests=None, *, max_ticks: int = 100_000) -> ServeReport:
        """Serve ``requests`` to completion and report.  Thin wrapper: the
        loop itself lives in the engine layer — this wraps the scheduler in
        an ``Engine`` view and drives ``Engine.step`` until idle, so every
        consumer (CLI, router, benchmarks, this method) runs the exact same
        loop."""
        from repro.serving.engine_api import Engine   # avoids import cycle
        return Engine.wrap(self).serve(requests, max_ticks=max_ticks)

    # -- admission ----------------------------------------------------------
    def _admit(self) -> None:
        """Place waiting work in (priority, arrival) order.

        Suspended (preempted) requests compete with the queue under the same
        key — preferred on ties, since their prefill is already paid.  Any
        number of resumes can happen per tick (no prefill involved); at most
        one NEW prefill starts, preserving the one-in-flight bound.  The
        head never skips: when the best candidate cannot be placed — even
        after the pool reclaimed cold prefix-cache blocks and, failing that,
        preemption swapped out strictly-lower-priority decodes — admission
        stops for this tick."""
        while True:
            cand = self._next_candidate()
            if cand is None:
                return
            kind, obj = cand
            if kind == "resume":
                prio = self._suspended[obj].flight.req.priority
                if self._try_resume(obj) or self._make_room(
                        prio, lambda: self._try_resume(obj)):
                    continue
                return
            if self._prefill is not None:
                return                       # one prefill in flight at a time
            if self._start_prefill(obj) or self._make_room(
                    obj.priority, lambda: self._start_prefill(obj)):
                return                       # one new prefill per tick
            return

    def _slack(self, req: Request, now: float) -> float:
        """Deadline headroom in ms: ``slo_ms`` minus the time already
        waited since arrival (+inf for deadline-free requests)."""
        if req.slo_ms is None:
            return float("inf")
        arrived = self._arrival_times.get(req.rid, now)
        return req.slo_ms - (now - arrived) * 1e3

    def _next_candidate(self):
        """Best waiting work item: ``("resume", rid)`` or ``("admit", req)``,
        ordered by (priority, deadline slack, arrival tick,
        resume-before-admit, FIFO).  Slack makes admission deadline-aware
        WITHIN a priority class: tighter deadlines place first, and a
        deadline-bearing request outranks deadline-free peers (slack +inf).
        With uniform ``slo_ms`` per class — every workload the generator
        produces — slack order equals arrival order, so the FIFO
        equivalence pins are untouched."""
        now = self.clock.monotonic()
        best = None
        for i, (rid, rec) in enumerate(self._suspended.items()):
            req = rec.flight.req
            key = (req.priority, self._slack(req, now), req.arrival_tick, 0, i)
            if best is None or key < best[0]:
                best = (key, ("resume", rid))
        for i, r in enumerate(self.queue):
            if r.arrival_tick > self.tick_count:
                continue
            key = (r.priority, self._slack(r, now), r.arrival_tick, 1, i)
            if best is None or key < best[0]:
                best = (key, ("admit", r))
        return best[1] if best else None

    def _start_prefill(self, req: Request) -> bool:
        """Claim capacity for ``req`` and set up its chunked prefill; False
        when the pool cannot place it (it stays queued)."""
        result = RequestResult(
            rid=req.rid, prompt_len=len(req.prompt), priority=req.priority,
            slo_ms=req.slo_ms, arrival_time=self._arrival_times[req.rid])
        if self.paged:
            # admission gates on free BLOCKS (after prefix matching and LRU
            # cache reclamation), not a whole worst-case-length slot
            seq = self.pool.admit(req.prompt)
            if seq is None:
                return False
            self.queue.remove(req)
            flight = _InFlight(req=req, result=result, slot=seq.slot,
                               remaining=req.max_new_tokens)
            if self.family.kind == "state":
                # single-shot into a batch-1 scratch cache, installed into
                # the sequence's state block at finish (the pool row is
                # donated to the decode jit, so prefill can't write it live)
                self._prefill = {
                    "flight": flight, "seq": seq,
                    "caches": engine.init_cache(self.cfg, 1,
                                                self.pool.slot_len),
                    "length": jnp.asarray(0, jnp.int32), "pos": 0,
                    "sizes": deque([len(req.prompt)]), "last": None,
                }
            elif self.family.kind == "encdec":
                # one shot: encode (or adopt the shared cross blocks) and
                # prime the decoder row at BOS — see _advance_encdec_prefill
                self._prefill = {
                    "flight": flight, "seq": seq, "caches": None,
                    "length": jnp.asarray(0, jnp.int32), "pos": 0,
                    "sizes": deque([len(req.prompt)]), "last": None,
                }
            else:
                self._prefill = {
                    "flight": flight,
                    "seq": seq,
                    "length": jnp.asarray(seq.matched, jnp.int32),
                    "pos": seq.matched,
                    # prefill resumes at the first unmatched token — shared
                    # prefix blocks already hold bit-identical cache content;
                    # single-shot families (quantized prefill never re-reads
                    # the stored prefix) get the whole remainder in one chunk
                    "sizes": deque(
                        [len(req.prompt) - seq.matched]
                        if self._single_shot_prefill
                        else engine.prefill_schedule(
                            len(req.prompt) - seq.matched,
                            self.prefill_chunk)),
                    "last": None,
                }
            self._admitted(self._prefill["flight"])
            return True
        if self.pool.free_slots == 0:
            return False
        self.queue.remove(req)
        self._prefill = {
            "flight": _InFlight(req=req, result=result,
                                remaining=req.max_new_tokens),
            "caches": engine.init_cache(self.cfg, 1, self.pool.slot_len),
            "length": jnp.asarray(0, jnp.int32),
            "pos": 0,
            # same schedule as chunked_prefill → same cache contents as a
            # solo prefill, and only O(log chunk) compiled tail widths
            "sizes": deque([len(req.prompt)] if self._single_shot_prefill
                           else engine.prefill_schedule(len(req.prompt),
                                                        self.prefill_chunk)),
            "last": None,
        }
        self._admitted(self._prefill["flight"])
        return True

    def _admitted(self, flight: _InFlight) -> None:
        """Queue wait ends here: stamp the phase split and flip the trace
        track from ``queued`` to ``prefill``."""
        flight.result.admitted_time = self.clock.monotonic()
        if self.tracer is not None:
            span = self._queued_spans.pop(flight.req.rid, None)
            if span is not None:
                self.tracer.end(span)
            flight.span = self.tracer.begin(
                "prefill", tid=self._tid(flight.req.rid), pid=self._pid,
                args={"prompt_len": flight.result.prompt_len})

    # -- preemption ---------------------------------------------------------
    def _make_room(self, priority: int, attempt) -> bool:
        """Swap out lower-priority victims one at a time, retrying
        ``attempt`` after each, until it succeeds, no victim remains, or a
        victim's swap freed no blocks while a row already sat free (blocks
        are then the binding constraint and further victims — whose pool
        residue is all shared — would be suspended for nothing).  Cold
        prefix-cache blocks were already reclaimed inside the pool —
        preempting live work is strictly the last resort."""
        if not (self.paged and self.preempt):
            return False                # SlotPool has no preemption (or off)
        while True:
            blocks_before = self.pool.free_blocks
            if not self._preempt_one(priority):
                return False
            if attempt():
                return True
            if (self.pool.free_slots > 0
                    and self.pool.free_blocks <= blocks_before):
                return False

    def _preempt_one(self, priority: int) -> bool:
        """Swap out ONE active decode strictly below ``priority``: the
        lowest-priority class first; within a class, prefer deadline-free
        victims, then the loosest deadline, then the longest remaining
        decode (the victim that frees capacity for the longest).  False
        when preemption is off, unpaged, or no strictly-lower-priority
        decode is running — equal-priority work is never preempted, so
        every class makes progress.  When no victim bears a deadline the
        key degenerates to the pre-deadline (priority, remaining, rid)
        order, so deadline-free workloads preempt exactly as before."""
        if not (self.paged and self.preempt) or not self.active:
            return False
        victims = [f for f in self.active.values()
                   if f.req.priority > priority]
        if not victims:
            return False
        now = self.clock.monotonic()
        victim = max(victims, key=lambda f: (f.req.priority,
                                             f.req.slo_ms is None,
                                             self._slack(f.req, now),
                                             f.remaining,
                                             f.req.rid))
        self._swap_out(victim)
        return True

    def _swap_out(self, flight: _InFlight) -> None:
        slot = flight.slot
        del self.active[slot]
        self.pool.swap_out(slot, flight.req.rid)
        self._suspended[flight.req.rid] = _Suspended(
            flight=flight, token=flight.result.tokens[-1])
        flight.slot = -1
        flight.result.preempted += 1
        self.preemptions += 1
        if self._metrics is not None:
            self._metrics["preemptions"].inc()
        if self.tracer is not None:
            self.tracer.instant(
                "preempt", tid=self._tid(flight.req.rid), pid=self._pid,
                args={"cause": "priority", "produced": flight.produced})
        self._begin_phase(flight, "suspended")

    def _prefetch_swap_in(self) -> None:
        """Stage the host-resident blocks of the suspended request most
        likely to resume next (same key order as ``_next_candidate``) onto
        the device while the current decode step is still in flight."""
        now = self.clock.monotonic()
        best = None
        for i, (rid, rec) in enumerate(self._suspended.items()):
            req = rec.flight.req
            key = (req.priority, self._slack(req, now), req.arrival_tick, i)
            if best is None or key < best[0]:
                best = (key, rid)
        if best is not None:
            self.pool.prefetch_swap_in(best[1])

    def _try_resume(self, rid: int) -> bool:
        """Reattach a suspended request: ``PagedPool.swap_in`` rebuilds its
        blocks/table/length, the last sampled token is re-fed, and decode
        continues — the (rid, token index) sample keys make the remaining
        stream bit-identical to the never-preempted run."""
        rec = self._suspended[rid]
        seq = self.pool.swap_in(rid)
        if seq is None:
            return False
        flight = rec.flight
        flight.slot = seq.slot
        self.tokens = self.tokens.at[seq.slot].set(rec.token)
        self.active[seq.slot] = flight
        del self._suspended[rid]
        self._begin_phase(flight, "decode", args={"resumed": True})
        return True

    # -- prefill ------------------------------------------------------------
    def _advance_prefill(self) -> None:
        if self._prefill is None:
            return
        # latency/occupancy tradeoff: one chunk per tick while the pool is
        # nearly full (bounded decode stall), proportionally more when slots
        # sit idle — idle slots cost more tokens than a longer stall — and
        # everything at once when nobody is waiting on decode
        budget = max(1, self.pool.free_slots) if self.active else 10 ** 9
        pf = self._prefill
        if self.paged and self.family.kind == "encdec":
            self._advance_encdec_prefill(pf)
            return
        prompt = pf["flight"].req.prompt
        while budget > 0 and pf["sizes"]:
            width = pf["sizes"].popleft()
            chunk = np.asarray(prompt[pf["pos"]:pf["pos"] + width])[None, :]
            chunk_span = (self.tracer.begin(
                "prefill_chunk", tid=self._tid(pf["flight"].req.rid),
                pid=self._pid, args={"pos": pf["pos"], "width": width})
                if self.tracer is not None else None)
            if self.paged and self.family.kind == "token":
                # chunks write straight into the shared pool through this
                # sequence's block-table row — no batch-1 scratch cache, no
                # insert copy at the end
                pf["last"], self.pool.caches, pf["length"] = \
                    self._prefill_paged(
                        self.params, self.pool.caches,
                        self.pool.device_row(pf["flight"].slot),
                        pf["length"], jnp.asarray(chunk))
            else:
                # unpaged slots AND paged fixed-state: single-sequence
                # prefill into the scratch cache (state installs into its
                # pool block at finish)
                pf["last"], pf["caches"], pf["length"] = self._prefill_step(
                    self.params, pf["caches"], pf["length"],
                    jnp.asarray(chunk))
            pf["pos"] += width
            self.prefill_chunks += 1
            budget -= 1
            if chunk_span is not None:
                self.tracer.end(chunk_span)
        if pf["sizes"]:
            return
        self._finish_prefill()

    def _advance_encdec_prefill(self, pf: dict) -> None:
        """One-shot enc-dec prefill: a whole-audio prefix hit gathers the
        shared cross blocks and skips the encoder entirely; a miss encodes
        the frames.  Both paths prime the decoder row at BOS and produce
        bit-identical decoder caches for the same audio."""
        flight = pf["flight"]
        seq = pf["seq"]
        bos = jnp.full((1, 1), engine.ENCDEC_BOS, jnp.int32)
        span = (self.tracer.begin(
            "prefill_chunk", tid=self._tid(flight.req.rid), pid=self._pid,
            args={"pos": 0, "width": len(flight.req.prompt),
                  "encoder_skipped": bool(seq.matched)})
            if self.tracer is not None else None)
        if seq.matched:
            nc = self.pool.max_blocks - 1
            cross = self._encdec_gather_cross(
                self.pool.caches, jnp.asarray(seq.blocks[:nc], jnp.int32))
            pf["last"], pf["caches"], pf["length"] = \
                self._encdec_prefill_cached(self.params, cross, bos)
        else:
            frames = engine.encdec_frames_from_ids(flight.req.prompt,
                                                   self.cfg)
            pf["last"], pf["caches"], pf["length"] = self._encdec_prefill(
                self.params, frames, bos)
        pf["sizes"].clear()
        pf["pos"] = len(flight.req.prompt)
        self.prefill_chunks += 1
        if span is not None:
            self.tracer.end(span)
        self._finish_prefill()

    def _finish_prefill(self) -> None:
        pf = self._prefill
        self._prefill = None
        flight: _InFlight = pf["flight"]
        rid = flight.req.rid
        logits = self._logits(self.params, pf["last"])
        tok = self._sample(self._key(rid, 0)[None], logits)
        # the first sampled token closes the prefill phase: record it, then
        # flip the lifecycle track to decode
        self._record_token(flight, int(tok[0]))
        self._begin_phase(flight, "decode")
        if flight.remaining <= 0 or self._hit_eos(flight):
            self._finish(flight)
            return
        if self.paged:
            slot = flight.slot               # row claimed at admission
            if self.family.kind == "state":
                self.pool.install_state(pf["seq"], pf["caches"])
            elif self.family.kind == "encdec":
                self.pool.install_encdec(pf["seq"], pf["caches"])
            self.pool.finalize_prefill(pf["seq"])
            self.pool.lens = self.pool.lens.at[slot].set(int(pf["length"]))
        else:
            slot = self.pool.acquire()
            assert slot is not None          # _admit gated on a free slot
            self.pool.insert(slot, pf["caches"], int(pf["length"]))
            flight.slot = slot
        self.tokens = self.tokens.at[slot].set(int(tok[0]))
        self.active[slot] = flight

    # -- decode -------------------------------------------------------------
    def _decode_tick(self) -> None:
        if not self.active:
            return
        if self.paged:
            # make every active row's next write position backed by an
            # exclusively-owned block (allocate across boundaries, CoW shared
            # blocks).  A row the pool cannot back — even after reclaiming
            # cold prefix-cache blocks inside prepare_write — first swaps out
            # strictly-lower-priority decodes (they resume bit-identically);
            # only with no such victim left is it evicted, returning its
            # non-shared blocks to the free list in this same tick
            lens_pre = np.asarray(self.pool.lens)
            for slot in list(self.active):
                flight = self.active.get(slot)
                if flight is None:          # swapped out as a victim above
                    continue
                ok = self.pool.prepare_write(slot, int(lens_pre[slot]))
                while not ok:
                    blocks_before = self.pool.free_blocks
                    if not self._preempt_one(flight.req.priority):
                        break
                    ok = self.pool.prepare_write(slot, int(lens_pre[slot]))
                    if not ok and self.pool.free_blocks <= blocks_before:
                        break               # victim freed nothing usable
                if not ok:
                    flight.result.evicted = True
                    self._finish(flight)
            if not self.active:
                return
        rids = np.full((self.pool.num_slots,), -1, np.int32)   # -1: idle slot
        produced = np.zeros((self.pool.num_slots,), np.int32)  # (sample dropped)
        active_mask = np.zeros((self.pool.num_slots,), bool)
        for s, flight in self.active.items():
            rids[s] = flight.req.rid
            produced[s] = flight.produced
            active_mask[s] = True
        if not self._profiled and obs_kernels.profiling_enabled():
            # one-time roofline hook: FLOPs / bytes of the compiled decode
            # step via compat.cost_analysis (lower+compile hits the jit
            # cache for shapes the step below compiles anyway)
            self._profiled = True
            if self.paged and self.family.kind != "token":
                pass        # roofline hook covers the dense step shapes
            elif self.paged:
                obs_kernels.profile_jitted(
                    self._decode_paged, "decode_step_paged", self.params,
                    self.pool.caches,
                    self.pool.device_tables(self.active.keys()),
                    self.pool.lens, self.tokens[:, None], jnp.asarray(rids),
                    jnp.asarray(produced), self.base_rng)
            else:
                obs_kernels.profile_jitted(
                    self._decode, "decode_step", self.params,
                    self.pool.caches, self.pool.lens, self.tokens[:, None],
                    jnp.asarray(rids), jnp.asarray(produced), self.base_rng)
        if self.paged and self.family.kind == "state":
            # each active slot decodes in its own state row; inactive slots
            # gather the sentinel row and their writes drop
            rows = np.zeros((self.pool.num_slots,), np.int32)
            for s in self.active:
                rows[s] = self.pool.seqs[s].blocks[0]
            tok, self.pool.caches, new_lens = self._decode_state(
                self.params, self.pool.caches, jnp.asarray(rows),
                jnp.asarray(active_mask), self.pool.lens,
                self.tokens[:, None], jnp.asarray(rids),
                jnp.asarray(produced), self.base_rng)
        elif self.paged and self.family.kind == "encdec":
            # table row = [cross blocks..., self row]; cross is immutable so
            # only the self rows scatter back
            tables = self.pool.device_tables(self.active.keys())
            nc = self.pool.max_blocks - 1
            tok, self.pool.caches, new_lens = self._decode_encdec(
                self.params, self.pool.caches, tables[:, :nc], tables[:, nc],
                jnp.asarray(active_mask), self.pool.lens,
                self.tokens[:, None], jnp.asarray(rids),
                jnp.asarray(produced), self.base_rng)
        elif self.paged:
            # non-active rows (idle OR mid-prefill) are masked to the
            # sentinel table row: their lens-0 garbage write must land in
            # block 0, never in a live block a prefill already filled
            tok, self.pool.caches, new_lens = self._decode_paged(
                self.params, self.pool.caches,
                self.pool.device_tables(self.active.keys()),
                self.pool.lens, self.tokens[:, None], jnp.asarray(rids),
                jnp.asarray(produced), self.base_rng)
        else:
            tok, self.pool.caches, new_lens = self._decode(
                self.params, self.pool.caches, self.pool.lens,
                self.tokens[:, None], jnp.asarray(rids),
                jnp.asarray(produced), self.base_rng)
        # idle slots don't age: their garbage write lands at 0 and is fully
        # overwritten by the next insert
        self.pool.lens = jnp.where(jnp.asarray(active_mask), new_lens, 0)
        self.tokens = tok
        self.decode_steps += 1
        self._occupancy_sum += len(self.active) / self.pool.num_slots
        if self._metrics is not None:
            self._metrics["occupancy"].observe(
                len(self.active) / self.pool.num_slots)
        if self.paged and self._suspended:
            # Overlap host→device swap-in staging with the decode step just
            # dispatched above: JAX queues the transfers asynchronously, so
            # they run while we block on np.asarray(tok) below.  Bit-exact —
            # swap_in consumes the staged device copies of the same payloads.
            self._prefetch_swap_in()
        tok_host = np.asarray(tok)
        lens_host = np.asarray(self.pool.lens)     # one sync, not per slot
        for slot in list(self.active):
            flight = self.active[slot]
            self._record_token(flight, int(tok_host[slot]))
            slot_full = int(lens_host[slot]) >= self.pool.slot_len
            if flight.remaining <= 0 or self._hit_eos(flight) or slot_full:
                flight.result.evicted = (slot_full and flight.remaining > 0
                                         and not self._hit_eos(flight))
                self._finish(flight)

    # -- bookkeeping --------------------------------------------------------
    def _record_token(self, flight: _InFlight, token: int) -> None:
        now = self.clock.monotonic()
        result = flight.result
        result.tokens.append(token)
        if flight.produced == 0:
            result.first_token_time = now
            result.record_latency(now - result.arrival_time)
        else:
            result.record_latency(now - flight.last_token_time)
        flight.last_token_time = now
        if self._metrics is not None:
            self._metrics["tokens"].inc()
        if self.tracer is not None:
            self.tracer.instant(
                "token", tid=self._tid(flight.req.rid), pid=self._pid,
                args={"i": flight.produced, "token": token})
        flight.produced += 1
        flight.remaining -= 1

    def _hit_eos(self, flight: _InFlight) -> bool:
        return (self.eos_id is not None and flight.result.tokens
                and flight.result.tokens[-1] == self.eos_id)

    def _finish(self, flight: _InFlight) -> None:
        flight.result.finish_time = self.clock.monotonic()
        self._end_phase(flight)
        if self.tracer is not None:
            cause = ("evicted" if flight.result.evicted
                     else "eos" if self._hit_eos(flight) and flight.remaining > 0
                     else "completed")
            self.tracer.instant(
                "retire", tid=self._tid(flight.req.rid), pid=self._pid,
                args={"cause": cause, "tokens": len(flight.result.tokens)})
        self.finished.append(flight.result)
        if flight.slot >= 0:
            # paged flights own their row (and blocks) from admission, so a
            # request retired straight out of prefill is not in `active` yet
            self.active.pop(flight.slot, None)
            self.pool.release(flight.slot)


# ---------------------------------------------------------------------------
# Synthetic workloads.
# ---------------------------------------------------------------------------
def poisson_workload(n_requests: int, *, rate_per_tick: float,
                     prompt_lens=(8, 32), decode_lens=(4, 32),
                     vocab: int = 1000, seed: int = 0,
                     shared_prefix: int = 0, priority_classes: int = 1,
                     slo_ms: Optional[float] = None) -> list:
    """Staggered synthetic requests: Poisson arrivals (exponential
    inter-arrival gaps in scheduler ticks), uniform prompt/decode lengths.

    ``shared_prefix > 0`` prepends the same random prefix to every prompt —
    the system-prompt pattern paged serving's prefix index deduplicates.
    ``priority_classes > 1`` assigns each request a uniform-random priority
    in [0, classes) — the mixed-priority workload the SLO scheduling is
    benchmarked on — and ``slo_ms`` attaches a completion deadline to the
    urgent class (priority 0), whose attainment the serve report scores."""
    rng = np.random.default_rng(seed)
    prefix = (rng.integers(0, vocab, shared_prefix) if shared_prefix
              else None)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / max(rate_per_tick, 1e-9))
        body = rng.integers(0, vocab, rng.integers(prompt_lens[0],
                                                   prompt_lens[1] + 1))
        priority = (int(rng.integers(0, priority_classes))
                    if priority_classes > 1 else 0)
        out.append(Request(
            rid=rid,
            prompt=body if prefix is None else np.concatenate([prefix, body]),
            max_new_tokens=int(rng.integers(decode_lens[0],
                                            decode_lens[1] + 1)),
            arrival_tick=int(t), priority=priority,
            slo_ms=slo_ms if (slo_ms and priority == 0) else None))
    return out
