"""Paged KV-cache serving: block allocator, prefix sharing, block tables.

PR 2's slot pool reserves one contiguous max-length KV region per sequence,
so batch size is capped by worst-case length and identical prompt prefixes
are stored once *per request*.  This module replaces the region with a pool
of fixed-size KV **blocks**:

* ``BlockAllocator`` — a fixed pool of physical blocks with a free list and
  per-block refcounts.  Allocation is O(1); freeing is refcount-aware, so a
  block shared by several sequences survives until its last reference drops.
  Double-frees raise instead of corrupting the pool.
* ``PrefixIndex`` — hash-of-token-prefix → block chain.  Every *full* prompt
  block is registered under the chain key of everything before it, so a new
  request with the same prompt prefix adopts the existing physical blocks
  (refcount++) instead of recomputing and re-storing them.  Partial tail
  blocks are indexed too: a new request copies the shared content into a
  fresh block and prefills only from the point of divergence — block-granular
  **copy-on-write**.  Entries live exactly as long as the block does (they
  are dropped when the block is freed), so sharing happens between
  temporally-overlapping requests; a persistent prefix cache with its own
  eviction policy is future work.
* ``PagedPool`` — the serving-facing surface: per-slot **block tables**
  ([num_slots, max_blocks] int32, physical block per logical block) that the
  engine's paged steps consume, per-slot lengths, the pooled cache pytree
  (``engine.init_paged_cache``), and the admission/write/retirement
  bookkeeping the scheduler drives.  Physical block 0 is a reserved sentinel:
  dead table entries point at it, and idle batch rows' garbage decode writes
  land in it, so no allocation is ever aliased by accident.

This is the ONLY module that constructs block tables or touches the
allocator (grep-enforced by ``tests/test_compat.py``); kernels, dispatch,
and the engine consume tables they are handed.

Why the paper matters here: the online ``(m, d)`` normalizer update is
order- and layout-agnostic (§3.1 — any ⊕ reduction tree is exact), so a
flash kernel can walk an arbitrary page list in ONE pass with no extra
memory traffic.  A two-pass softmax would have to re-gather every page.

Determinism: ``slot_len`` must be a multiple of ``block_size``, so the
gathered page list has exactly the contiguous slot's sequence extent; the
masked online update is exact for invalid columns, making paged decode
bit-identical per request to the PR-2 slot-pool decode (pinned by
``tests/test_serving_paged.py``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serving import engine


class DoubleFreeError(RuntimeError):
    """A block was dereferenced more times than it was referenced."""


class BlockAllocator:
    """Fixed pool of physical KV blocks: free list + per-block refcounts.

    ``alloc`` hands out a block with refcount 1; ``incref`` records another
    holder (prefix sharing); ``decref`` drops one and returns the block to
    the free list only when the count hits zero.  Invariants (pinned by the
    property suite): every free-listed block has refcount 0, refcounts are
    never negative, and free + live always partitions the pool.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"need at least one block (got {num_blocks})")
        self.num_blocks = int(num_blocks)
        self._ref = np.zeros(self.num_blocks, np.int32)
        self._free: deque[int] = deque(range(self.num_blocks))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return int((self._ref > 0).sum())

    def refcount(self, bid: int) -> int:
        return int(self._ref[bid])

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        bid = self._free.popleft()
        self._ref[bid] = 1
        return bid

    def incref(self, bid: int) -> None:
        if self._ref[bid] <= 0:
            raise ValueError(f"incref of unallocated block {bid}")
        self._ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; True iff the block was returned to the free
        list (the caller must then invalidate anything indexing it)."""
        if self._ref[bid] <= 0:
            raise DoubleFreeError(f"block {bid} freed more times than "
                                  "referenced")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
            return True
        return False

    def check_invariants(self) -> None:
        free = list(self._free)
        assert len(free) == len(set(free)), "free list holds duplicates"
        assert all(self._ref[b] == 0 for b in free), \
            "free-listed block with a live refcount"
        assert (self._ref >= 0).all(), "negative refcount"
        assert len(free) + self.live_blocks == self.num_blocks, \
            "free + live does not partition the pool"


class PrefixIndex:
    """Hash-of-token-prefix → physical block, at block granularity.

    Chain keys are nested tuples ``key_i = (key_{i-1}, tokens_of_block_i)``
    (exact match — no hash collisions to reason about).  Full blocks map one
    key to one block; partial tails are kept per chain key as (tokens, block)
    candidates so a new request can adopt the longest common prefix of a
    divergence block.  ``drop_block`` is called by the pool the moment a
    block's refcount hits zero — an index entry therefore always points at
    live, immutable-prefix content.
    """

    def __init__(self):
        self._full: dict[tuple, int] = {}
        self._partial: dict[tuple, dict[tuple, int]] = {}
        self._by_block: dict[int, list] = {}

    def lookup(self, key: tuple) -> Optional[int]:
        return self._full.get(key)

    def lookup_partial(self, key: tuple, rem_tokens, cap: int):
        """Best divergence-block candidate under chain ``key``: the
        registered partial whose content shares the longest common prefix
        (≤ ``cap``) with ``rem_tokens``.  Returns (block, shared_len) or
        (None, 0)."""
        best, best_len = None, 0
        for toks, bid in self._partial.get(key, {}).items():
            n = 0
            for a, b in zip(toks, rem_tokens):
                if a != b or n >= cap:
                    break
                n += 1
            if n > best_len:
                best, best_len = bid, n
        return best, best_len

    def register(self, key: tuple, bid: int) -> None:
        if key in self._full:
            return                        # first writer wins; same content
        self._full[key] = bid
        self._by_block.setdefault(bid, []).append(("full", key))

    def register_partial(self, key: tuple, tokens: tuple, bid: int) -> None:
        bucket = self._partial.setdefault(key, {})
        if tokens in bucket:
            return
        bucket[tokens] = bid
        self._by_block.setdefault(bid, []).append(("partial", key, tokens))

    def drop_block(self, bid: int) -> None:
        for entry in self._by_block.pop(bid, ()):
            if entry[0] == "full":
                self._full.pop(entry[1], None)
            else:
                bucket = self._partial.get(entry[1])
                if bucket is not None:
                    bucket.pop(entry[2], None)
                    if not bucket:
                        self._partial.pop(entry[1], None)

    def __len__(self) -> int:
        return len(self._full) + sum(len(b) for b in self._partial.values())


@dataclass
class PagedSeq:
    """One admitted sequence's paged-cache state."""
    slot: int                       # batch row / block-table row
    prompt: np.ndarray
    blocks: list = field(default_factory=list)   # physical ids, logical order
    matched: int = 0                # prompt tokens adopted from the index


# The copy-on-write primitive, jitted once per pool shape (shapes recur, so
# jax.jit's signature cache is the right granularity).
_copy_block = jax.jit(engine.copy_paged_block, donate_argnums=(0,))


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagedPool:
    """Block-pooled KV cache with per-slot block tables — the paged
    counterpart of ``scheduler.SlotPool``.

    ``num_slots`` bounds the decode batch; ``slot_len`` (a multiple of
    ``block_size`` — the determinism contract above) bounds one sequence;
    ``num_blocks`` (default: enough for every slot at full length) is the
    real capacity lever — admission is gated on free *blocks*, so many short
    sequences can outnumber the worst-case-length bound that sized PR 2's
    pool.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, slot_len: int,
                 block_size: int, num_blocks: Optional[int] = None):
        if slot_len % block_size:
            raise ValueError(
                f"slot_len {slot_len} must be a multiple of block_size "
                f"{block_size} (bit-identity with the contiguous slot pool "
                "needs the gathered page list to match the slot extent)")
        self.cfg = cfg
        self.num_slots = num_slots
        self.slot_len = slot_len
        self.block_size = block_size
        self.max_blocks = slot_len // block_size
        usable = (num_blocks if num_blocks is not None
                  else num_slots * self.max_blocks)
        if usable < 1:
            raise ValueError(f"need at least one usable block (got {usable})")
        # +1: physical block 0 is the reserved sentinel (dead table entries,
        # idle-row garbage writes); the allocator never hands it out again
        self.alloc = BlockAllocator(usable + 1)
        self._sentinel = self.alloc.alloc()
        assert self._sentinel == 0
        self.index = PrefixIndex()
        self.caches = engine.init_paged_cache(cfg, usable + 1, block_size)
        self.lens = jnp.zeros((num_slots,), jnp.int32)
        self.tables = np.zeros((num_slots, self.max_blocks), np.int32)
        self._free_rows: deque[int] = deque(range(num_slots))
        self.seqs: dict[int, PagedSeq] = {}
        # stats for the smoke run / benchmarks
        self.blocks_shared = 0          # full blocks adopted via the index
        self.tokens_reused = 0          # prompt tokens whose prefill was skipped
        self.cow_copies = 0
        self.min_free_blocks = self.alloc.free_blocks

    # -- slot-pool-compatible surface ---------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free_rows)

    def fits(self, prompt_len: int) -> bool:
        """Whether a prompt of this length can EVER be admitted: its worst
        case block need (no sharing, prompt + first decode write) must fit
        the usable pool, or the FIFO head would wait forever."""
        return _ceil_div(prompt_len + 1, self.block_size) \
            <= self.alloc.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return self.alloc.free_blocks

    def device_tables(self, active_slots=None) -> jax.Array:
        """Block tables for a batched decode step.

        A batched decode writes position ``lens[slot]`` through EVERY row's
        table — including rows that are idle or mid-prefill, whose lens is 0.
        Those rows' real tables (installed at admission) must therefore be
        masked to the sentinel row here, or the garbage write lands at
        position 0 of a live block — the prefilling request's first block,
        possibly shared with another sequence.  Pass the decoding slots in
        ``active_slots``; None returns the raw tables (single-row prefill
        steps use ``device_row``)."""
        if active_slots is None:
            return jnp.asarray(self.tables)
        t = np.full_like(self.tables, self._sentinel)
        for s in active_slots:
            t[s] = self.tables[s]
        return jnp.asarray(t)

    def device_row(self, slot: int) -> jax.Array:
        return jnp.asarray(self.tables[slot:slot + 1])

    # -- admission ----------------------------------------------------------
    def admit(self, prompt: np.ndarray) -> Optional[PagedSeq]:
        """Match the prompt against the prefix index, then atomically claim a
        batch row plus the fresh blocks the unmatched part needs (prompt + the
        first decode write).  None when either is unavailable — the request
        stays queued.  At most ``len(prompt) - 1`` tokens are adopted: the
        final prompt position always prefills locally so there is a hidden
        state to sample the first token from."""
        if not self._free_rows:
            return None
        toks = [int(t) for t in prompt]
        n = len(toks)
        bs = self.block_size
        cap = n - 1
        shared: list[int] = []
        key: tuple = ()
        matched = 0
        while matched + bs <= cap:
            k2 = (key, tuple(toks[matched:matched + bs]))
            bid = self.index.lookup(k2)
            if bid is None:
                break
            shared.append(bid)
            key = k2
            matched += bs
        tail_src, tail_len = (None, 0)
        if matched < cap:
            tail_src, tail_len = self.index.lookup_partial(
                key, toks[matched:], cap - matched)
        total = _ceil_div(n + 1, bs)
        fresh_needed = total - len(shared)
        if self.alloc.free_blocks < fresh_needed:
            return None
        slot = self._free_rows.popleft()
        for bid in shared:
            self.alloc.incref(bid)
        blocks = list(shared)
        for _ in range(fresh_needed):
            bid = self.alloc.alloc()
            assert bid is not None          # gated above
            blocks.append(bid)
        if tail_src is not None:
            # copy-on-write at the divergence block: adopt the shared
            # content, then prefill only from where the prompts part ways
            self.caches = _copy_block(self.caches, tail_src,
                                      blocks[len(shared)])
            self.cow_copies += 1
            matched += tail_len
        self.blocks_shared += len(shared)
        self.tokens_reused += matched
        self.tables[slot, :len(blocks)] = blocks
        seq = PagedSeq(slot=slot, prompt=np.asarray(toks, np.int64),
                       blocks=blocks, matched=matched)
        self.seqs[slot] = seq
        self.min_free_blocks = min(self.min_free_blocks,
                                   self.alloc.free_blocks)
        return seq

    def finalize_prefill(self, seq: PagedSeq) -> None:
        """Register the finished prompt's block chain so later arrivals with
        the same prefix share it.  Full blocks key the exact-match chain;
        a partial tail registers as a divergence-block candidate."""
        toks = [int(t) for t in seq.prompt]
        bs = self.block_size
        key: tuple = ()
        n_full = len(toks) // bs
        for i in range(n_full):
            tup = tuple(toks[i * bs:(i + 1) * bs])
            key_i = (key, tup)
            self.index.register(key_i, seq.blocks[i])
            if i == n_full - 1 and len(toks) == n_full * bs:
                # block-aligned prompt: the cap rule (≥ 1 token must prefill
                # locally) stops an identical prompt one token short of this
                # block, so register it as a divergence candidate too — the
                # adopter CoW-copies it and prefills only the final token
                self.index.register_partial(key, tup, seq.blocks[i])
            key = key_i
        rem = tuple(toks[n_full * bs:])
        if rem:
            self.index.register_partial(key, rem, seq.blocks[n_full])

    # -- decode-time block upkeep -------------------------------------------
    def prepare_write(self, slot: int, pos: int) -> bool:
        """Make position ``pos`` of ``slot`` writable before the decode step:
        allocate the next block when the write crosses a boundary, and
        copy-on-write a block some other sequence still references.  False
        means the pool is out of blocks — the scheduler evicts the sequence,
        returning its non-shared blocks in the same tick."""
        seq = self.seqs[slot]
        bi = pos // self.block_size
        assert bi <= len(seq.blocks), (bi, len(seq.blocks))
        if bi < len(seq.blocks):
            bid = seq.blocks[bi]
            if self.alloc.refcount(bid) > 1:
                fresh = self.alloc.alloc()
                if fresh is None:
                    return False
                self.caches = _copy_block(self.caches, bid, fresh)
                self.alloc.decref(bid)      # refcount ≥ 2: never frees here
                seq.blocks[bi] = fresh
                self.tables[slot, bi] = fresh
                self.cow_copies += 1
                self.min_free_blocks = min(self.min_free_blocks,
                                           self.alloc.free_blocks)
            return True
        fresh = self.alloc.alloc()
        if fresh is None:
            return False
        seq.blocks.append(fresh)
        self.tables[slot, len(seq.blocks) - 1] = fresh
        self.min_free_blocks = min(self.min_free_blocks,
                                   self.alloc.free_blocks)
        return True

    # -- retirement ---------------------------------------------------------
    def release(self, slot: int) -> None:
        """Retire ``slot``: decref every block it holds (freeing the
        non-shared ones — a block another live sequence references survives)
        and return the batch row.  Runs host-side, so freed blocks are
        admissible in the same scheduler tick."""
        seq = self.seqs.pop(slot, None)
        if seq is None:
            return
        for bid in seq.blocks:
            if self.alloc.decref(bid):
                self.index.drop_block(bid)
        self.tables[slot, :] = self._sentinel
        self.lens = self.lens.at[slot].set(0)
        self._free_rows.append(slot)

    def stats(self) -> dict:
        return {
            "block_size": self.block_size,
            "num_blocks": self.alloc.num_blocks - 1,      # minus sentinel
            "free_blocks": self.alloc.free_blocks,
            "min_free_blocks": self.min_free_blocks,
            "blocks_shared": self.blocks_shared,
            "tokens_reused": self.tokens_reused,
            "cow_copies": self.cow_copies,
        }
