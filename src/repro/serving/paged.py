"""Paged KV-cache serving: block allocator, prefix sharing, block tables.

PR 2's slot pool reserves one contiguous max-length KV region per sequence,
so batch size is capped by worst-case length and identical prompt prefixes
are stored once *per request*.  This module replaces the region with a pool
of fixed-size KV **blocks**:

* ``BlockAllocator`` — a fixed pool of physical blocks with a free list and
  per-block refcounts.  Allocation is O(1); freeing is refcount-aware, so a
  block shared by several sequences survives until its last reference drops.
  Double-frees raise instead of corrupting the pool.
* ``PrefixIndex`` — hash-of-token-prefix → block chain.  Every *full* prompt
  block is registered under the chain key of everything before it, so a new
  request with the same prompt prefix adopts the existing physical blocks
  (refcount++) instead of recomputing and re-storing them.  Partial tail
  blocks are indexed too: a new request copies the shared content into a
  fresh block and prefills only from the point of divergence — block-granular
  **copy-on-write**.  The index is **persistent**: when the last sequence
  holding an indexed block retires, the block's reference transfers to the
  pool's LRU prefix cache instead of the free list, so a later request with
  the same prompt prefix skips its prefill even though no live sequence
  overlaps it.  Cold cached blocks are reclaimed (LRU-first) into the free
  list whenever admission, decode growth, or swap-in runs short — always
  BEFORE the scheduler resorts to preempting or evicting live work.
* **Preempt-and-swap** — ``PagedPool.swap_out`` suspends a sequence to a
  host-side block store keyed by request id: blocks it owns exclusively are
  copied out and freed (that is the memory preemption reclaims); blocks
  shared with another live sequence or with the prefix cache are *never*
  copied or freed — the suspended sequence simply keeps its reference, so
  the content stays resident at zero extra cost.  ``swap_in`` reverses it:
  fresh blocks are allocated for the copied-out content (bit-exact host
  round-trip), kept shared blocks are reused as-is, and the rebuilt block
  table lets decode resume at the exact position it stopped — no re-prefill,
  and (because sampling is keyed by (request id, token index)) a token
  stream bit-identical to the never-preempted run.
* ``PagedPool`` — the serving-facing surface: per-slot **block tables**
  ([num_slots, max_blocks] int32, physical block per logical block) that the
  engine's paged steps consume, per-slot lengths, the pooled cache pytree
  (``engine.init_paged_cache``), and the admission/write/retirement
  bookkeeping the scheduler drives.  Physical block 0 is a reserved sentinel:
  dead table entries point at it, and idle batch rows' garbage decode writes
  land in it, so no allocation is ever aliased by accident.

This is the ONLY module that constructs block tables or touches the
allocator (grep-enforced by ``tests/test_compat.py``); kernels, dispatch,
and the engine consume tables they are handed.

Why the paper matters here: the online ``(m, d)`` normalizer update is
order- and layout-agnostic (§3.1 — any ⊕ reduction tree is exact), so a
flash kernel can walk an arbitrary page list in ONE pass with no extra
memory traffic.  A two-pass softmax would have to re-gather every page.

Determinism: ``slot_len`` must be a multiple of ``block_size``, so the
gathered page list has exactly the contiguous slot's sequence extent; the
masked online update is exact for invalid columns, making paged decode
bit-identical per request to the PR-2 slot-pool decode (pinned by
``tests/test_serving_paged.py``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import functools

from repro import compat
from repro.configs.base import ModelConfig
from repro.serving import cache_family, engine


class DoubleFreeError(RuntimeError):
    """A block was dereferenced more times than it was referenced."""


class BlockAllocator:
    """Fixed pool of physical KV blocks: free list + per-block refcounts.

    ``alloc`` hands out a block with refcount 1; ``incref`` records another
    holder (prefix sharing); ``decref`` drops one and returns the block to
    the free list only when the count hits zero.  Invariants (pinned by the
    property suite): every free-listed block has refcount 0, refcounts are
    never negative, and free + live always partitions the pool.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"need at least one block (got {num_blocks})")
        self.num_blocks = int(num_blocks)
        self._ref = np.zeros(self.num_blocks, np.int32)
        self._free: deque[int] = deque(range(self.num_blocks))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return int((self._ref > 0).sum())

    def refcount(self, bid: int) -> int:
        return int(self._ref[bid])

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        bid = self._free.popleft()
        self._ref[bid] = 1
        return bid

    def incref(self, bid: int) -> None:
        if self._ref[bid] <= 0:
            raise ValueError(f"incref of unallocated block {bid}")
        self._ref[bid] += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; True iff the block was returned to the free
        list (the caller must then invalidate anything indexing it)."""
        if self._ref[bid] <= 0:
            raise DoubleFreeError(f"block {bid} freed more times than "
                                  "referenced")
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
            return True
        return False

    def check_invariants(self) -> None:
        free = list(self._free)
        assert len(free) == len(set(free)), "free list holds duplicates"
        assert all(self._ref[b] == 0 for b in free), \
            "free-listed block with a live refcount"
        assert (self._ref >= 0).all(), "negative refcount"
        assert len(free) + self.live_blocks == self.num_blocks, \
            "free + live does not partition the pool"


class PrefixIndex:
    """Hash-of-token-prefix → physical block, at block granularity.

    Chain keys are nested tuples ``key_i = (key_{i-1}, tokens_of_block_i)``
    (exact match — no hash collisions to reason about).  Full blocks map one
    key to one block; partial tails are kept per chain key as (tokens, block)
    candidates so a new request can adopt the longest common prefix of a
    divergence block.  ``drop_block`` is called by the pool the moment a
    block leaves it (freed, reclaimed from the prefix cache, or swapped out
    to the host) — an index entry therefore always points at live,
    immutable-prefix content.  Entries are NOT dropped when the last
    *sequence* holding a block retires: the pool parks such blocks in its
    LRU prefix cache and the entries outlive the sequence.
    """

    def __init__(self):
        self._full: dict[tuple, int] = {}
        self._partial: dict[tuple, dict[tuple, int]] = {}
        self._by_block: dict[int, list] = {}

    @staticmethod
    def chain_keys(tokens, block_size: int) -> list:
        """The nested chain keys of every full block of ``tokens`` —
        ``key_i = (key_{i-1}, tokens_of_block_i)``, the exact values
        admission matches under.  Exposed so the replica router can hash a
        prompt's block chain with the SAME function the index uses: a
        router affinity entry keyed on ``chain_keys(prompt)[-1]`` refers to
        precisely the blocks a later ``admit`` of that prompt would adopt."""
        toks = tuple(int(t) for t in tokens)
        keys: list = []
        key: tuple = ()
        for i in range(len(toks) // block_size):
            key = (key, toks[i * block_size:(i + 1) * block_size])
            keys.append(key)
        return keys

    def lookup(self, key: tuple) -> Optional[int]:
        return self._full.get(key)

    def lookup_partial(self, key: tuple, rem_tokens, cap: int):
        """Best divergence-block candidate under chain ``key``: the
        registered partial whose content shares the longest common prefix
        (≤ ``cap``) with ``rem_tokens``.  Returns (block, shared_len) or
        (None, 0)."""
        best, best_len = None, 0
        for toks, bid in self._partial.get(key, {}).items():
            n = 0
            for a, b in zip(toks, rem_tokens):
                if a != b or n >= cap:
                    break
                n += 1
            if n > best_len:
                best, best_len = bid, n
        return best, best_len

    def register(self, key: tuple, bid: int) -> None:
        if key in self._full:
            return                        # first writer wins; same content
        self._full[key] = bid
        self._by_block.setdefault(bid, []).append(("full", key))

    def register_partial(self, key: tuple, tokens: tuple, bid: int) -> None:
        bucket = self._partial.setdefault(key, {})
        if tokens in bucket:
            return
        bucket[tokens] = bid
        self._by_block.setdefault(bid, []).append(("partial", key, tokens))

    def has_block(self, bid: int) -> bool:
        """Whether any full/partial entry points at ``bid`` — the pool's
        release path asks this to decide cache-park vs free."""
        return bid in self._by_block

    def drop_block(self, bid: int) -> None:
        for entry in self._by_block.pop(bid, ()):
            if entry[0] == "full":
                self._full.pop(entry[1], None)
            else:
                bucket = self._partial.get(entry[1])
                if bucket is not None:
                    bucket.pop(entry[2], None)
                    if not bucket:
                        self._partial.pop(entry[1], None)

    def __len__(self) -> int:
        return len(self._full) + sum(len(b) for b in self._partial.values())


@dataclass
class PagedSeq:
    """One admitted sequence's paged-cache state."""
    slot: int                       # batch row / block-table row
    prompt: np.ndarray
    blocks: list = field(default_factory=list)   # physical ids, logical order
    matched: int = 0                # prompt tokens adopted from the index


@dataclass
class SwappedSeq:
    """A preempted sequence's host-side record (``PagedPool.swapped``).

    ``entries`` mirrors the block list in logical order; each element is
    ``("shared", bid)`` — the sequence kept its reference on a block another
    holder (live sequence or the prefix cache) also references, content
    still resident — or ``("host", content)`` — an exclusively-owned block
    whose cache content was copied to the host and whose physical block was
    freed.  ``length`` is the valid cache extent at suspension, the offset
    decode resumes from after ``swap_in``.  ``staged`` holds device copies
    of host entries prepared ahead of time by ``prefetch_swap_in`` (entry
    index → device pytree): the scheduler stages them while a decode step
    is still in flight, and ``swap_in`` consumes them instead of paying the
    host→device transfer on the resume's critical path."""
    prompt: np.ndarray
    matched: int
    length: int
    entries: list
    staged: dict = field(default_factory=dict)


# The copy-on-write and swap-in-restore primitives, jitted once per pool
# shape (shapes recur, so jax.jit's signature cache is the right
# granularity).  They address blocks as ``leaf[:, bid]`` — valid for EVERY
# cache family because the pool-layout contract puts the physical-block axis
# at leaf position 1 (see ``serving.cache_family``).
_copy_block = jax.jit(engine.copy_paged_block, donate_argnums=(0,))
_write_block = jax.jit(engine.write_paged_block, donate_argnums=(0,))
_install_encdec = jax.jit(engine.install_encdec_row, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _install_state(cfg: ModelConfig):
    """The fixed-state prefill install: scatter a batch-1 contiguous cache
    into one pool row.  Jitted per config (the block pattern is static)."""
    return jax.jit(functools.partial(engine.scatter_state_rows, cfg),
                   donate_argnums=(0,))


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagedPool:
    """Block-pooled KV cache with per-slot block tables — the paged
    counterpart of ``scheduler.SlotPool``.

    Parameters
    ----------
    cfg:
        Model config (must pass ``engine.paged_supported``).
    num_slots:
        Batch rows — the bound on concurrently *decoding* sequences.
    slot_len:
        Per-sequence cache bound, a multiple of ``block_size`` (the
        determinism contract above).
    block_size:
        Tokens per physical KV block (= the paged kernels' KV tile).
    num_blocks:
        Usable physical blocks (default: enough for every slot at full
        length).  The real capacity lever — admission is gated on free
        *blocks*, so many short sequences can outnumber the
        worst-case-length bound that sized PR 2's pool.
    persistent_prefix:
        Keep indexed prompt blocks resident after their last sequence
        retires (the LRU prefix cache, default on).  Cached blocks are
        reclaimed to the free list — coldest first — whenever the pool runs
        short, so persistence never costs an admission; ``False`` restores
        the PR-4 entries-die-with-the-block behaviour.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, slot_len: int,
                 block_size: int, num_blocks: Optional[int] = None,
                 persistent_prefix: bool = True):
        self.cfg = cfg
        self.family = cache_family.resolve(cfg)
        # dense: slot_len must be a multiple of block_size (bit-identity with
        # the contiguous slot pool needs the gathered page list to match the
        # slot extent); enc-dec: the encoder window must block-align
        self.family.validate_geometry(slot_len, block_size)
        self.num_slots = num_slots
        self.slot_len = slot_len
        self.block_size = block_size
        self.max_blocks = self.family.max_blocks(slot_len, block_size)
        usable = (num_blocks if num_blocks is not None
                  else num_slots * self.max_blocks)
        if usable < 1:
            raise ValueError(f"need at least one usable block (got {usable})")
        # +1: physical block 0 is the reserved sentinel (dead table entries,
        # idle batch rows' garbage reads/writes); the allocator never hands
        # it out again
        self.alloc = BlockAllocator(usable + 1)
        self._sentinel = self.alloc.alloc()
        assert self._sentinel == 0
        self.index = PrefixIndex()
        self.caches = engine.init_paged_cache(cfg, usable + 1, block_size,
                                              slot_len)
        self.lens = jnp.zeros((num_slots,), jnp.int32)
        self.tables = np.zeros((num_slots, self.max_blocks), np.int32)
        self._free_rows: deque[int] = deque(range(num_slots))
        self.seqs: dict[int, PagedSeq] = {}
        self.persistent_prefix = persistent_prefix
        # LRU prefix cache: bid → None, insertion order = cold→hot.  Each
        # member holds exactly one allocator reference (transferred from the
        # last sequence that held the block), so free+live still partitions
        # the pool and a cached block can never be handed out as fresh.
        self._cached: dict[int, None] = {}
        # host-side store of preempted sequences, keyed by request id
        self.swapped: dict[int, SwappedSeq] = {}
        # stats for the smoke run / benchmarks
        self.blocks_shared = 0          # full blocks adopted via the index
        self.tokens_reused = 0          # prompt tokens whose prefill was skipped
        self.cow_copies = 0
        self.prefix_cache_hits = 0      # cache-held blocks revived by admission
        self.reclaimed_blocks = 0       # cold cached blocks fed to the free list
        self.swapped_blocks_out = 0     # exclusive blocks copied to the host
        self.swapped_blocks_in = 0      # host blocks restored by swap_in
        self.swapped_bytes_out = 0      # payload bytes of those copies
        self.swapped_bytes_in = 0
        self.swap_prefetched_blocks = 0  # host blocks staged ahead of swap_in
        self.min_free_blocks = self.alloc.free_blocks

    # -- slot-pool-compatible surface ---------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free_rows)

    def fits(self, prompt_len: int) -> bool:
        """Whether a prompt of this length can EVER be admitted: its worst
        case block need (no sharing; dense: prompt + first decode write,
        state: one row, enc-dec: encoder blocks + self row) must fit the
        usable pool, or the FIFO head would wait forever."""
        return self.family.blocks_for_prompt(prompt_len, self.block_size) \
            <= self.alloc.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return self.alloc.free_blocks

    @property
    def cached_blocks(self) -> int:
        """Blocks parked in the persistent prefix cache (reclaimable)."""
        return len(self._cached)

    # -- persistent prefix cache (LRU) --------------------------------------
    def _touch(self, bid: int) -> None:
        """Mark a cache-resident block most-recently-used."""
        if bid in self._cached:
            self._cached.pop(bid)
            self._cached[bid] = None

    def _reclaim_until(self, free_target: int, exclude=()) -> None:
        """Feed cold cached blocks (LRU-first) to the free list until
        ``free_target`` blocks are free or the cache is spent.  ``exclude``
        protects blocks an in-progress admission is about to adopt.  This is
        the pressure valve that runs BEFORE the scheduler preempts or evicts
        live work."""
        exclude = set(exclude)
        for bid in list(self._cached):
            if self.alloc.free_blocks >= free_target:
                break
            if bid in exclude:
                continue
            if self.alloc.refcount(bid) > 1:
                # a live sequence also holds this block: releasing the
                # cache's reference cannot yield a free block, and dropping
                # the index entries would only forfeit its future sharing —
                # skip it (it stays cached and matchable)
                continue
            del self._cached[bid]
            # the content is leaving the pool's custody: matching against it
            # would hand out a block whose bits may be recycled — drop the
            # index entries before releasing the cache's reference
            self.index.drop_block(bid)
            if self.alloc.decref(bid):      # a live adopter may still hold it
                self.reclaimed_blocks += 1

    def device_tables(self, active_slots=None) -> jax.Array:
        """Block tables for a batched decode step.

        A batched decode writes position ``lens[slot]`` through EVERY row's
        table — including rows that are idle or mid-prefill, whose lens is 0.
        Those rows' real tables (installed at admission) must therefore be
        masked to the sentinel row here, or the garbage write lands at
        position 0 of a live block — the prefilling request's first block,
        possibly shared with another sequence.  Pass the decoding slots in
        ``active_slots``; None returns the raw tables (single-row prefill
        steps use ``device_row``)."""
        if active_slots is None:
            return jnp.asarray(self.tables)
        t = np.full_like(self.tables, self._sentinel)
        for s in active_slots:
            t[s] = self.tables[s]
        return jnp.asarray(t)

    def device_row(self, slot: int) -> jax.Array:
        return jnp.asarray(self.tables[slot:slot + 1])

    # -- admission ----------------------------------------------------------
    def admit(self, prompt: np.ndarray) -> Optional[PagedSeq]:
        """Match the prompt against the prefix index, then atomically claim a
        batch row plus the fresh blocks the unmatched part needs (prompt + the
        first decode write).  None when either is unavailable — the request
        stays queued.  At most ``len(prompt) - 1`` tokens are adopted: the
        final prompt position always prefills locally so there is a hidden
        state to sample the first token from.

        Non-token families route to their own admission: fixed-state claims
        one unshared row block; enc-dec matches the WHOLE audio against the
        index (the encoder is bidirectional — a frame-prefix match would
        adopt K/V computed from a different full audio) and claims a self
        row block."""
        if not self._free_rows:
            return None
        if self.family.kind == "state":
            return self._admit_state(prompt)
        if self.family.kind == "encdec":
            return self._admit_encdec(prompt)
        toks = [int(t) for t in prompt]
        n = len(toks)
        bs = self.block_size
        cap = n - 1
        shared: list[int] = []
        key: tuple = ()
        matched = 0
        for k2 in PrefixIndex.chain_keys(toks, bs):
            if matched + bs > cap:
                break
            bid = self.index.lookup(k2)
            if bid is None:
                break
            shared.append(bid)
            key = k2
            matched += bs
        tail_src, tail_len = (None, 0)
        if matched < cap:
            tail_src, tail_len = self.index.lookup_partial(
                key, toks[matched:], cap - matched)
        total = _ceil_div(n + 1, bs)
        fresh_needed = total - len(shared)
        if self.alloc.free_blocks < fresh_needed:
            # short on blocks: reclaim cold cached prefixes first, protecting
            # the blocks this very admission is about to adopt
            protect = set(shared)
            if tail_src is not None:
                protect.add(tail_src)
            self._reclaim_until(fresh_needed, exclude=protect)
        if self.alloc.free_blocks < fresh_needed:
            return None                 # caller may now preempt live work
        slot = self._free_rows.popleft()
        for bid in shared:
            if self.alloc.refcount(bid) == 1 and bid in self._cached:
                self.prefix_cache_hits += 1     # revived: no live seq held it
            self.alloc.incref(bid)
            self._touch(bid)
        if tail_src is not None:
            self._touch(tail_src)
        blocks = list(shared)
        for _ in range(fresh_needed):
            bid = self.alloc.alloc()
            assert bid is not None          # gated above
            blocks.append(bid)
        if tail_src is not None:
            # copy-on-write at the divergence block: adopt the shared
            # content, then prefill only from where the prompts part ways
            self.caches = _copy_block(self.caches, tail_src,
                                      blocks[len(shared)])
            self.cow_copies += 1
            matched += tail_len
        self.blocks_shared += len(shared)
        self.tokens_reused += matched
        self.tables[slot, :len(blocks)] = blocks
        seq = PagedSeq(slot=slot, prompt=np.asarray(toks, np.int64),
                       blocks=blocks, matched=matched)
        self.seqs[slot] = seq
        self.min_free_blocks = min(self.min_free_blocks,
                                   self.alloc.free_blocks)
        return seq

    @staticmethod
    def _audio_key(toks, block_size: int) -> tuple:
        """The whole-audio identity an enc-dec prompt shares under: the full
        chain key over every frame block — it encodes the entire frame
        sequence, so two prompts share it iff they are the same audio."""
        return PrefixIndex.chain_keys(toks, block_size)[-1]

    def _admit_state(self, prompt) -> Optional[PagedSeq]:
        """Fixed-state admission: one fresh block (the whole state row), no
        sharing — state mutates in place every decode step."""
        if self.alloc.free_blocks < 1:
            self._reclaim_until(1)
        bid = self.alloc.alloc()
        if bid is None:
            return None
        slot = self._free_rows.popleft()
        self.tables[slot, 0] = bid
        seq = PagedSeq(slot=slot,
                       prompt=np.asarray([int(t) for t in prompt], np.int64),
                       blocks=[bid], matched=0)
        self.seqs[slot] = seq
        self.min_free_blocks = min(self.min_free_blocks,
                                   self.alloc.free_blocks)
        return seq

    def _admit_encdec(self, prompt) -> Optional[PagedSeq]:
        """Enc-dec admission: adopt the whole audio's encoder blocks on an
        exact match (refcount++, zero encoder recompute), else claim fresh
        ones; always claim one self-K/V row block."""
        toks = [int(t) for t in prompt]
        bs = self.block_size
        nc = self.max_blocks - 1
        audio = self._audio_key(toks, bs)
        shared: list[int] = []
        for i in range(nc):
            bid = self.index.lookup((audio, i))
            if bid is None:
                shared = []          # all-or-nothing by construction
                break
            shared.append(bid)
        fresh_needed = (nc - len(shared)) + 1          # + the self row
        if self.alloc.free_blocks < fresh_needed:
            self._reclaim_until(fresh_needed, exclude=shared)
        if self.alloc.free_blocks < fresh_needed:
            return None
        slot = self._free_rows.popleft()
        for bid in shared:
            if self.alloc.refcount(bid) == 1 and bid in self._cached:
                self.prefix_cache_hits += 1
            self.alloc.incref(bid)
            self._touch(bid)
        blocks = list(shared)
        for _ in range(fresh_needed):
            bid = self.alloc.alloc()
            assert bid is not None          # gated above
            blocks.append(bid)
        matched = len(toks) if shared else 0
        self.blocks_shared += len(shared)
        self.tokens_reused += matched
        self.tables[slot, :len(blocks)] = blocks
        seq = PagedSeq(slot=slot, prompt=np.asarray(toks, np.int64),
                       blocks=blocks, matched=matched)
        self.seqs[slot] = seq
        self.min_free_blocks = min(self.min_free_blocks,
                                   self.alloc.free_blocks)
        return seq

    # -- prefill install (non-token families) -------------------------------
    def install_state(self, seq: PagedSeq, caches) -> None:
        """Scatter a freshly-prefilled batch-1 contiguous cache into the
        sequence's state row block."""
        rows = jnp.asarray([seq.blocks[0]], jnp.int32)
        self.caches = _install_state(self.cfg)(self.caches, caches, rows)

    def install_encdec(self, seq: PagedSeq, caches) -> None:
        """Scatter a freshly-prefilled batch-1 decoder cache into the pool:
        the self row always; the cross blocks only when this sequence
        computed them (a prefix hit adopted identical shared blocks, which
        must not be rewritten — their bids are routed out of range so the
        jitted scatter drops them)."""
        nc = self.max_blocks - 1
        if seq.matched:
            cross_bids = np.full(nc, self.alloc.num_blocks, np.int32)
        else:
            cross_bids = np.asarray(seq.blocks[:nc], np.int32)
        self.caches = _install_encdec(
            self.caches, caches, jnp.asarray(cross_bids),
            jnp.asarray(seq.blocks[nc], jnp.int32))

    def finalize_prefill(self, seq: PagedSeq) -> None:
        """Register the finished prompt's block chain so later arrivals with
        the same prefix share it.  Full blocks key the exact-match chain;
        a partial tail registers as a divergence-block candidate.  Enc-dec
        registers the cross blocks under the whole-audio key; non-shareable
        families (fixed-state mutates in place, dense_int8 keeps scales as
        per-sequence write-time artifacts) register nothing — their index
        stays empty, so ``admit`` never matches and ``release`` frees
        blocks outright instead of parking them in the prefix LRU."""
        if not self.family.shareable:
            return
        if self.family.kind == "encdec":
            toks = [int(t) for t in seq.prompt]
            audio = self._audio_key(toks, self.block_size)
            for i, bid in enumerate(seq.blocks[:self.max_blocks - 1]):
                self.index.register((audio, i), bid)
            return
        toks = [int(t) for t in seq.prompt]
        bs = self.block_size
        key: tuple = ()
        n_full = len(toks) // bs
        for i in range(n_full):
            tup = tuple(toks[i * bs:(i + 1) * bs])
            key_i = (key, tup)
            self.index.register(key_i, seq.blocks[i])
            if i == n_full - 1 and len(toks) == n_full * bs:
                # block-aligned prompt: the cap rule (≥ 1 token must prefill
                # locally) stops an identical prompt one token short of this
                # block, so register it as a divergence candidate too — the
                # adopter CoW-copies it and prefills only the final token
                self.index.register_partial(key, tup, seq.blocks[i])
            key = key_i
        rem = tuple(toks[n_full * bs:])
        if rem:
            self.index.register_partial(key, rem, seq.blocks[n_full])

    def probe(self, prompt) -> int:
        """Read-only prefix probe: how many prompt tokens an ``admit`` of
        ``prompt`` would adopt from the index RIGHT NOW (full-block chain
        matches plus the best partial-tail candidate), with no refcount,
        cache-LRU, or stats side effects.  The replica router ranks engines
        on this to route a request where its prefix already lives;
        ``admit`` stays the only path that claims blocks."""
        toks = [int(t) for t in prompt]
        if self.family.kind == "state":
            return 0
        if self.family.kind == "encdec":
            audio = self._audio_key(toks, self.block_size)
            return len(toks) if self.index.lookup((audio, 0)) is not None else 0
        cap = len(toks) - 1
        bs = self.block_size
        matched = 0
        key: tuple = ()
        for k2 in PrefixIndex.chain_keys(toks, bs):
            if matched + bs > cap or self.index.lookup(k2) is None:
                break
            key = k2
            matched += bs
        if matched < cap:
            _, tail_len = self.index.lookup_partial(key, toks[matched:],
                                                    cap - matched)
            matched += tail_len
        return matched

    # -- decode-time block upkeep -------------------------------------------
    def _alloc_reclaiming(self, exclude=()) -> Optional[int]:
        """``alloc`` with the cache pressure valve: an empty free list first
        reclaims the coldest cached prefix blocks (``exclude`` protects the
        calling sequence's own blocks), and only then reports exhaustion."""
        bid = self.alloc.alloc()
        if bid is None:
            self._reclaim_until(1, exclude=exclude)
            bid = self.alloc.alloc()
        return bid

    def prepare_write(self, slot: int, pos: int) -> bool:
        """Make position ``pos`` of ``slot`` writable before the decode step:
        allocate the next block when the write crosses a boundary, and
        copy-on-write a block some other sequence still references.  False
        means the pool is out of blocks even after reclaiming the prefix
        cache — the scheduler preempts a lower-priority sequence or evicts
        this one, returning its non-shared blocks in the same tick."""
        if self.family.kind != "token":
            return True    # state rewrites in place; enc-dec self rows are
        seq = self.seqs[slot]  # pre-sized to slot_len and cross is immutable
        bi = pos // self.block_size
        assert bi <= len(seq.blocks), (bi, len(seq.blocks))
        if bi < len(seq.blocks):
            bid = seq.blocks[bi]
            if self.alloc.refcount(bid) > 1:
                fresh = self._alloc_reclaiming(exclude=seq.blocks)
                if fresh is None:
                    return False
                self.caches = _copy_block(self.caches, bid, fresh)
                if self.alloc.decref(bid):  # refcount ≥ 2 here: frees only if
                    self.index.drop_block(bid)   # a reclaim raced the holder
                seq.blocks[bi] = fresh
                self.tables[slot, bi] = fresh
                self.cow_copies += 1
                self.min_free_blocks = min(self.min_free_blocks,
                                           self.alloc.free_blocks)
            return True
        fresh = self._alloc_reclaiming(exclude=seq.blocks)
        if fresh is None:
            return False
        seq.blocks.append(fresh)
        self.tables[slot, len(seq.blocks) - 1] = fresh
        self.min_free_blocks = min(self.min_free_blocks,
                                   self.alloc.free_blocks)
        return True

    # -- retirement ---------------------------------------------------------
    def release(self, slot: int) -> None:
        """Retire ``slot``: drop the sequence's reference on every block it
        holds and return the batch row.  A block another live sequence
        references survives untouched; a block whose LAST reference this was
        either parks in the persistent prefix cache (if the index still maps
        prompt content to it — the entry now outlives the sequence) or
        returns to the free list.  Runs host-side, so freed blocks are
        admissible in the same scheduler tick."""
        seq = self.seqs.pop(slot, None)
        if seq is None:
            return
        for bid in seq.blocks:
            if (self.persistent_prefix and self.alloc.refcount(bid) == 1
                    and self.index.has_block(bid)):
                # transfer the sequence's reference to the cache: the block
                # stays live (and matchable) without any owner sequence
                self._cached[bid] = None
                continue
            if self.alloc.decref(bid):
                self.index.drop_block(bid)
        self.tables[slot, :] = self._sentinel
        self.lens = self.lens.at[slot].set(0)
        self._free_rows.append(slot)

    # -- preempt-and-swap ---------------------------------------------------
    def swap_out(self, slot: int, rid: int) -> SwappedSeq:
        """Suspend ``slot``'s sequence to the host-side store under ``rid``.

        Refcount-aware: a block shared with another live sequence or with
        the prefix cache keeps this sequence's reference — its content stays
        resident in the pool and is NEVER copied out (shared prefixes cost a
        preemption nothing).  An exclusively-held block is copied to the
        host (bit-exact) and freed — that is the memory the preemption
        reclaims.  The batch row, table row, and length are released like a
        retirement; ``swap_in`` rebuilds them."""
        seq = self.seqs.pop(slot)
        entries: list = []
        for bid in seq.blocks:
            if self.alloc.refcount(bid) > 1:
                entries.append(("shared", bid))
                continue
            content = compat.tree_map(lambda x: np.asarray(x[:, bid]),
                                      self.caches)
            if self.alloc.decref(bid):
                self.index.drop_block(bid)
            entries.append(("host", content))
            self.swapped_blocks_out += 1
            self.swapped_bytes_out += sum(
                l.nbytes for l in compat.tree_leaves(content))
        rec = SwappedSeq(prompt=seq.prompt, matched=seq.matched,
                         length=int(np.asarray(self.lens)[slot]),
                         entries=entries)
        self.swapped[rid] = rec
        self.tables[slot, :] = self._sentinel
        self.lens = self.lens.at[slot].set(0)
        self._free_rows.append(slot)
        return rec

    def swap_in(self, rid: int) -> Optional[PagedSeq]:
        """Resume the sequence ``swap_out`` stored under ``rid``: claim a
        batch row, restore every host-copied block into a freshly-allocated
        physical block (reclaiming cold cached blocks if the free list runs
        short), reattach the kept shared blocks, and rebuild the block table
        with the pre-preemption length — decode continues at the exact
        position it stopped, no re-prefill.  None when a row or the blocks
        are unavailable; the record stays stored for a later attempt."""
        rec = self.swapped[rid]
        if not self._free_rows:
            return None
        kept = {e[1] for e in rec.entries if e[0] == "shared"}
        need = sum(1 for e in rec.entries if e[0] == "host")
        if self.alloc.free_blocks < need:
            self._reclaim_until(need, exclude=kept)
        if self.alloc.free_blocks < need:
            return None
        del self.swapped[rid]
        slot = self._free_rows.popleft()
        blocks: list = []
        for i, (kind, payload) in enumerate(rec.entries):
            if kind == "shared":
                blocks.append(payload)
                self._touch(payload)
                continue
            bid = self.alloc.alloc()
            assert bid is not None          # gated above
            # a prefetch-staged device copy (bit-identical content, already
            # transferred while an earlier decode step ran) beats paying the
            # host→device move here on the resume's critical path
            self.caches = _write_block(self.caches,
                                       rec.staged.get(i, payload), bid)
            blocks.append(bid)
            self.swapped_blocks_in += 1
            self.swapped_bytes_in += sum(
                l.nbytes for l in compat.tree_leaves(payload))
        self.tables[slot, :] = self._sentinel
        self.tables[slot, :len(blocks)] = blocks
        self.lens = self.lens.at[slot].set(rec.length)
        seq = PagedSeq(slot=slot, prompt=rec.prompt, blocks=blocks,
                       matched=rec.matched)
        self.seqs[slot] = seq
        self.min_free_blocks = min(self.min_free_blocks,
                                   self.alloc.free_blocks)
        return seq

    def prefetch_swap_in(self, rid: int) -> int:
        """Stage the suspended sequence's host-side blocks onto the device
        ahead of its eventual ``swap_in``.  ``jnp.asarray`` dispatches the
        host→device transfers asynchronously, so calling this right after a
        decode step is issued overlaps the copies with that step's compute;
        the staged arrays are bit-identical to the host content and
        ``swap_in`` consumes them instead of re-transferring.  Idempotent —
        already-staged entries are skipped.  Returns blocks newly staged."""
        rec = self.swapped.get(rid)
        if rec is None:
            return 0
        staged = 0
        for i, (kind, payload) in enumerate(rec.entries):
            if kind != "host" or i in rec.staged:
                continue
            rec.staged[i] = compat.tree_map(jnp.asarray, payload)
            staged += 1
        self.swap_prefetched_blocks += staged
        return staged

    def stats(self) -> dict:
        return {
            "block_size": self.block_size,
            "num_blocks": self.alloc.num_blocks - 1,      # minus sentinel
            "free_blocks": self.alloc.free_blocks,
            "min_free_blocks": self.min_free_blocks,
            "blocks_shared": self.blocks_shared,
            "tokens_reused": self.tokens_reused,
            "cow_copies": self.cow_copies,
            "cached_blocks": len(self._cached),
            "prefix_cache_hits": self.prefix_cache_hits,
            "reclaimed_blocks": self.reclaimed_blocks,
            "swapped_blocks_out": self.swapped_blocks_out,
            "swapped_blocks_in": self.swapped_blocks_in,
            "swapped_bytes_out": self.swapped_bytes_out,
            "swapped_bytes_in": self.swapped_bytes_in,
            "swap_prefetched_blocks": self.swap_prefetched_blocks,
        }
