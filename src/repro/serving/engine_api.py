"""Engine layer: the narrow serving surface over ``ContinuousScheduler``.

``Engine`` owns one scheduler instance — and through it the KV pool and the
``lru_cache``-shared jitted steps — and exposes the five operations every
front-end needs and nothing more:

* ``submit(req)``   — enqueue a request (validation lives in the scheduler).
* ``step()``        — advance exactly one scheduler tick; returns whether
                      work remains.  The router interleaves replicas by
                      calling this round-robin.
* ``drain()``       — step until idle, then report.
* ``stats()``       — live counters (queue depth, active, pool state) for
                      routing and monitoring.
* ``cache_probe(p)``— how many tokens of prompt ``p`` the persistent prefix
                      cache would serve for free (paged mode; 0 otherwise).
                      The router's affinity signal.

``serve(requests)`` is the batch convenience (begin + submit all + drain)
that ``ContinuousScheduler.run`` now delegates to, so the CLI, benchmarks,
examples, the router, and the legacy ``run`` all drive the exact same loop.
Everything the scheduler already guarantees — per-(rid, token index) sample
keys, deadline-aware admission, preempt-and-swap — passes through untouched:
the engine adds no policy, only a boundary.

The grep-policy test ``tests/test_compat.py::test_engine_loop_centralized``
pins this boundary: outside ``src/repro/serving/`` nobody constructs a
``ContinuousScheduler`` or calls its ``tick`` — they hold an ``Engine`` (or
a ``repro.serving.router.ReplicaRouter`` over several).
"""
from __future__ import annotations

from typing import Iterable, Optional

from repro.obs import kernels as obs_kernels
from repro.obs import metrics as obs_metrics
from repro.serving.scheduler import (ContinuousScheduler, Request,
                                     RequestResult, ServeReport)


class Engine:
    """One serving replica: scheduler + KV pool + jitted steps behind a
    ``submit / step / drain / stats / cache_probe`` surface.

    Construction takes the same signature as ``ContinuousScheduler`` —
    ``Engine(params, cfg, num_slots=..., slot_len=..., paged=True, ...)`` —
    because the engine owns the scheduler it builds.  ``Engine.wrap``
    adopts an existing scheduler instead (the compatibility path
    ``ContinuousScheduler.run`` uses)."""

    def __init__(self, params, cfg, **scheduler_kwargs):
        self._sched = ContinuousScheduler(params, cfg, **scheduler_kwargs)
        self._t0: Optional[float] = None

    @classmethod
    def wrap(cls, sched: ContinuousScheduler) -> "Engine":
        """Adopt an already-built scheduler (no new pools or jit)."""
        eng = cls.__new__(cls)
        eng._sched = sched
        eng._t0 = None
        return eng

    # -- introspection ------------------------------------------------------
    @property
    def scheduler(self) -> ContinuousScheduler:
        return self._sched

    @property
    def paged(self) -> bool:
        return self._sched.paged

    @property
    def num_slots(self) -> int:
        return self._sched.pool.num_slots

    @property
    def busy(self) -> bool:
        return self._sched.busy

    @property
    def load(self) -> int:
        """Requests this engine is responsible for but has not finished:
        queued + active + suspended + the in-flight prefill.  The router's
        least-loaded signal."""
        s = self._sched
        return (len(s.queue) + len(s.active) + len(s._suspended)
                + (1 if s._prefill is not None else 0))

    # -- the narrow surface -------------------------------------------------
    def submit(self, req: Request) -> None:
        self._sched.submit(req)

    def begin(self) -> None:
        """(Re)start the wall clock (the scheduler's injected clock seam).
        ``step``/``drain`` call it lazily on first use; ``serve`` calls it
        unconditionally so a reused engine times each batch from its own
        start, exactly like the pre-engine ``ContinuousScheduler.run``
        did."""
        self._t0 = self._sched.clock.monotonic()

    def step(self) -> bool:
        """Advance one scheduler tick.  Returns True while work remains."""
        if self._t0 is None:
            self.begin()
        self._sched.tick()
        return self._sched.busy

    def drain(self, *, max_ticks: int = 100_000) -> ServeReport:
        """Step until idle, then report.  ``max_ticks`` guards the same
        wedge conditions (and message) the old scheduler loop did."""
        if self._t0 is None:
            self.begin()
        s = self._sched
        while s.busy:
            if s.tick_count >= max_ticks:
                raise RuntimeError(f"scheduler wedged after {max_ticks} ticks")
            s.tick()
        return self.report()

    def serve(self, requests: Optional[Iterable[Request]] = None, *,
              max_ticks: int = 100_000) -> ServeReport:
        """Batch mode: submit everything, drain, report."""
        self.begin()
        for r in (requests or ()):
            self.submit(r)
        return self.drain(max_ticks=max_ticks)

    def report(self) -> ServeReport:
        """Snapshot the scheduler's cumulative results as a ``ServeReport``
        (identical construction to the pre-engine ``run`` return)."""
        s = self._sched
        now = s.clock.monotonic()
        started = self._t0 if self._t0 is not None else now
        wall = now - self._t0 if self._t0 is not None else 0.0
        occ = (s._occupancy_sum / s.decode_steps if s.decode_steps else 0.0)
        return ServeReport(results=s.finished,
                           decode_steps=s.decode_steps,
                           prefill_chunks=s.prefill_chunks,
                           occupancy=occ, wall_time=wall,
                           paged=s.pool.stats() if s.paged else None,
                           preemptions=s.preemptions,
                           started_at=started, ended_at=now)

    def stats(self) -> dict:
        """Live counters for routing/monitoring (pool stats merged in when
        paged; metrics-registry snapshot attached when the registry is
        enabled)."""
        s = self._sched
        out = {"tick_count": s.tick_count,
               "decode_steps": s.decode_steps,
               "prefill_chunks": s.prefill_chunks,
               "queue_depth": len(s.queue),
               "active": len(s.active),
               "suspended": len(s._suspended),
               "finished": len(s.finished),
               "free_slots": s.pool.free_slots,
               "preemptions": s.preemptions}
        if s.paged:
            out.update(s.pool.stats())
        if obs_metrics.enabled():
            out["metrics"] = obs_metrics.snapshot()
        return out

    def kernel_profile(self) -> dict:
        """Dispatch paths, autotune decisions, and XLA cost figures the
        kernels layer recorded (``repro.obs.kernels``)."""
        return obs_kernels.snapshot()

    def cache_probe(self, prompt) -> int:
        """Tokens of ``prompt`` the persistent prefix cache / live blocks
        would serve without prefilling (0 when unpaged).  Read-only."""
        if not self._sched.paged:
            return 0
        return self._sched.pool.probe(prompt)

    def starved(self, prompt_len: int) -> bool:
        """Admission-backpressure signal: the queue is at least a full
        pool deep AND the pool cannot place ``prompt_len`` even by
        reclaiming every cold prefix-cache block.  Queued work here waits
        on capacity, not on the tick cadence."""
        s = self._sched
        if len(s.queue) < s.pool.num_slots:
            return False
        if not s.paged:
            return s.pool.free_slots == 0
        need = s.pool.family.blocks_for_prompt(prompt_len, s.pool.block_size)
        return s.pool.free_blocks + s.pool.cached_blocks < need


__all__ = ["Engine", "Request", "RequestResult", "ServeReport"]
