"""Cache families: one protocol for every cache shape the stack serves.

The paper's associative ``(m, d)`` merge makes online softmax indifferent to
*how* the KV operands are stored — dense fp blocks, quantized blocks, a
fixed-size recurrence state, or an immutable encoder projection are all just
operand layouts.  This module owns everything the serving stack assumes about
those layouts, so ``PagedPool`` / ``ContinuousScheduler`` / ``Engine`` can
stay layout-agnostic:

* pool-tensor init (contiguous slot caches and paged block pools),
* block-size semantics (``token``: a block holds ``block_size`` token
  positions; ``state``: one block IS a sequence's entire recurrent state;
  ``encdec``: immutable encoder-output blocks + one growing decoder row),
* prefix-shareability rules (dense prefixes chain-share with copy-on-write;
  state mutates in place and never shares; encoder output shares only on a
  whole-audio exact match — the encoder is bidirectional, so a frame-prefix
  match would adopt K/V computed from a *different* full audio),
* the ``continuous_serveable`` / single-shot-prefill policy bits that used to
  live as string checks inside ``engine.py`` and ``scheduler.py``.

Every paged layout obeys one structural contract: **all pool leaves carry the
physical-block axis at position 1**.  That single rule is what lets the
pool's generic machinery — swap-out/swap-in serialization, copy-on-write
block copies, LRU parking — run unchanged across families.

Families are resolved per config (``resolve(cfg)``) and cached, so the
jitted helpers the scheduler builds around a family persist for the process.
The ``dense_int8`` family serves paged: its block pools carry int8 K/V plus
per-(position, head) bfloat16 scale pages (block axis still at leaf
position 1), and ``dequantize_block`` is the protocol boundary the kernel
gather step lowers — scales are consumed tile-local, after the HBM read
(PAPERS.md 2201.04562 / 2111.10770 supply the reduced-precision menu the
softmax-form registry draws from).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.models import ssm, transformer
from repro.models import xlstm as xlstm_mod

Array = jax.Array
PyTree = Any

STATE_KINDS = frozenset({"mamba", "mlstm", "slstm"})


def _attn_cache(cfg: ModelConfig, n: int, batch: int, max_len: int,
                quantized: bool) -> dict:
    dt = jnp.dtype(cfg.dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if quantized:
        return {"attn": {
            "k": jnp.zeros((n, batch, max_len, hkv, hd), jnp.int8),
            "v": jnp.zeros((n, batch, max_len, hkv, hd), jnp.int8),
            "k_scale": jnp.zeros((n, batch, max_len, hkv), jnp.bfloat16),
            "v_scale": jnp.zeros((n, batch, max_len, hkv), jnp.bfloat16)}}
    return {"attn": {
        "k": jnp.zeros((n, batch, max_len, hkv, hd), dt),
        "v": jnp.zeros((n, batch, max_len, hkv, hd), dt)}}


def _segment_caches(cfg: ModelConfig, batch: int, max_len: int,
                    quantized: bool) -> list:
    """The per-segment stacked cache pytree (zeros) — one entry per
    ``transformer.block_pattern`` segment, leading axis = layers in the
    segment (Zamba2's shared block stored unstacked, batch on axis 0)."""
    dt = jnp.dtype(cfg.dtype)
    caches: list = []
    layer_idx = 0
    for kind, count in transformer.block_pattern(cfg):
        if kind in ("dense", "moe"):
            caches.append(_attn_cache(cfg, count, batch, max_len, quantized))
        elif kind == "shared_attn":
            c = _attn_cache(cfg, 1, batch, max_len, quantized)
            caches.append(compat.tree_map(lambda x: x[0], c))
        elif kind == "mla":
            m = cfg.mla
            caches.append({"attn": {
                "c_kv": jnp.zeros((count, batch, max_len, m.kv_lora_rank), dt),
                "k_rope": jnp.zeros((count, batch, max_len,
                                     m.qk_rope_head_dim), dt)}})
        elif kind == "mamba":
            one = ssm.mamba2_cache_init(cfg, batch, dt)
            caches.append(compat.tree_map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape), one))
        elif kind in ("mlstm", "slstm"):
            one = xlstm_mod.xlstm_cache_init(cfg, layer_idx, batch, dt)
            caches.append(compat.tree_map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape), one))
        else:
            raise ValueError(kind)
        layer_idx += count
    return caches


class CacheFamily:
    """Base protocol: layout construction + serving-policy bits.

    Subclasses set the policy attributes and implement the layout methods;
    the scheduler and pool only ever consult these, never ``cfg.family`` or
    ``cfg.kv_cache_dtype`` directly (grep-enforced by
    ``tests/test_compat.py::test_cache_family_centralized``).
    """

    #: "token" (block = block_size token positions), "state" (block = one
    #: sequence's whole recurrent state), or "encdec".
    kind: str = "token"
    #: May this config serve through ContinuousScheduler at all?
    continuous_serveable: bool = True
    #: May it serve through PagedPool?  When False, ``init_paged_cache``
    #: raises with ``paged_unsupported_reason``.
    paged_serveable: bool = True
    #: Must prefill go in one shot (no chunk schedule)?  True where chunked
    #: prefill would drop information: quantized caches re-read only exact
    #: fp tensors of the current chunk, and SSM/xLSTM chunked prefill does
    #: not thread the recurrent prefix state.
    single_shot_prefill: bool = False
    #: Do identical prompt prefixes share physical blocks (with CoW)?
    shareable: bool = True
    #: Does the prompt occupy the decode cache?  (enc-dec prompts are audio
    #: frames feeding the encoder; the decoder row starts at BOS.)
    prompt_in_decoder: bool = True
    #: Does this family only make sense under the paged pool?
    requires_paged: bool = False
    paged_unsupported_reason: str = ""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- layout ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> PyTree:
        """Contiguous (slot-pool / solo) cache pytree, zeros."""
        raise NotImplementedError

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         slot_len: Optional[int] = None) -> PyTree:
        """Block-pool cache pytree, zeros.  Every leaf carries the physical
        block axis at position 1; ``num_blocks`` includes the sentinel."""
        raise NotImplementedError

    def _reject_paged(self) -> None:
        cfg = self.cfg
        raise ValueError(
            f"paged KV cache unsupported for arch {cfg.name!r}: "
            f"{self.paged_unsupported_reason} "
            f"(family={cfg.family!r}, kv_cache_dtype={cfg.kv_cache_dtype!r})")

    # -- geometry --------------------------------------------------------
    def max_blocks(self, slot_len: int, block_size: int) -> int:
        """Block-table width: physical blocks one sequence can hold."""
        raise NotImplementedError

    def blocks_for_prompt(self, prompt_len: int, block_size: int) -> int:
        """Blocks a fresh request needs admitted (prompt + first token)."""
        raise NotImplementedError

    def validate_geometry(self, slot_len: int, block_size: int) -> None:
        """Raise ValueError on a pool geometry this family cannot serve."""

    def validate_prompt(self, prompt_len: int, slot_len: int) -> None:
        """Raise ValueError on a prompt this family can never admit."""
        if self.prompt_in_decoder and prompt_len >= slot_len:
            raise ValueError(
                f"prompt of {prompt_len} cannot fit a slot of {slot_len} "
                "with room to decode")

    # -- quantization hook ----------------------------------------------
    def dequantize_block(self, block: PyTree) -> PyTree:
        """Dequantize one block payload to compute dtype.  Identity for fp
        families; the int8 family overrides this with the same arithmetic
        the in-kernel dequant gather applies tile-local."""
        return block


class DenseFamily(CacheFamily):
    """Standard fp attention K/V — dense, MoE, MLA, VLM text stacks.

    A paged block holds ``block_size`` token positions per layer/head; prefix
    chains share blocks with copy-on-write.  MLA's latent cache is contiguous
    only for now (paging it is a named ROADMAP gap), so ``paged_serveable``
    follows the block kinds.
    """

    name = "dense"
    kind = "token"
    quantized = False

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        kinds = {k for k, _ in transformer.block_pattern(cfg)}
        self.paged_serveable = kinds <= {"dense", "moe"}
        if not self.paged_serveable:
            self.paged_unsupported_reason = (
                "needs standard fp attention caches in every block")

    def init_cache(self, batch: int, max_len: int) -> list:
        return _segment_caches(self.cfg, batch, max_len, self.quantized)

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         slot_len: Optional[int] = None) -> list:
        if not self.paged_serveable:
            self._reject_paged()
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return [{"attn": {
            "k": jnp.zeros((count, num_blocks, hkv, block_size, hd), dt),
            "v": jnp.zeros((count, num_blocks, hkv, block_size, hd), dt)}}
            for _, count in transformer.block_pattern(cfg)]

    def max_blocks(self, slot_len: int, block_size: int) -> int:
        return slot_len // block_size

    def blocks_for_prompt(self, prompt_len: int, block_size: int) -> int:
        return -(-(prompt_len + 1) // block_size)

    def validate_geometry(self, slot_len: int, block_size: int) -> None:
        if slot_len % block_size:
            raise ValueError(
                f"slot_len {slot_len} must be a multiple of block_size "
                f"{block_size}")


class DenseInt8Family(DenseFamily):
    """Quantized (int8 + per-position scales) attention K/V.

    Continuous-serveable with single-shot prefill: the quantized prefill
    computes on the CURRENT chunk's exact fp tensors only — the quantized
    prefix is never re-read during prefill — so a chunk schedule would
    silently drop the prefix.  Paged pools add bfloat16 ``k_scale`` /
    ``v_scale`` pages beside the int8 K/V pools (same block axis, one scale
    per (position, kv-head)); the gather step dequantizes with them
    tile-local — in the chunked-XLA fallback via ``_chunked_fwd_impl`` and
    in the Pallas paged kernels via scalar-prefetched scale pages — so the
    pool lifecycle (swap, CoW, LRU parking) never sees fp data.  Blocks are
    not prefix-shared: scales are per-sequence write-time artifacts, so the
    family opts out of the prefix index rather than risk mixing chains.
    """

    name = "dense_int8"
    quantized = True
    single_shot_prefill = True
    shareable = False

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         slot_len: Optional[int] = None) -> list:
        if not self.paged_serveable:
            self._reject_paged()
        cfg = self.cfg
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return [{"attn": {
            "k": jnp.zeros((count, num_blocks, hkv, block_size, hd),
                           jnp.int8),
            "v": jnp.zeros((count, num_blocks, hkv, block_size, hd),
                           jnp.int8),
            "k_scale": jnp.zeros((count, num_blocks, hkv, block_size),
                                 jnp.bfloat16),
            "v_scale": jnp.zeros((count, num_blocks, hkv, block_size),
                                 jnp.bfloat16)}}
            for _, count in transformer.block_pattern(cfg)]

    def dequantize_block(self, block: PyTree) -> PyTree:
        """Reconstruct fp32 K/V from one block's int8 payload + scale pages.

        ``block`` is a single physical block's payload — any tree whose
        ``attn`` dicts pair ``k``/``v`` int8 leaves ``[..., BS, hd]`` with
        ``k_scale``/``v_scale`` leaves ``[..., BS]``.  This is the exact
        arithmetic the kernels apply tile-local after the HBM read
        (``x.astype(f32) * scale.astype(f32)``); tests pin the two against
        each other so the hook can't drift from the lowered form.
        """
        def deq(attn: dict) -> dict:
            return {
                "k": (attn["k"].astype(jnp.float32)
                      * attn["k_scale"].astype(jnp.float32)[..., None]),
                "v": (attn["v"].astype(jnp.float32)
                      * attn["v_scale"].astype(jnp.float32)[..., None])}
        if isinstance(block, dict):
            return {"attn": deq(block["attn"])} if "attn" in block \
                else deq(block)
        return [self.dequantize_block(seg) for seg in block]


class FixedStateFamily(CacheFamily):
    """SSM / xLSTM / hybrid recurrent state (zamba2, xlstm configs).

    Fixed-size state is a degenerate one-block "page": one physical block IS
    a sequence's entire cache row — the recurrent state of every layer plus,
    for hybrids, the shared-attention K/V region.  ``block_size`` is
    irrelevant; the table is one column wide.  State mutates in place every
    step, so blocks never share (refcount stays 1) and prefill must be
    single-shot (the chunked SSD scan does not thread prefix state).
    """

    name = "fixed_state"
    kind = "state"
    single_shot_prefill = True
    shareable = False

    def init_cache(self, batch: int, max_len: int) -> list:
        return _segment_caches(self.cfg, batch, max_len, False)

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         slot_len: Optional[int] = None) -> list:
        if slot_len is None:
            raise TypeError(
                "fixed-state pools need slot_len: one block holds a whole "
                "state row sized by it")
        segs = self.init_cache(num_blocks, slot_len)
        out = []
        for (kind, _), c in zip(transformer.block_pattern(self.cfg), segs):
            # the shared block is stored unstacked (block axis 0) in slot
            # caches; re-add a unit layer axis so the pool contract holds
            # (block axis at position 1 on every leaf)
            out.append(compat.tree_map(lambda x: x[None], c)
                       if kind == "shared_attn" else c)
        return out

    def max_blocks(self, slot_len: int, block_size: int) -> int:
        return 1

    def blocks_for_prompt(self, prompt_len: int, block_size: int) -> int:
        return 1

    def prompt_quantum(self) -> int:
        """Single-shot prefill runs the chunked scan once over the whole
        prompt, and the scan requires the length to divide into its chunk —
        prompts must be ≤ this quantum or a multiple of it."""
        qs = [sub.chunk for sub in (self.cfg.ssm, self.cfg.xlstm)
              if sub is not None]
        q = 1
        for c in qs:
            q = q * c // math.gcd(q, c)
        return q

    def validate_prompt(self, prompt_len: int, slot_len: int) -> None:
        super().validate_prompt(prompt_len, slot_len)
        q = self.prompt_quantum()
        if prompt_len > q and prompt_len % q:
            raise ValueError(
                f"fixed-state prefill is single-shot through the chunked "
                f"scan: prompt of {prompt_len} must be ≤ {q} or a multiple "
                f"of {q}")


class EncDecFamily(CacheFamily):
    """Encoder–decoder (whisper): immutable encoder cross-K/V + decoder row.

    The prompt is the audio (frame ids); the encoder is bidirectional, so
    its output — and thus the cross-attention K/V — depends on *all* frames:
    only a whole-audio exact match may share blocks, and whisper's fixed
    padded window (``cfg.encoder_seq_len``) makes every prompt that exact
    length.  A sequence's table row is ``S_enc // block_size`` immutable
    cross blocks (shareable, refcounted, LRU-parked like dense prefixes)
    plus one self-K/V row block that grows with decoded tokens.
    """

    name = "encdec"
    kind = "encdec"
    single_shot_prefill = True
    prompt_in_decoder = False
    requires_paged = True

    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        n, s_enc = cfg.num_layers, cfg.encoder_seq_len
        return {
            "self": {"k": jnp.zeros((n, batch, max_len, hkv, hd), dt),
                     "v": jnp.zeros((n, batch, max_len, hkv, hd), dt)},
            "cross": {"k": jnp.zeros((n, batch, s_enc, hkv, hd), dt),
                      "v": jnp.zeros((n, batch, s_enc, hkv, hd), dt)},
        }

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         slot_len: Optional[int] = None) -> dict:
        if slot_len is None:
            raise TypeError(
                "enc-dec pools need slot_len: each block carries a decoder "
                "self-K/V row sized by it")
        self.validate_geometry(slot_len, block_size)
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        n = cfg.num_layers
        return {
            "self": {
                "k": jnp.zeros((n, num_blocks, slot_len, hkv, hd), dt),
                "v": jnp.zeros((n, num_blocks, slot_len, hkv, hd), dt)},
            "cross": {
                "k": jnp.zeros((n, num_blocks, block_size, hkv, hd), dt),
                "v": jnp.zeros((n, num_blocks, block_size, hkv, hd), dt)},
        }

    def cross_blocks(self, block_size: int) -> int:
        return self.cfg.encoder_seq_len // block_size

    def max_blocks(self, slot_len: int, block_size: int) -> int:
        return self.cross_blocks(block_size) + 1

    def blocks_for_prompt(self, prompt_len: int, block_size: int) -> int:
        return self.cross_blocks(block_size) + 1

    def validate_geometry(self, slot_len: int, block_size: int) -> None:
        if self.cfg.encoder_seq_len % block_size:
            raise ValueError(
                f"encoder_seq_len {self.cfg.encoder_seq_len} must be a "
                f"multiple of block_size {block_size} to page the encoder "
                "output")

    def validate_prompt(self, prompt_len: int, slot_len: int) -> None:
        if prompt_len != self.cfg.encoder_seq_len:
            raise ValueError(
                f"enc-dec prompts are audio frame ids padded to the encoder "
                f"window: expected exactly {self.cfg.encoder_seq_len} "
                f"frames, got {prompt_len}")


@functools.lru_cache(maxsize=None)
def resolve(cfg: ModelConfig) -> CacheFamily:
    """The cache family serving this config.  Cached per config so the
    jitted step functions the scheduler builds around a family persist."""
    if cfg.family == "encdec":
        return EncDecFamily(cfg)
    kinds = {k for k, _ in transformer.block_pattern(cfg)}
    if kinds & STATE_KINDS:
        return FixedStateFamily(cfg)
    if cfg.kv_cache_dtype == "int8":
        return DenseInt8Family(cfg)
    return DenseFamily(cfg)
