"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog,
heartbeats, crash-exact data resumption.

Failure model (what actually happens at 1000+ nodes): a worker dies → the job
is rescheduled → every host restarts this loop → ``run()`` restores the last
COMMITTED checkpoint and the counter-based data pipeline regenerates exactly
the next batch.  The loop is deliberately a dumb idempotent function of
(checkpoint dir, step) — all cleverness lives in the substrate:

* ``CheckpointManager`` — async + atomic commit (no torn checkpoints);
* ``SyntheticDataset.batch(step)`` — stateless data (no iterator state to
  lose);
* step-time watchdog — median-based straggler detection; on real clusters
  this is where you'd trigger hot-spare swap; here it logs and records;
* heartbeat file — external orchestrators (k8s/B****) kill hung workers by
  heartbeat age, which composes with restart-from-checkpoint above.

``inject_failure`` lets tests crash the loop at an arbitrary step and assert
bit-exact recovery (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
from typing import Callable, Optional

import jax
import numpy as np

from repro import compat
from repro.obs import clock as obs_clock
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import RunConfig
from repro.data.synthetic import SyntheticConfig, SyntheticDataset


class StragglerWatchdog:
    """Flags steps slower than ``factor`` × running median."""

    def __init__(self, factor: float = 3.0, window: int = 50):
        self.factor = factor
        self.times: list[float] = []
        self.window = window
        self.events: list[dict] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        med = float(np.median(self.times))
        if len(self.times) >= 5 and dt > self.factor * med:
            self.events.append({"step": step, "dt": dt, "median": med})
            return True
        return False


def run(run_cfg: RunConfig, *, steps: int, train_step: Callable,
        params, opt_state, shardings=None,
        dataset: Optional[SyntheticDataset] = None,
        inject_failure: Optional[Callable[[int], None]] = None,
        log: Callable[[str], None] = print):
    """Run ``steps`` optimizer steps with checkpoint/restart semantics.

    Returns (params, opt_state, history).  Restores from the newest committed
    checkpoint in ``run_cfg.checkpoint_dir`` if one exists (restart path).
    """
    cfg = run_cfg.model
    ckpt = CheckpointManager(run_cfg.checkpoint_dir,
                             keep=run_cfg.keep_checkpoints)
    dataset = dataset or SyntheticDataset(SyntheticConfig(
        vocab_size=cfg.real_vocab_size or cfg.vocab_size,
        seq_len=128, global_batch=8, seed=run_cfg.seed))
    watchdog = StragglerWatchdog()
    hb_path = os.path.join(run_cfg.checkpoint_dir, "heartbeat")

    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        log(f"[restore] resuming from committed step {latest}")
        state = ckpt.restore(latest, {"params": params, "opt": opt_state},
                             shardings=shardings)
        params, opt_state = state["params"], state["opt"]
        start = latest

    history = []
    step = start
    while step < steps:
        batch = dataset.batch(step)
        batch = compat.tree_map(lambda x: jax.numpy.asarray(x), batch)
        if inject_failure is not None:
            inject_failure(step)          # may raise — simulated node death
        t0 = obs_clock.monotonic()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()
                   if np.ndim(v) == 0}
        dt = obs_clock.monotonic() - t0
        if watchdog.observe(step, dt):
            log(f"[straggler] step {step} took {dt:.3f}s "
                f"(median {np.median(watchdog.times):.3f}s)")
        with open(hb_path, "w") as f:
            json.dump({"step": step, "t": obs_clock.wall_time()}, f)
        history.append({"step": step, "dt": dt, **metrics})
        if step % run_cfg.log_every == 0:
            log(f"[step {step}] loss={metrics.get('loss', float('nan')):.4f} "
                f"dt={dt * 1e3:.1f}ms")
        step += 1
        if step % run_cfg.checkpoint_every == 0 or step == steps:
            ckpt.save(step, {"params": params, "opt": opt_state})
    ckpt.wait()
    return params, opt_state, history
