"""Train-step builder: loss → grads → (compressed) reduce → AdamW, under pjit.

Microbatch gradient accumulation is a ``lax.scan`` whose per-microbatch
data-parallel reduction XLA can overlap with the next microbatch's backward
(latency-hiding scheduler) — the accumulate-then-step structure is what makes
that overlap legal.  Gradients are cast to ``grad_reduce_dtype`` (default
bf16) at the autodiff boundary so the cross-replica all-reduce moves half the
bytes (verified in the dry-run HLO, §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs.base import RunConfig
from repro.distributed import compression, sharding
from repro.models import encdec, layers as L, transformer
from repro.optim import adamw

PyTree = Any


def loss_for(cfg) -> Callable:
    return encdec.loss_fn if cfg.family == "encdec" else transformer.loss_fn


def make_train_step(run: RunConfig) -> Callable:
    """Pure (params, opt_state, batch) → (params, opt_state, metrics)."""
    cfg = run.model
    loss_fn = loss_for(cfg)
    n_micro = run.parallel.microbatches

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(
            lambda p, b: loss_fn(p, b, cfg), has_aux=True)
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = compat.tree_map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def body(acc, b_i):
                (l, m), g = grad_fn(params, b_i)
                g = compression.cast_grads(g, run.parallel.grad_reduce_dtype)
                acc = compat.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, (l, m)

            zeros = compat.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ms) = jax.lax.scan(body, zeros, mb)
            grads = compat.tree_map(lambda g: g / n_micro, grads)
            loss = losses.mean()
            metrics = compat.tree_map(jnp.mean, ms)
        grads = compression.cast_grads(grads, run.parallel.grad_reduce_dtype)
        params, opt_state, om = adamw.update(grads, opt_state, params,
                                             run.optimizer)
        return params, opt_state, {**metrics, **om, "loss_out": loss}

    return train_step


def init_state(run: RunConfig, rng) -> tuple[PyTree, PyTree, PyTree]:
    """Returns (params, opt_state, axes_tree)."""
    cfg = run.model
    init_fn = encdec.init if cfg.family == "encdec" else transformer.init
    boxed = init_fn(rng, cfg)
    params, axes = L.split_params(boxed)
    opt_state = adamw.init(params)
    return params, opt_state, axes


def jit_train_step(run: RunConfig, mesh: Mesh, axes: PyTree):
    """jit with explicit in/out shardings for the production mesh."""
    cfg = run.model
    par = sharding.derive_parallel(cfg, mesh, run.parallel)
    p_sh = sharding.param_sharding(axes, cfg, par, mesh)
    opt_sh = adamw.AdamWState(
        step=compat.named_sharding(mesh, P()),
        mu=p_sh, nu=p_sh)
    bspec = compat.named_sharding(mesh, P(par.data_axes, None))
    step = make_train_step(run)
    return jax.jit(
        step,
        in_shardings=(p_sh, opt_sh, None),
        out_shardings=(p_sh, opt_sh, None),
        donate_argnums=(0, 1),
    ), p_sh, opt_sh
