"""Continuous-batching serving subsystem: scheduler equivalence and policy.

The load-bearing guarantee: the tokens a request produces under continuous
batching — admitted into a shared slot pool, prefilled in chunks between
other sequences' decode steps, decoded at full batch occupancy next to ragged
neighbours — are IDENTICAL to running that request alone through the
single-sequence decode path.  Sampling keys are per-(request, token index)
and ``engine.sample_per_slot`` draws per-row, so batch composition cannot
leak into any request's stream.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import engine, scheduler

SLOT_LEN = 48
CHUNK = 8
TOP_K = 5
BASE_RNG = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("smollm_360m")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    return params, cfg


def _key(rid, step):
    return jax.random.fold_in(jax.random.fold_in(BASE_RNG, rid), step)


def _single_sequence_decode(params, cfg, req):
    """The request alone: chunked prefill + per-slot decode at batch size 1."""
    last, caches, ln = engine.chunked_prefill(
        params, jnp.asarray(req.prompt)[None], cfg, max_len=SLOT_LEN,
        chunk=CHUNK)
    logits = engine.logits_from_hidden(params, last, cfg)
    tok = engine.sample_per_slot(_key(req.rid, 0)[None], logits, TOP_K)
    tokens = [int(tok[0])]
    lens = jnp.asarray([int(ln)], jnp.int32)
    for step in range(1, req.max_new_tokens):
        tok, caches, lens = engine.decode_step_slots(
            params, caches, lens, tok[:, None], cfg,
            rngs=_key(req.rid, step)[None], top_k=TOP_K)
        tokens.append(int(tok[0]))
    return tokens


def _workload(pattern):
    """≥ 8 requests, all prompt lengths distinct, mixed decode budgets."""
    rng = np.random.default_rng(11)
    prompt_lens = [4, 6, 7, 9, 11, 13, 16, 18]
    decode_lens = [5, 3, 7, 4, 6, 3, 5, 4]
    arrivals = {
        "burst": [0] * 8,                       # everyone at once
        "staggered": [0, 0, 1, 2, 4, 5, 7, 9],  # trickling in mid-flight
        "reversed": [0, 8, 7, 6, 5, 4, 3, 2],   # later rids arrive earlier
    }[pattern]
    return [scheduler.Request(
        rid=i, prompt=rng.integers(0, 512, p), max_new_tokens=d,
        arrival_tick=a)
        for i, (p, d, a) in enumerate(zip(prompt_lens, decode_lens, arrivals))]


@pytest.mark.parametrize("pattern", ["burst", "staggered", "reversed"])
def test_continuous_batching_matches_single_sequence(model, pattern):
    params, cfg = model
    requests = _workload(pattern)
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=3, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG)
    report = sched.run(requests)
    assert len(report.results) == len(requests)
    by_rid = {r.rid: r for r in report.results}
    for req in requests:
        want = _single_sequence_decode(params, cfg, req)
        got = by_rid[req.rid]
        assert got.tokens == want, (
            f"request {req.rid} diverged under {pattern} arrivals:"
            f" pool={got.tokens} alone={want}")
        assert len(got.tokens) == req.max_new_tokens
        assert not got.evicted


def test_no_drain_between_requests(model):
    """A finished slot is reused without waiting for the batch to empty:
    with more requests than slots the pool must overlap generations."""
    params, cfg = model
    requests = _workload("burst")
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG)
    report = sched.run(requests)
    assert len(report.results) == 8
    # lockstep would need sum over batches of max(decode); continuous decode
    # steps must come in strictly under serialized execution
    assert report.decode_steps < sum(len(r.tokens) for r in report.results)


def test_occupancy_beats_drain_and_refill(model):
    """The acceptance bar: under a backlogged staggered workload the pool
    stays fuller than the lockstep schedule's slot-step occupancy."""
    params, cfg = model
    requests = scheduler.poisson_workload(
        12, rate_per_tick=3.0, prompt_lens=(4, 16), decode_lens=(2, 16),
        vocab=512, seed=5)
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=3, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG)
    report = sched.run(requests)
    baseline = report.baseline_occupancy(3)
    assert report.occupancy > baseline, (report.occupancy, baseline)
    pct = report.latency_percentiles((50, 95))
    assert 0 < pct["p50"] <= pct["p95"]
    assert report.tokens_per_s > 0


def test_eviction_at_slot_capacity(model):
    """A sequence that would outgrow its slot is retired by the capacity
    backstop and flagged ``evicted``; everyone else is unaffected."""
    params, cfg = model
    small = 24
    requests = [
        scheduler.Request(rid=0, prompt=np.arange(10) % 512,
                          max_new_tokens=100),          # wants > slot space
        scheduler.Request(rid=1, prompt=np.arange(5) % 512,
                          max_new_tokens=4),
    ]
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=small, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG)
    report = sched.run(requests)
    by_rid = {r.rid: r for r in report.results}
    assert by_rid[0].evicted
    # prompt 10 + first token + (slot_len - prompt - 1) decode writes
    assert len(by_rid[0].tokens) == small - 10 + 1
    assert not by_rid[1].evicted
    assert len(by_rid[1].tokens) == 4


def test_invalid_submissions_rejected(model):
    params, cfg = model
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=1, slot_len=16, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG)
    with pytest.raises(ValueError, match="cannot fit"):
        sched.submit(scheduler.Request(rid=0, prompt=np.zeros(16, np.int64),
                                       max_new_tokens=2))
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(scheduler.Request(rid=1, prompt=np.zeros(0, np.int64),
                                       max_new_tokens=2))
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(scheduler.Request(rid=3, prompt=np.zeros(4, np.int64),
                                       max_new_tokens=0))
    sched.submit(scheduler.Request(rid=2, prompt=np.zeros(4, np.int64),
                                   max_new_tokens=2))
    with pytest.raises(ValueError, match="duplicate request id"):
        sched.submit(scheduler.Request(rid=2, prompt=np.zeros(5, np.int64),
                                       max_new_tokens=2))


def test_eos_retires_request_without_evicted_flag(model):
    """Retirement on eos_id: the request stops at its first eos token, is
    not flagged evicted, and (per the equivalence guarantee) every other
    request's stream is untouched by the early exit."""
    params, cfg = model
    requests = _workload("burst")[:4]
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG)
    streams = {r.rid: r.tokens for r in sched.run(requests).results}
    # pick a token some request emits that no OTHER stream contains, so eos
    # retires exactly one request and leaves the rest comparable
    target = eos = None
    for rid, toks in streams.items():
        unique = [t for t in toks
                  if all(t not in o for orid, o in streams.items()
                         if orid != rid)]
        if unique:
            target, eos = rid, unique[0]
            break
    assert target is not None, streams
    cut = streams[target].index(eos)
    sched2 = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, eos_id=int(eos))
    by_rid = {r.rid: r for r in sched2.run(requests).results}
    assert by_rid[target].tokens == streams[target][:cut + 1]
    assert not by_rid[target].evicted
    for rid, toks in streams.items():
        if rid != target:
            assert by_rid[rid].tokens == toks


def test_chunked_prefill_correct_under_pallas_preference(model):
    """Cached chunked prefill under a Pallas preference now routes to the
    offset-aware flash kernel (interpret mode on this host): absolute-position
    causal masking means the second chunk still attends the already-prefilled
    prefix.  This pins the end-to-end engine result against the XLA form —
    the exact masking bug class PR 2 had to route around."""
    params, cfg = model
    prompt = jnp.asarray(np.arange(12)[None] % 512)
    ref_last, _, _ = engine.chunked_prefill(params, prompt, cfg,
                                            max_len=32, chunk=5)
    got_last, _, _ = engine.chunked_prefill(params, prompt,
                                            cfg.replace(use_pallas=True),
                                            max_len=32, chunk=5)
    np.testing.assert_allclose(np.asarray(got_last), np.asarray(ref_last),
                               rtol=1e-5, atol=1e-6)


def test_slot_pool_acquire_release_insert(model):
    params, cfg = model
    pool = scheduler.SlotPool(cfg, num_slots=2, slot_len=16)
    assert pool.free_slots == 2
    s0, s1 = pool.acquire(), pool.acquire()
    assert {s0, s1} == {0, 1} and pool.acquire() is None
    prompt = jnp.arange(6)[None] % 512
    _, seq, ln = engine.chunked_prefill(params, prompt, cfg, max_len=16)
    pool.insert(s1, seq, int(ln))
    assert int(pool.lens[s1]) == 6 and int(pool.lens[s0]) == 0
    # the inserted slice must equal the sequence cache, slot-for-slot
    got = jax.tree.leaves(pool.caches[0])[0][:, s1]
    want = jax.tree.leaves(seq[0])[0][:, 0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    pool.release(s1)
    assert pool.free_slots == 1 and int(pool.lens[s1]) == 0


def test_drain_and_refill_occupancy_math():
    # two batches of 2: steps = 8 + 6, busy = 8+2+6+4 → 20/28
    assert scheduler.drain_and_refill_occupancy([8, 2, 6, 4], 2) == \
        pytest.approx(20 / 28)
    assert scheduler.drain_and_refill_occupancy([5, 5, 5, 5], 4) == 1.0
    assert scheduler.drain_and_refill_occupancy([], 4) == 0.0
