"""Chunked online attention + chunked cross-entropy vs dense references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core


def _rand(shape, seed, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


class TestChunkedAttention:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("chunk", [7, 16, 64])
    @pytest.mark.parametrize("Hq,Hkv", [(8, 2), (4, 4), (6, 1)])
    def test_matches_naive(self, causal, chunk, Hq, Hkv):
        B, Tq, Tk, Dh = 2, 24, 64, 16
        q = _rand((B, Tq, Hq, Dh), 0)
        k = _rand((B, Tk, Hkv, Dh), 1)
        v = _rand((B, Tk, Hkv, Dh), 2)
        o1 = core.online_attention(q, k, v, causal=causal, q_offset=Tk - Tq,
                                   chunk_size=chunk)
        o2 = core.naive_attention(q, k, v, causal=causal, q_offset=Tk - Tq)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)

    def test_valid_len_masking(self):
        B, T, H, Dh = 3, 32, 2, 8
        q = _rand((B, 1, H, Dh), 3)
        k = _rand((B, T, H, Dh), 4)
        v = _rand((B, T, H, Dh), 5)
        vlen = jnp.array([32, 7, 1])
        o1 = core.online_attention(q, k, v, kv_valid_len=vlen, chunk_size=8)
        o2 = core.naive_attention(q, k, v, kv_valid_len=vlen)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)

    def test_different_v_dim(self):
        """MLA path: value dim != qk dim."""
        B, T, H = 2, 32, 1
        q = _rand((B, 4, H, 24), 6)
        k = _rand((B, T, H, 24), 7)
        v = _rand((B, T, H, 16), 8)
        o1 = core.online_attention(q, k, v, causal=False, chunk_size=8)
        o2 = core.naive_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)

    def test_grads_match_naive(self):
        B, T, Hq, Hkv, Dh = 2, 32, 4, 2, 8
        q = _rand((B, T, Hq, Dh), 9)
        k = _rand((B, T, Hkv, Dh), 10)
        v = _rand((B, T, Hkv, Dh), 11)
        w = _rand((B, T, Hq, Dh), 12)
        f1 = lambda q, k, v: (core.online_attention(
            q, k, v, causal=True, chunk_size=8) * w).sum()
        f2 = lambda q, k, v: (core.naive_attention(
            q, k, v, causal=True) * w).sum()
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=1e-5)

    def test_non_divisible_chunk_padding(self):
        B, T, H, Dh = 1, 50, 2, 8      # 50 % 16 != 0
        q = _rand((B, T, H, Dh), 13)
        k = _rand((B, T, H, Dh), 14)
        v = _rand((B, T, H, Dh), 15)
        o1 = core.online_attention(q, k, v, causal=True, chunk_size=16)
        o2 = core.naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)


class TestChunkedCrossEntropy:
    @pytest.mark.parametrize("chunks", [1, 4, 16])
    def test_matches_full(self, chunks):
        T, D, V = 48, 16, 256
        h = _rand((T, D), 0)
        w = _rand((D, V), 1, 0.2)
        labels = jax.random.randint(jax.random.PRNGKey(2), (T,), 0, V)
        l1 = core.chunked_cross_entropy(h, w, labels, num_chunks=chunks)
        l2 = core.full_cross_entropy(h, w, labels)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-6)

    def test_grads_match_full(self):
        T, D, V = 32, 8, 128
        h = _rand((T, D), 3)
        w = _rand((D, V), 4, 0.2)
        labels = jax.random.randint(jax.random.PRNGKey(5), (T,), 0, V)
        g1 = jax.grad(lambda h, w: core.chunked_cross_entropy(
            h, w, labels, num_chunks=8).mean(), argnums=(0, 1))(h, w)
        g2 = jax.grad(lambda h, w: core.full_cross_entropy(
            h, w, labels).mean(), argnums=(0, 1))(h, w)
        np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                                   rtol=1e-4, atol=1e-6)

    def test_z_loss(self):
        T, D, V = 16, 8, 64
        h = _rand((T, D), 6)
        w = _rand((D, V), 7, 0.2)
        labels = jax.random.randint(jax.random.PRNGKey(8), (T,), 0, V)
        l0 = core.chunked_cross_entropy(h, w, labels, num_chunks=4)
        l1 = core.chunked_cross_entropy(h, w, labels, num_chunks=4,
                                        z_loss=1e-2)
        lse = jax.scipy.special.logsumexp(h @ w, axis=-1)
        np.testing.assert_allclose(np.asarray(l1 - l0),
                                   1e-2 * np.asarray(lse) ** 2,
                                   rtol=1e-4, atol=1e-5)

    def test_big_logits_no_overflow(self):
        T, D, V = 8, 4, 64
        h = _rand((T, D), 9, 30.0)     # logits up to ~1000s
        w = _rand((D, V), 10, 1.0)
        labels = jnp.zeros((T,), jnp.int32)
        l1 = core.chunked_cross_entropy(h, w, labels, num_chunks=4)
        assert np.isfinite(np.asarray(l1)).all()


class TestTopkFusion:
    @pytest.mark.parametrize("k", [1, 5, 17])
    @pytest.mark.parametrize("block", [None, 64, 100])
    def test_matches_unfused(self, k, block):
        x = _rand((4, 400), 0, 6.0)
        fused = core.softmax_topk(x, k, block=block)
        unfused = core.safe_softmax_then_topk(x, k)
        np.testing.assert_allclose(np.asarray(fused.values),
                                   np.asarray(unfused.values),
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(fused.indices),
                                      np.asarray(unfused.indices))

    def test_sampling_distribution(self):
        """topk_sample draws ∝ renormalized top-k probabilities."""
        logits = jnp.log(jnp.array([[0.5, 0.3, 0.1, 0.06, 0.04]])) * 1.0
        logits = jnp.tile(logits, (4096, 1))
        rng = jax.random.PRNGKey(0)
        toks, _ = core.topk_sample(rng, logits, 3)
        freq = np.bincount(np.asarray(toks), minlength=5) / toks.shape[0]
        expect = np.array([0.5, 0.3, 0.1, 0, 0]) / 0.9
        np.testing.assert_allclose(freq[:3], expect[:3], atol=0.03)
        assert freq[3] == freq[4] == 0
