"""Paged KV-cache serving: allocator invariants, prefix sharing, eviction
block accounting, and the bit-identity guarantee.

The load-bearing claims (ISSUE 4 acceptance):

* ``BlockAllocator`` never double-frees, refcounts always match the live
  references, and churn can never oversubscribe the pool (property tests —
  real hypothesis where installed, the fixed-seed fallback elsewhere).
* Paged decode/prefill is **bit-identical** per request to the PR-2
  slot-pool decode (same per-slot PRNG scheme) across arrival orders — the
  block pool is a layout change, not a numerics change.
* Two requests sharing a prompt prefix demonstrably share physical blocks
  (free-block accounting) and diverge correctly after copy-on-write.
* An ``evicted``-flagged sequence returns its non-shared blocks to the free
  list in the same tick, and never frees a block whose refcount > 1.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                                    # offline fallback
    from _hypothesis_compat import given, settings, st

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import engine, paged, scheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SLOT_LEN = 48
BLOCK = 8
CHUNK = 8
TOP_K = 5
BASE_RNG = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("smollm_360m")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    return params, cfg


def _key(rid, step):
    return jax.random.fold_in(jax.random.fold_in(BASE_RNG, rid), step)


def _single_sequence_decode(params, cfg, req):
    """The request alone: slot-pool chunked prefill + batch-1 decode — the
    PR-2 reference the paged pool must reproduce token-for-token."""
    last, caches, ln = engine.chunked_prefill(
        params, jnp.asarray(req.prompt)[None], cfg, max_len=SLOT_LEN,
        chunk=CHUNK)
    logits = engine.logits_from_hidden(params, last, cfg)
    tok = engine.sample_per_slot(_key(req.rid, 0)[None], logits, TOP_K)
    tokens = [int(tok[0])]
    lens = jnp.asarray([int(ln)], jnp.int32)
    for step in range(1, req.max_new_tokens):
        tok, caches, lens = engine.decode_step_slots(
            params, caches, lens, tok[:, None], cfg,
            rngs=_key(req.rid, step)[None], top_k=TOP_K)
        tokens.append(int(tok[0]))
    return tokens


# ---------------------------------------------------------------------------
# BlockAllocator invariants (property tests).
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=15)
@given(st.integers(min_value=1, max_value=12),
       st.lists(st.integers(min_value=0, max_value=2 ** 31 - 1),
                min_size=0, max_size=120))
def test_allocator_invariants_under_churn(num_blocks, actions):
    """Random alloc/incref/decref churn: refcounts track the references we
    hold, free+live partitions the pool, and allocation past capacity fails
    cleanly instead of aliasing."""
    alloc = paged.BlockAllocator(num_blocks)
    held: dict[int, int] = {}
    for a in actions:
        op = a % 3
        if op == 0:
            bid = alloc.alloc()
            if bid is None:
                assert alloc.free_blocks == 0
            else:
                assert bid not in held          # fresh: no aliasing
                held[bid] = 1
        elif op == 1 and held:
            bid = sorted(held)[a % len(held)]
            alloc.incref(bid)
            held[bid] += 1
        elif op == 2 and held:
            bid = sorted(held)[(a // 3) % len(held)]
            freed = alloc.decref(bid)
            held[bid] -= 1
            if held[bid] == 0:
                del held[bid]
                assert freed                    # last ref frees...
            else:
                assert not freed                # ...earlier refs never do
        alloc.check_invariants()
        for bid, n in held.items():
            assert alloc.refcount(bid) == n
        assert alloc.live_blocks == len(held) <= num_blocks


def test_allocator_double_free_raises():
    alloc = paged.BlockAllocator(2)
    bid = alloc.alloc()
    assert alloc.decref(bid)
    with pytest.raises(paged.DoubleFreeError):
        alloc.decref(bid)
    with pytest.raises(ValueError):
        alloc.incref(bid)                       # dead blocks can't be shared


def test_allocator_alloc_after_churn_never_exceeds_pool():
    alloc = paged.BlockAllocator(3)
    for _ in range(5):
        got = [alloc.alloc() for _ in range(4)]
        assert got[3] is None and None not in got[:3]
        assert sorted(got[:3]) == sorted(set(got[:3]))
        for bid in got[:3]:
            alloc.decref(bid)
        alloc.check_invariants()


# ---------------------------------------------------------------------------
# Bit-identity: paged serving == single-sequence slot-pool decode.
# ---------------------------------------------------------------------------
def _workload(pattern):
    rng = np.random.default_rng(11)
    prompt_lens = [4, 6, 9, 13, 16, 18]
    decode_lens = [5, 3, 6, 4, 5, 3]
    arrivals = {
        "burst": [0] * 6,
        "staggered": [0, 0, 1, 3, 5, 7],
        "reversed": [0, 6, 5, 4, 3, 2],
    }[pattern]
    return [scheduler.Request(
        rid=i, prompt=rng.integers(0, 512, p), max_new_tokens=d,
        arrival_tick=a)
        for i, (p, d, a) in enumerate(zip(prompt_lens, decode_lens,
                                          arrivals))]


@pytest.fixture(scope="module")
def solo_streams(model):
    params, cfg = model
    return {req.rid: _single_sequence_decode(params, cfg, req)
            for req in _workload("burst")}      # prompts identical per rid


@pytest.mark.parametrize("pattern", ["burst", "staggered", "reversed"])
def test_paged_matches_single_sequence(model, solo_streams, pattern):
    params, cfg = model
    requests = _workload(pattern)
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=3, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=BLOCK)
    report = sched.run(requests)
    assert len(report.results) == len(requests)
    by_rid = {r.rid: r for r in report.results}
    for req in requests:
        got = by_rid[req.rid]
        assert got.tokens == solo_streams[req.rid], (
            f"request {req.rid} diverged under paged {pattern} arrivals")
        assert len(got.tokens) == req.max_new_tokens
        assert not got.evicted
    # every block is accounted for: free, or parked in the persistent
    # prefix cache (entries outliving their sequences — ISSUE 5)
    assert (report.paged["free_blocks"] + report.paged["cached_blocks"]
            == report.paged["num_blocks"])


def test_paged_requires_block_aligned_slots(model):
    params, cfg = model
    with pytest.raises(ValueError, match="multiple of block_size"):
        scheduler.ContinuousScheduler(
            params, cfg, num_slots=2, slot_len=42, prefill_chunk=CHUNK,
            top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=8)


def test_paged_submit_rejects_never_admissible_prompt(model):
    """A prompt whose worst-case block need exceeds the whole pool must be
    rejected at submit, not spin in the queue forever."""
    params, cfg = model
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=32, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=8,
        num_blocks=2)
    with pytest.raises(ValueError, match="block need exceeds"):
        sched.submit(scheduler.Request(rid=0, prompt=np.zeros(20, np.int64),
                                       max_new_tokens=2))


def test_paged_int8_pools_pair_scales_with_payload():
    """int8 dense pages now (in-kernel dequant gather): the pool pairs int8
    K/V with bfloat16 per-position scale pages on the same block axis."""
    cfg8 = configs.get_smoke("smollm_360m").replace(kv_cache_dtype="int8")
    pools = engine.init_paged_cache(cfg8, num_blocks=4, block_size=8)
    attn = pools[0]["attn"]
    assert attn["k"].dtype == jnp.int8 and attn["v"].dtype == jnp.int8
    assert attn["k_scale"].dtype == jnp.bfloat16
    assert attn["k_scale"].shape == attn["k"].shape[:-1]
    assert attn["v_scale"].shape == attn["v"].shape[:-1]


def test_paged_rejects_unsupported_archs():
    # MLA's latent cache stays contiguous-only (named ROADMAP gap)
    cfg = configs.get_smoke("minicpm3_4b")
    with pytest.raises(ValueError, match="paged KV cache unsupported"):
        engine.init_paged_cache(cfg, num_blocks=4, block_size=8)


def test_paged_fixed_state_pool_needs_slot_len():
    """zamba2 pages since the cache-family refactor — its pool tensor is the
    slot cache itself, so building it requires the slot length."""
    cfg = configs.get_smoke("zamba2_1p2b")
    with pytest.raises(TypeError, match="slot_len"):
        engine.init_paged_cache(cfg, num_blocks=4, block_size=8)
    pools = engine.init_paged_cache(cfg, num_blocks=4, block_size=8,
                                    slot_len=SLOT_LEN)
    assert isinstance(pools, list) and pools


# ---------------------------------------------------------------------------
# Prefix sharing + copy-on-write.
# ---------------------------------------------------------------------------
def _shared_prefix_requests(vocab=512, seed=3):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, 2 * BLOCK + 2)   # 2 full blocks + 2 tail
    return prefix, [
        scheduler.Request(rid=0,
                          prompt=np.concatenate([prefix,
                                                 rng.integers(0, vocab, 5)]),
                          max_new_tokens=6, arrival_tick=0),
        scheduler.Request(rid=1,
                          prompt=np.concatenate([prefix,
                                                 rng.integers(0, vocab, 3)]),
                          max_new_tokens=6, arrival_tick=1),
        scheduler.Request(rid=2, prompt=prefix.copy(),   # identical prompt
                          max_new_tokens=6, arrival_tick=2),
    ]


def test_prefix_sharing_shares_blocks_and_diverges_after_cow(model):
    """The acceptance scenario: overlapping requests with a common prompt
    prefix share physical blocks (measured in block accounting), the
    divergence block is copy-on-write'd, and every stream still equals the
    request running alone."""
    params, cfg = model
    _, requests = _shared_prefix_requests()
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=3, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=BLOCK)
    report = sched.run(requests)
    stats = report.paged
    assert stats["blocks_shared"] >= 4          # 2 full blocks × 2 adopters
    assert stats["cow_copies"] >= 2             # each adopter CoWs the tail
    assert stats["tokens_reused"] >= 4 * BLOCK
    by_rid = {r.rid: r for r in report.results}
    for req in requests:
        want = _single_sequence_decode(params, cfg, req)
        assert by_rid[req.rid].tokens == want, (
            f"request {req.rid} diverged under prefix sharing")
    assert (stats["free_blocks"] + stats["cached_blocks"]
            == stats["num_blocks"])


def test_shared_blocks_reduce_pool_pressure(model):
    """Free-block measurement: serving the same prompt twice concurrently
    must consume fewer blocks than two disjoint prompts."""
    params, cfg = model
    rng = np.random.default_rng(9)
    common = rng.integers(0, 512, 2 * BLOCK + 1)

    def min_free(prompts):
        sched = scheduler.ContinuousScheduler(
            params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
            top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=BLOCK)
        reqs = [scheduler.Request(rid=i, prompt=p, max_new_tokens=8,
                                  arrival_tick=i)
                for i, p in enumerate(prompts)]
        return sched.run(reqs).paged["min_free_blocks"]

    shared = min_free([common, common.copy()])
    disjoint = min_free([common, rng.integers(0, 512, 2 * BLOCK + 1)])
    assert shared > disjoint        # the adopted full blocks were not re-alloc'd


def test_decode_tick_does_not_corrupt_inflight_prefill_blocks(model):
    """Regression (cache-content, not token-stream, sensitivity): a batched
    decode step writes position ``lens``=0 through every non-active row.  A
    mid-prefill row already has a REAL block table installed, so its rows
    must be masked to the sentinel for the decode — otherwise the garbage
    write lands at position 0 of the request's first (possibly shared)
    block.  Token streams can mask this through top-k sampling; the pool's
    block contents cannot."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=2, slot_len=24, block_size=8)
    rng = np.random.default_rng(17)
    pa, pb = rng.integers(0, 512, 9), rng.integers(0, 512, 12)
    # A: fully prefilled and decoding
    sa = pool.admit(pa)
    last, pool.caches, ln_a = engine.prefill_chunk_paged(
        params, pool.caches, pool.device_row(sa.slot),
        jnp.asarray(0, jnp.int32), jnp.asarray(pa)[None], cfg)
    pool.finalize_prefill(sa)
    pool.lens = pool.lens.at[sa.slot].set(int(ln_a))
    # B: first chunk written, prefill still in flight (lens stays 0)
    sb = pool.admit(pb)
    _, pool.caches, ln_b = engine.prefill_chunk_paged(
        params, pool.caches, pool.device_row(sb.slot),
        jnp.asarray(0, jnp.int32), jnp.asarray(pb[:7])[None], cfg)
    snapshot = [np.asarray(leaf[:, bid])
                for bid in sb.blocks
                for leaf in jax.tree.leaves(pool.caches[0])]
    # one interleaved decode tick over the pool: only A is active
    assert pool.prepare_write(sa.slot, int(ln_a))
    tok, pool.caches, new_lens = engine.decode_step_paged(
        params, pool.caches, pool.device_tables(active_slots=[sa.slot]),
        pool.lens, jnp.asarray([[3], [0]], jnp.int32), cfg,
        rngs=jnp.stack([_key(0, 1), _key(1, 0)]), top_k=TOP_K)
    after = [np.asarray(leaf[:, bid])
             for bid in sb.blocks
             for leaf in jax.tree.leaves(pool.caches[0])]
    for want, got in zip(snapshot, after):
        np.testing.assert_array_equal(want, got)
    # and B's finished cache equals the solo chunked prefill, bit for bit
    _, pool.caches, ln_b = engine.prefill_chunk_paged(
        params, pool.caches, pool.device_row(sb.slot), ln_b,
        jnp.asarray(pb[7:])[None], cfg)
    _, solo_caches, _ = engine.chunked_prefill(
        params, jnp.asarray(pb)[None], cfg, max_len=24, chunk=7)
    kb = np.asarray(jax.tree.leaves(pool.caches[0])[0])      # [L, P, H, BS, D]
    ks = np.asarray(jax.tree.leaves(solo_caches[0])[0])      # [L, 1, S, H, D]
    for j, bid in enumerate(sb.blocks):
        for pos in range(8):
            abs_pos = j * 8 + pos
            if abs_pos >= len(pb):
                break
            np.testing.assert_array_equal(
                kb[:, bid, :, pos], ks[:, 0, abs_pos],
                err_msg=f"K mismatch at position {abs_pos}")


def test_block_aligned_prompt_shares_final_block(model):
    """An identical block-aligned prompt must adopt every block: k-1 full
    blocks read-only plus the last one copy-on-write (the cap rule keeps one
    token to prefill locally) — no re-prefill of a whole block."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=2, slot_len=32, block_size=8)
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, 512, 16)            # exactly 2 blocks
    sa = pool.admit(prompt)
    pool.finalize_prefill(sa)
    sb = pool.admit(prompt.copy())
    assert sb.blocks[0] == sa.blocks[0]          # full block shared
    assert sb.blocks[1] != sa.blocks[1]          # last block CoW'd, not shared
    assert sb.matched == 15                      # only the final token prefills
    assert pool.cow_copies == 1
    assert pool.alloc.refcount(sa.blocks[0]) == 2
    assert pool.alloc.refcount(sa.blocks[1]) == 1


# ---------------------------------------------------------------------------
# Eviction block accounting (the satellite regression).
# ---------------------------------------------------------------------------
def test_eviction_returns_nonshared_blocks_same_tick(model):
    """Pool-level regression with a full pool and a shared prefix: releasing
    an evicted sequence frees exactly its non-shared blocks immediately and
    never frees a block whose refcount > 1."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=2, slot_len=16, block_size=4,
                           num_blocks=5)
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, 512, 8)            # 2 full blocks at bs=4
    pa = np.concatenate([prefix, rng.integers(0, 512, 2)])   # 3 blocks total
    sa = pool.admit(pa)
    assert sa is not None and len(sa.blocks) == 3
    pool.finalize_prefill(sa)
    sb = pool.admit(np.concatenate([prefix, rng.integers(0, 512, 1)]))
    assert sb is not None
    assert sb.blocks[:2] == sa.blocks[:2]       # full prefix blocks shared
    assert sb.matched >= 8
    assert pool.alloc.refcount(sa.blocks[0]) == 2
    assert pool.free_blocks == 1
    # A grows into the last free block; B's next boundary crossing starves
    assert pool.prepare_write(sa.slot, 12)      # A: new block → free = 0
    assert pool.free_blocks == 0
    assert not pool.prepare_write(sb.slot, 12)  # B: out of blocks → evict
    before = pool.free_blocks
    pool.release(sb.slot)                       # same-tick release
    # B held 2 shared (survive: refcount was 2) + 1 private (freed)
    assert pool.free_blocks == before + 1
    assert pool.alloc.refcount(sa.blocks[0]) == 1
    assert pool.alloc.refcount(sa.blocks[1]) == 1
    pool.alloc.check_invariants()
    # A is untouched and can now take the freed block
    assert pool.prepare_write(sa.slot, 16 - 1)
    pool.release(sa.slot)
    pool.alloc.check_invariants()
    # everything back, no leak: A's indexed prompt blocks park in the
    # persistent prefix cache, its decode-growth block is freed outright
    assert pool.free_blocks + pool.cached_blocks == 5
    assert pool.free_blocks == 2 and pool.cached_blocks == 3


def test_scheduler_evicts_on_block_exhaustion_and_recovers(model):
    """End-to-end: a pool too small for the workload evicts (flagged) but
    serves every request, and the free list drains back to full — blocks
    freed by eviction are re-admitted in the same tick."""
    params, cfg = model
    rng = np.random.default_rng(5)
    requests = [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 9),
                                  max_new_tokens=20, arrival_tick=0)
                for i in range(4)]
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=32, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=8,
        num_blocks=5)                           # 2 seqs need 3 each + growth
    report = sched.run(requests)
    assert len(report.results) == 4
    assert any(r.evicted for r in report.results)
    for r in report.results:                    # evicted still produced tokens
        assert len(r.tokens) >= 1
    assert (report.paged["free_blocks"] + report.paged["cached_blocks"]
            == report.paged["num_blocks"])
    assert report.paged["min_free_blocks"] == 0


# ---------------------------------------------------------------------------
# Pallas preference (interpret on CI) through the paged engine steps.
# ---------------------------------------------------------------------------
def test_paged_prefill_correct_under_pallas_preference(model):
    """One paged prefill chunk at a nonzero offset under use_pallas must
    match the XLA gather fallback — the kernel-routing twin of the PR-3
    offset-prefill test."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=1, slot_len=24, block_size=8)
    prompt = jnp.asarray(np.arange(12)[None] % 512)
    seq = pool.admit(np.asarray(prompt[0]))
    table = pool.device_row(seq.slot)
    ln = jnp.asarray(0, jnp.int32)
    _, caches, ln = engine.prefill_chunk_paged(
        params, pool.caches, table, ln, prompt[:, :7], cfg)
    ref_last, _, _ = engine.prefill_chunk_paged(
        params, caches, table, ln, prompt[:, 7:], cfg)
    got_last, _, _ = engine.prefill_chunk_paged(
        params, caches, table, ln, prompt[:, 7:],
        cfg.replace(use_pallas=True))
    np.testing.assert_allclose(np.asarray(got_last), np.asarray(ref_last),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# CI tooling: serve CLI + benchmark harness exercise the paged path.
# ---------------------------------------------------------------------------
def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    return env


def test_serve_cli_paged_smoke():
    """`python -m repro.launch.serve --smoke --continuous --paged` reports
    tok/s, occupancy, and blocks saved by sharing."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--continuous", "--paged", "--requests", "5", "--tokens", "8",
         "--prompt-len", "10", "--slots", "2", "--rate", "3.0",
         "--prefill-chunk", "8", "--block-size", "8", "--shared-prefix", "8"],
        env=_env(), capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "tok/s" in out.stdout
    assert "batch occupancy" in out.stdout
    assert "blocks saved by sharing:" in out.stdout
    saved = int(out.stdout.split("blocks saved by sharing:")[1].split()[0])
    assert saved > 0, out.stdout               # the shared prefix deduplicated


def test_benchmarks_serving_paged_records_json(tmp_path):
    """`benchmarks/run.py serving --paged --json` lands the paged rows —
    same names as the slot-pool run (so `report` diffs them) plus the
    block-sharing accounting."""
    import json
    json_path = str(tmp_path / "paged.json")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--smoke", "serving", "--paged", "--json", json_path],
        env=_env(), capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    with open(json_path) as f:
        data = json.load(f)
    rows = {r["name"]: r for r in data["rows"]}
    assert {"serving/smoke/per_token", "serving/smoke/occupancy_pct",
            "serving/smoke/blocks_shared"} <= set(rows)
    assert rows["serving/smoke/blocks_shared"]["us_per_call"] > 0
    assert "cow=" in rows["serving/smoke/blocks_shared"]["derived"]
