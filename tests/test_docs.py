"""Doc-freshness CI (ISSUE 5): documentation cannot silently rot.

Two mechanisms:

* Fenced ``sh``/``python`` blocks in README.md and docs/*.md that carry a
  ``<!-- doctest -->`` marker are extracted and actually executed here —
  a renamed flag, moved module, or changed API breaks tier-1, not a
  reader.
* Every module named in docs/architecture.md (backticked or in the
  dataflow diagram, ``repro.x.y`` dotted form) must resolve to a real file
  or package under src/ — the module map stays truthful.
"""
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = [os.path.join(REPO, "README.md")] + sorted(
    os.path.join(REPO, "docs", f)
    for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md"))

_BLOCK_RE = re.compile(
    r"<!--\s*doctest\s*-->\s*\n```(sh|python)\n(.*?)```", re.S)


def _doctest_blocks():
    out = []
    for path in DOCS:
        with open(path) as f:
            text = f.read()
        for i, m in enumerate(_BLOCK_RE.finditer(text)):
            out.append((f"{os.path.basename(path)}#{i}",
                        m.group(1), m.group(2)))
    return out


BLOCKS = _doctest_blocks()


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    return env


def test_docs_exist_and_carry_doctests():
    """README + the three docs pages exist and the doc-freshness net has
    something to hold on to (the ISSUE 5 acceptance surface)."""
    names = {os.path.basename(p) for p in DOCS}
    assert {"README.md", "architecture.md", "serving.md",
            "kernels.md"} <= names
    assert len(BLOCKS) >= 4, [b[0] for b in BLOCKS]


@pytest.mark.parametrize("name,lang,body", BLOCKS,
                         ids=[b[0] for b in BLOCKS])
def test_doc_command_runs(name, lang, body):
    if lang == "python":
        out = subprocess.run([sys.executable, "-c", body], env=_env(),
                             capture_output=True, text=True, timeout=600,
                             cwd=REPO)
        assert out.returncode == 0, (
            f"{name} failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
        return
    # sh: line-continuation-aware, one subprocess per command line
    for cmd in re.sub(r"\\\n", " ", body).strip().splitlines():
        cmd = cmd.strip()
        if not cmd or cmd.startswith("#"):
            continue
        out = subprocess.run(cmd, shell=True, env=_env(),
                             capture_output=True, text=True, timeout=600,
                             cwd=REPO)
        assert out.returncode == 0, (
            f"{name}: `{cmd}` failed:\n"
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")


_MODULE_RE = re.compile(r"\brepro(?:\.[a-z0-9_]+)+\b")


def test_architecture_doc_modules_exist():
    """Every ``repro.x.y`` dotted name in docs/architecture.md must be a
    real module (file) or package (directory) under src/."""
    with open(os.path.join(REPO, "docs", "architecture.md")) as f:
        text = f.read()
    mods = sorted(set(_MODULE_RE.findall(text)))
    assert len(mods) >= 10, "architecture.md should name the module map"
    missing = []
    for mod in mods:
        rel = mod.replace(".", os.sep)
        as_file = os.path.join(REPO, "src", rel + ".py")
        as_pkg = os.path.join(REPO, "src", rel)
        if not (os.path.isfile(as_file) or os.path.isdir(as_pkg)):
            missing.append(mod)
    assert not missing, f"docs/architecture.md names missing modules: {missing}"
