"""Multi-device tests (8 host CPU devices via a subprocess so the main
pytest process stays single-device, per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_DRYRUN_DEVICES"] = str(devices)
    out = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                         capture_output=True, text=True)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_decode_attention_and_topk():
    run_py("""
import jax, jax.numpy as jnp, numpy as np, jax.random as jr
mesh = jax.make_mesh((2, 4), ('data', 'model'))
from repro.distributed.decode_attention import sharded_decode_attention, sharded_topk_sample
from repro.kernels import ref
from repro import core
B,S,Hq,Hkv,D = 4, 64, 8, 2, 16
ks = jr.split(jr.PRNGKey(0), 4)
q = jr.normal(ks[0], (B,1,Hq,D)); kc = jr.normal(ks[1], (B,S,Hkv,D)); vc = jr.normal(ks[2], (B,S,Hkv,D))
vlen = jnp.array([64, 40, 17, 1], jnp.int32)
with mesh:
    o = sharded_decode_attention(q, kc, vc, vlen, mesh=mesh, seq_axes=('model',), batch_axes=('data',), chunk_size=16, scale=D**-0.5)
np.testing.assert_allclose(np.asarray(o), np.asarray(ref.attention_ref(q, kc, vc, causal=False, kv_valid_len=vlen)), rtol=2e-5, atol=2e-5)
logits = jr.normal(ks[3], (B, 512)) * 4
with mesh:
    tok, probs = sharded_topk_sample(jr.PRNGKey(7), logits, 5, mesh=mesh, batch_axes=('data',))
st = core.softmax_topk(logits, 5)
np.testing.assert_allclose(np.asarray(probs), np.asarray(st.values), rtol=1e-5, atol=1e-6)
print('OK')
""")


def test_int8_allreduce():
    run_py("""
import jax, jax.numpy as jnp, numpy as np
mesh = jax.make_mesh((8,), ('data',))
from repro.distributed.compression import int8_allreduce
x = jnp.linspace(-2, 2, 1024)
with mesh:
    y = int8_allreduce(x, mesh, 'data')
np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=2e-2)
print('OK')
""")


def test_sharded_train_step_matches_single_device():
    """The pjit'd train step on a 2x4 mesh must produce the same params as
    the unsharded step (same batch, same init)."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
import repro.configs as configs
from repro.configs.base import OptimizerConfig, ParallelConfig, RunConfig
from repro.data.synthetic import SyntheticConfig, SyntheticDataset
from repro.distributed import context, sharding
from repro.training.train_step import init_state, make_train_step
cfg = configs.get_smoke('smollm_360m')
run = RunConfig(model=cfg, optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0, schedule='constant'),
                parallel=ParallelConfig(grad_reduce_dtype='float32'))
params, opt, axes = init_state(run, jax.random.PRNGKey(0))
ds = SyntheticDataset(SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
batch = jax.tree.map(jnp.asarray, ds.batch(0))
# single device
p1, _, m1 = make_train_step(run)(jax.tree.map(jnp.copy, params), jax.tree.map(jnp.copy, opt), batch)
# sharded
mesh = jax.make_mesh((2, 4), ('data', 'model'))
par = sharding.derive_parallel(cfg, mesh, run.parallel)
p_sh = sharding.param_sharding(axes, cfg, par, mesh)
params_s = jax.device_put(params, p_sh)
ctx = context.ShardContext(mesh=mesh, par=par)
with mesh, context.use(ctx):
    step = jax.jit(make_train_step(run))
    p2, _, m2 = step(params_s, opt, batch)
assert abs(float(m1['loss']) - float(m2['loss'])) < 5e-3, (float(m1['loss']), float(m2['loss']))
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=3e-3)
print('OK loss', float(m1['loss']))
""")


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen2-moe-a2.7b",
                                  "zamba2-1.2b"])
def test_dryrun_smoke_cells(arch):
    """Every builder path lowers+compiles on a small mesh (smoke configs)."""
    run_py(f"""
import jax
from repro.launch import dryrun
mesh = jax.make_mesh((2, 4), ('data', 'model'))
for shape in ('train_4k', 'prefill_32k', 'decode_32k'):
    rec = dryrun.run_cell({arch!r}, shape, multi_pod=False, mesh=mesh,
                          smoke=True, verbose=False)
    assert rec['status'] == 'ok', (shape, rec)
    assert rec['hlo_flops_per_device'] > 0
    assert rec['collective_bytes_per_device'] >= 0
print('OK')
""")


def test_dryrun_multipod_smoke():
    run_py("""
import jax
from repro.launch import dryrun
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
rec = dryrun.run_cell('smollm-360m', 'train_4k', multi_pod=True, mesh=mesh,
                      smoke=True, verbose=False)
assert rec['status'] == 'ok'
rec = dryrun.run_cell('xlstm-125m', 'long_500k', multi_pod=True, mesh=mesh,
                      smoke=True, verbose=False)
assert rec['status'] == 'ok'
print('OK')
""")


def test_elastic_reshard_restore():
    """Save sharded on a 2x4 mesh, restore onto 4x2 — elastic scaling."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
import repro.configs as configs
from repro.checkpoint.manager import CheckpointManager
from repro.distributed import sharding
from repro.training.train_step import init_state
from repro.configs.base import RunConfig
import tempfile
cfg = configs.get_smoke('smollm_360m')
run = RunConfig(model=cfg)
params, _, axes = init_state(run, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mesh1 = jax.make_mesh((2, 4), ('data', 'model'))
par1 = sharding.derive_parallel(cfg, mesh1)
sh1 = sharding.param_sharding(axes, cfg, par1, mesh1)
p1 = jax.device_put(params, sh1)
mgr.save(1, {'params': p1}, blocking=True)
mesh2 = jax.make_mesh((4, 2), ('data', 'model'))
par2 = sharding.derive_parallel(cfg, mesh2)
sh2 = sharding.param_sharding(axes, cfg, par2, mesh2)
like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), {'params': params})
restored = mgr.restore(1, like, shardings={'params': sh2})
for a, b in zip(jax.tree.leaves(restored['params']), jax.tree.leaves(params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('OK')
""")
