"""SLO-aware scheduling: priority admission, paged preempt-and-swap, and
the persistent prefix cache (ISSUE 5).

The load-bearing claims:

* Admission orders by (priority, arrival); with one priority class the
  scheduler degenerates to the PR-2 FIFO (pinned by the untouched
  continuous/paged equivalence suites).
* **Preempt-and-resume is bit-identical**: a request swapped out mid-decode
  (``PagedPool.swap_out`` → host store → ``swap_in``) produces exactly the
  token stream of the never-preempted run — across arrival orders and
  pool-pressure levels.
* Shared prefix blocks survive preemption **without copy-out**: the
  suspended sequence keeps its reference; only exclusively-owned blocks
  round-trip through the host.
* The prefix index is persistent: entries outlive their last sequence (a
  later identical prompt adopts cached blocks with no live overlap), and
  LRU reclamation feeds the free list under pressure — BEFORE live work is
  preempted or evicted.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import engine, paged, scheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SLOT_LEN = 48
BLOCK = 8
CHUNK = 8
TOP_K = 5
BASE_RNG = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("smollm_360m")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    return params, cfg


def _key(rid, step):
    return jax.random.fold_in(jax.random.fold_in(BASE_RNG, rid), step)


def _single_sequence_decode(params, cfg, req):
    """The request alone — what every stream must reproduce bit-for-bit."""
    last, caches, ln = engine.chunked_prefill(
        params, jnp.asarray(req.prompt)[None], cfg, max_len=SLOT_LEN,
        chunk=CHUNK)
    logits = engine.logits_from_hidden(params, last, cfg)
    tok = engine.sample_per_slot(_key(req.rid, 0)[None], logits, TOP_K)
    tokens = [int(tok[0])]
    lens = jnp.asarray([int(ln)], jnp.int32)
    for step in range(1, req.max_new_tokens):
        tok, caches, lens = engine.decode_step_slots(
            params, caches, lens, tok[:, None], cfg,
            rngs=_key(req.rid, step)[None], top_k=TOP_K)
        tokens.append(int(tok[0]))
    return tokens


def _sched(params, cfg, **kw):
    base = dict(num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
                top_k=TOP_K, base_rng=BASE_RNG, paged=True,
                block_size=BLOCK)
    base.update(kw)
    return scheduler.ContinuousScheduler(params, cfg, **base)


# ---------------------------------------------------------------------------
# Priority admission ordering.
# ---------------------------------------------------------------------------
def test_priority_orders_admission(model):
    """Two requests waiting at the same tick with one slot: the urgent one
    (smaller priority value) is admitted — and therefore finishes — first,
    even though the background one was submitted earlier."""
    params, cfg = model
    rng = np.random.default_rng(0)
    reqs = [
        scheduler.Request(rid=0, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=3, priority=5),
        scheduler.Request(rid=1, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=3, priority=0),
    ]
    sched = _sched(params, cfg, num_slots=1)
    report = sched.run(reqs)
    assert [r.rid for r in report.results] == [1, 0]
    assert report.preemptions == 0          # ordering, not preemption


def test_single_class_degenerates_to_fifo(model):
    """All-default priorities reproduce the PR-2 FIFO completion order."""
    params, cfg = model
    rng = np.random.default_rng(1)
    reqs = [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 5),
                              max_new_tokens=2) for i in range(3)]
    report = _sched(params, cfg, num_slots=1).run(reqs)
    assert [r.rid for r in report.results] == [0, 1, 2]


# ---------------------------------------------------------------------------
# Preempt-and-resume bit-identity (the acceptance pin).
# ---------------------------------------------------------------------------
def _priority_workload(pattern):
    """Low-priority long decodes first, urgent work landing mid-flight."""
    rng = np.random.default_rng(11)
    lo = [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 9 + 2 * i),
                            max_new_tokens=12, arrival_tick=0, priority=1)
          for i in range(2)]
    hi_arrivals = {"early": 3, "mid": 5, "late": 8}[pattern]
    hi = [scheduler.Request(rid=2, prompt=rng.integers(0, 512, 8),
                            max_new_tokens=4, arrival_tick=hi_arrivals,
                            priority=0)]
    return lo + hi


@pytest.mark.parametrize("pattern", ["early", "mid", "late"])
@pytest.mark.parametrize("num_blocks", [None, 8])
def test_preempt_and_resume_bit_identical(model, pattern, num_blocks):
    """A low-priority decode swapped out for an urgent arrival — under row
    pressure (full pool default) AND block pressure (undersized pool) —
    resumes with exactly the token stream of the never-preempted run."""
    params, cfg = model
    requests = _priority_workload(pattern)
    sched = _sched(params, cfg, num_blocks=num_blocks)
    report = sched.run(requests)
    assert len(report.results) == len(requests)
    assert report.preemptions >= 1, "workload must actually preempt"
    assert any(r.preempted for r in report.results)
    by_rid = {r.rid: r for r in report.results}
    for req in requests:
        got = by_rid[req.rid]
        assert got.tokens == _single_sequence_decode(params, cfg, req), (
            f"request {req.rid} diverged (pattern={pattern}, "
            f"num_blocks={num_blocks}, preempted={got.preempted})")
        assert len(got.tokens) == req.max_new_tokens
        assert not got.evicted              # preemption is not eviction
    stats = report.paged
    assert stats["swapped_blocks_out"] >= 1
    assert stats["swapped_blocks_in"] == stats["swapped_blocks_out"]
    assert (stats["free_blocks"] + stats["cached_blocks"]
            == stats["num_blocks"])


def test_preempt_disabled_never_swaps(model):
    """``preempt=False``: the same contended workload serves strictly by
    priority ordering — zero preemptions, everyone still completes."""
    params, cfg = model
    requests = _priority_workload("mid")
    report = _sched(params, cfg, preempt=False).run(requests)
    assert report.preemptions == 0
    assert report.paged["swapped_blocks_out"] == 0
    assert len(report.results) == len(requests)
    by_rid = {r.rid: r for r in report.results}
    for req in requests:
        assert by_rid[req.rid].tokens == _single_sequence_decode(
            params, cfg, req)


def test_equal_priority_never_preempts(model):
    """Preemption requires a STRICTLY lower-priority victim: a same-class
    backlog runs exactly like the PR-4 scheduler."""
    params, cfg = model
    rng = np.random.default_rng(4)
    reqs = [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 8),
                              max_new_tokens=6, arrival_tick=i, priority=3)
            for i in range(4)]
    report = _sched(params, cfg).run(reqs)
    assert report.preemptions == 0


# ---------------------------------------------------------------------------
# Swap mechanics: shared blocks survive in place, exclusive blocks
# round-trip bit-exactly.
# ---------------------------------------------------------------------------
def test_swap_preserves_shared_blocks_without_copyout(model):
    """Two sequences share a 2-block prompt prefix; swapping one out must
    keep the shared blocks resident by reference (no host copy, no free)
    and copy out only the exclusive tail."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=2, slot_len=SLOT_LEN,
                           block_size=BLOCK)
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, 512, 2 * BLOCK)
    pa = np.concatenate([prefix, rng.integers(0, 512, 3)])
    pb = np.concatenate([prefix, rng.integers(0, 512, 5)])
    sa = pool.admit(pa)
    _, pool.caches, ln_a = engine.prefill_chunk_paged(
        params, pool.caches, pool.device_row(sa.slot),
        jnp.asarray(0, jnp.int32), jnp.asarray(pa)[None], cfg)
    pool.finalize_prefill(sa)
    pool.lens = pool.lens.at[sa.slot].set(int(ln_a))
    sb = pool.admit(pb)
    assert sb.blocks[:2] == sa.blocks[:2]       # prefix adopted
    shared_ids = list(sb.blocks[:2])
    free_before = pool.free_blocks
    rec = pool.swap_out(sb.slot, rid=77)
    kinds = [e[0] for e in rec.entries]
    assert kinds[:2] == ["shared", "shared"]    # never copied out
    assert "host" in kinds[2:]                  # the exclusive tail was
    assert [e[1] for e in rec.entries[:2]] == shared_ids
    for bid in shared_ids:                      # still live, still shared
        assert pool.alloc.refcount(bid) == 2
    assert pool.swapped_blocks_out == kinds.count("host")
    # exactly the exclusive blocks were freed
    assert pool.free_blocks == free_before + kinds.count("host")
    pool.alloc.check_invariants()
    # resume restores the table against the SAME shared physical blocks
    sb2 = pool.swap_in(77)
    assert sb2 is not None
    assert sb2.blocks[:2] == shared_ids
    assert 77 not in pool.swapped


def test_swap_roundtrip_restores_cache_content_bitexact(model):
    """Pool-level: swap_out → swap_in reproduces the exact cache bytes of
    an exclusively-owned block (the host round-trip is lossless)."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=2, slot_len=24, block_size=8)
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, 512, 11)
    seq = pool.admit(prompt)
    _, pool.caches, ln = engine.prefill_chunk_paged(
        params, pool.caches, pool.device_row(seq.slot),
        jnp.asarray(0, jnp.int32), jnp.asarray(prompt)[None], cfg)
    pool.finalize_prefill(seq)
    pool.lens = pool.lens.at[seq.slot].set(int(ln))
    want = [np.asarray(leaf[:, bid]) for bid in seq.blocks
            for leaf in jax.tree.leaves(pool.caches[0])]
    old_blocks = list(seq.blocks)
    pool.swap_out(seq.slot, rid=5)
    assert int(np.asarray(pool.lens)[seq.slot]) == 0
    s2 = pool.swap_in(5)
    assert s2 is not None
    assert int(np.asarray(pool.lens)[s2.slot]) == int(ln)
    got = [np.asarray(leaf[:, bid]) for bid in s2.blocks
           for leaf in jax.tree.leaves(pool.caches[0])]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    del old_blocks
    pool.alloc.check_invariants()


# ---------------------------------------------------------------------------
# Persistent prefix cache: entries outlive their sequence; LRU reclaim.
# ---------------------------------------------------------------------------
def test_prefix_entries_outlive_their_sequence(model):
    """A second, identical prompt with NO temporal overlap adopts the
    retired sequence's cached blocks (prefill skipped) and still produces
    the cold-run token stream."""
    params, cfg = model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 512, 2 * BLOCK + 2)
    sched = _sched(params, cfg)
    r1 = sched.run([scheduler.Request(rid=0, prompt=prompt,
                                      max_new_tokens=4)])
    assert r1.paged["cached_blocks"] >= 2       # prompt blocks parked
    assert r1.paged["prefix_cache_hits"] == 0
    r2 = sched.run([scheduler.Request(rid=1, prompt=prompt.copy(),
                                      max_new_tokens=4)])
    assert r2.paged["prefix_cache_hits"] >= 2   # revived with no live holder
    assert r2.paged["tokens_reused"] >= 2 * BLOCK
    want = _single_sequence_decode(
        params, cfg, scheduler.Request(rid=1, prompt=prompt,
                                       max_new_tokens=4))
    assert [r for r in r2.results if r.rid == 1][0].tokens == want


def test_lru_reclaim_feeds_free_list_under_pressure(model):
    """Cold cached blocks are reclaimed (LRU-first) when admission runs
    short — persistence never costs an admission."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=2, slot_len=16, block_size=4,
                           num_blocks=5)
    rng = np.random.default_rng(21)
    sa = pool.admit(rng.integers(0, 512, 8))    # 3 blocks (prompt+decode)
    pool.finalize_prefill(sa)
    pool.release(sa.slot)
    # the two full prompt blocks park; the decode-only block frees outright
    assert pool.cached_blocks == 2 and pool.free_blocks == 3
    # a disjoint prompt needing 4 blocks: must reclaim a cached block
    sb = pool.admit(rng.integers(0, 512, 15))
    assert sb is not None
    assert pool.reclaimed_blocks >= 1
    assert pool.cached_blocks <= 1
    pool.alloc.check_invariants()


def test_reclaim_skips_cache_blocks_held_by_live_sequences(model):
    """Reclaiming a cached block a live sequence still references frees
    nothing — it must be skipped (keeping its index entries and cache
    residency) rather than sacrificed for zero capacity."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=3, slot_len=16, block_size=4,
                           num_blocks=6)
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, 512, 9)            # 2 full blocks + 1 tail
    sa = pool.admit(prompt)
    pool.finalize_prefill(sa)
    pool.release(sa.slot)
    assert pool.cached_blocks == 3              # all three blocks indexed
    sb = pool.admit(prompt.copy())              # revives the two full blocks
    b0, b1 = sb.blocks[0], sb.blocks[1]
    assert pool.alloc.refcount(b0) == pool.alloc.refcount(b1) == 2
    # an admission that would need every cached block: the two live-held
    # blocks cannot yield a free block and must survive the reclaim sweep
    assert pool.admit(rng.integers(0, 512, 15)) is None
    assert pool.index.has_block(b0) and pool.index.has_block(b1)
    assert b0 in pool._cached and b1 in pool._cached
    assert pool.alloc.refcount(b0) == 2
    pool.alloc.check_invariants()


def test_persistent_prefix_off_restores_pr4_lifecycle(model):
    """``persistent_prefix=False``: release frees everything; the index
    entry dies with the block."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=1, slot_len=16, block_size=4,
                           persistent_prefix=False)
    seq = pool.admit(np.arange(9) % 512)
    pool.finalize_prefill(seq)
    pool.release(seq.slot)
    assert pool.cached_blocks == 0
    assert pool.free_blocks == pool.alloc.num_blocks - 1
    assert len(pool.index) == 0


def test_reclaim_runs_before_preemption(model):
    """Swap/evict ordering (ISSUE 5): when cold cached blocks can satisfy
    an urgent admission, live lower-priority work is NOT preempted."""
    params, cfg = model
    rng = np.random.default_rng(6)
    sched = _sched(params, cfg, num_slots=2, num_blocks=5)
    # phase 1: a background request retires, leaving 2 cached prompt blocks
    # (its decode-growth block frees outright) → free=3, cached=2
    sched.run([scheduler.Request(rid=0, prompt=rng.integers(0, 512, 16),
                                 max_new_tokens=2, priority=1)])
    assert sched.pool.cached_blocks >= 2
    # phase 2: one background decode holds 2 blocks (free=1); the urgent
    # arrival needs 2 — short on the free list, covered by free+cached.
    # Neither request outgrows its blocks, so admission is the only
    # pressure event.
    reqs = [
        scheduler.Request(rid=1, prompt=rng.integers(0, 512, 9),
                          max_new_tokens=4, priority=1),
        scheduler.Request(rid=2, prompt=rng.integers(0, 512, 14),
                          max_new_tokens=2, arrival_tick=4, priority=0),
    ]
    report = sched.run(reqs)
    assert report.preemptions == 0, \
        "cache reclamation must satisfy the urgent admission first"
    assert sched.pool.reclaimed_blocks >= 1
    by_rid = {r.rid: r for r in report.results}
    assert len(by_rid[2].tokens) == 2


# ---------------------------------------------------------------------------
# Deadline-aware admission and victim selection (slo_ms drives scheduling,
# not just scoring).
# ---------------------------------------------------------------------------
def test_deadline_orders_admission_within_class(model):
    """Same priority class, one slot, all waiting at the same tick: the
    tightest deadline places first, deadline-bearing work outranks
    deadline-free peers, and the deadline-free request goes last."""
    params, cfg = model
    rng = np.random.default_rng(21)
    reqs = [
        scheduler.Request(rid=0, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=2),                    # no deadline
        scheduler.Request(rid=1, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=2, slo_ms=60_000.0),   # loose
        scheduler.Request(rid=2, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=2, slo_ms=500.0),      # tight
    ]
    report = _sched(params, cfg, num_slots=1).run(reqs)
    assert [r.rid for r in report.results] == [2, 1, 0]


def test_preemption_prefers_deadline_free_victim(model):
    """Among equal-priority victims the swap-out falls on the deadline-free
    decode — even when the deadline-bearing one has more decode remaining
    (which the pre-deadline longest-remaining rule would have chosen)."""
    params, cfg = model
    rng = np.random.default_rng(11)
    reqs = [
        scheduler.Request(rid=0, prompt=rng.integers(0, 512, 9),
                          max_new_tokens=14, arrival_tick=0, priority=1,
                          slo_ms=60_000.0),     # longest remaining, deadline
        scheduler.Request(rid=1, prompt=rng.integers(0, 512, 11),
                          max_new_tokens=10, arrival_tick=0, priority=1),
        scheduler.Request(rid=2, prompt=rng.integers(0, 512, 8),
                          max_new_tokens=4, arrival_tick=5, priority=0),
    ]
    report = _sched(params, cfg).run(reqs)
    assert report.preemptions >= 1, "urgent arrival must preempt"
    by_rid = {r.rid: r for r in report.results}
    assert by_rid[1].preempted >= 1             # the deadline-free victim
    assert by_rid[0].preempted == 0             # deadline work kept running
    for req in reqs:                            # and nobody's stream moved
        assert by_rid[req.rid].tokens == _single_sequence_decode(
            params, cfg, req)


# ---------------------------------------------------------------------------
# Async swap-in prefetch: staging overlaps the decode tick, bit-exactly.
# ---------------------------------------------------------------------------
def test_prefetch_swap_in_stages_bitexact_pool_level(model):
    """Pool-level: prefetch stages every host entry exactly once (device
    copies, counted), and the subsequent swap_in restores the same cache
    bytes as the unprefetched round-trip."""
    params, cfg = model
    pool = paged.PagedPool(cfg, num_slots=2, slot_len=24, block_size=8)
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, 512, 11)
    seq = pool.admit(prompt)
    _, pool.caches, ln = engine.prefill_chunk_paged(
        params, pool.caches, pool.device_row(seq.slot),
        jnp.asarray(0, jnp.int32), jnp.asarray(prompt)[None], cfg)
    pool.finalize_prefill(seq)
    pool.lens = pool.lens.at[seq.slot].set(int(ln))
    want = [np.asarray(leaf[:, bid]) for bid in seq.blocks
            for leaf in jax.tree.leaves(pool.caches[0])]
    pool.swap_out(seq.slot, rid=5)
    hosts = sum(1 for kind, _ in pool.swapped[5].entries if kind == "host")
    assert hosts >= 1
    assert pool.prefetch_swap_in(5) == hosts
    assert pool.prefetch_swap_in(5) == 0        # idempotent: already staged
    assert pool.prefetch_swap_in(404) == 0      # unknown rid: no-op
    assert pool.swap_prefetched_blocks == hosts
    s2 = pool.swap_in(5)
    assert s2 is not None
    got = [np.asarray(leaf[:, bid]) for bid in s2.blocks
           for leaf in jax.tree.leaves(pool.caches[0])]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    pool.alloc.check_invariants()


def test_scheduler_prefetches_next_resume(model):
    """Scheduler-level: while a preempted request waits, decode ticks stage
    its host blocks ahead of the resume (the counter proves the overlap
    path ran; the bit-identity suites prove it changed nothing)."""
    params, cfg = model
    requests = _priority_workload("mid")
    report = _sched(params, cfg).run(requests)
    assert report.preemptions >= 1
    assert report.paged["swap_prefetched_blocks"] >= 1
    assert report.paged["swapped_blocks_in"] == \
        report.paged["swapped_blocks_out"]


# ---------------------------------------------------------------------------
# SLO metrics.
# ---------------------------------------------------------------------------
def test_slo_attainment_and_by_class_percentiles(model):
    params, cfg = model
    rng = np.random.default_rng(8)
    reqs = [
        scheduler.Request(rid=0, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=3, priority=0, slo_ms=1e7),
        scheduler.Request(rid=1, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=3, priority=1),
        scheduler.Request(rid=2, prompt=rng.integers(0, 512, 6),
                          max_new_tokens=3, priority=0, slo_ms=1e-6),
    ]
    report = _sched(params, cfg).run(reqs)
    # one generous deadline met, one impossible deadline missed
    assert report.slo_attainment() == pytest.approx(0.5)
    by_rid = {r.rid: r for r in report.results}
    assert by_rid[0].slo_met is True
    assert by_rid[2].slo_met is False
    assert by_rid[1].slo_met is None            # no deadline attached
    by_class = report.latency_percentiles_by_class((50, 95))
    assert set(by_class) == {0, 1}
    for pct in by_class.values():
        assert 0 < pct["p50"] <= pct["p95"]


def test_workload_generator_assigns_classes_and_deadlines():
    reqs = scheduler.poisson_workload(
        32, rate_per_tick=2.0, priority_classes=3, slo_ms=250.0, seed=2)
    prios = {r.priority for r in reqs}
    assert prios <= {0, 1, 2} and len(prios) > 1
    for r in reqs:
        if r.priority == 0:
            assert r.slo_ms == 250.0
        else:
            assert r.slo_ms is None


# ---------------------------------------------------------------------------
# CI tooling: serve CLI and benchmark harness exercise the SLO path.
# ---------------------------------------------------------------------------
def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    return env


def test_serve_cli_reports_priority_classes():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--continuous", "--paged", "--requests", "5", "--tokens", "8",
         "--prompt-len", "10", "--slots", "2", "--rate", "3.0",
         "--prefill-chunk", "8", "--block-size", "8", "--shared-prefix", "8",
         "--priority-classes", "2", "--slo-ms", "60000"],
        env=_env(), capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "class 0:" in out.stdout and "class 1:" in out.stdout
    assert "SLO attainment:" in out.stdout
    assert "prefix cache:" in out.stdout
    assert "preemptions:" in out.stdout


def test_benchmarks_serving_priorities_records_slo_rows(tmp_path):
    import json
    json_path = str(tmp_path / "prio.json")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--smoke", "serving", "--paged", "--priorities",
         "--json", json_path],
        env=_env(), capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    with open(json_path) as f:
        rows = {r["name"]: r for r in json.load(f)["rows"]}
    assert {"serving/smoke/slo_attained_pct",
            "serving/smoke/p95_latency_hipri",
            "serving/smoke/preemptions"} <= set(rows)
    assert rows["serving/smoke/preemptions"]["us_per_call"] >= 1, \
        "the mixed-priority smoke workload must actually preempt"
    assert "preempt=on" in rows["serving/smoke/slo_attained_pct"]["derived"]
