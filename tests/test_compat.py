"""Regression tests for the repro.compat portability layer and the
capability-probing kernel dispatch registry.

Three bug classes took down the seed suite (missing ``jax.shard_map``
export, ``cost_analysis()`` list-vs-dict, hard ``import hypothesis``); these
tests pin the shims against the *installed* JAX and grep-enforce the policy
that no module outside ``repro.compat`` touches those surfaces again.
"""
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat, core
from repro.kernels import dispatch

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src", "repro")


# ---------------------------------------------------------------------------
# Shim resolution on the installed JAX.
# ---------------------------------------------------------------------------
def test_shard_map_shim_resolves_and_runs():
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(lambda x: x * 2.0, mesh=mesh, in_specs=P(),
                         out_specs=P(), check_vma=False)
    np.testing.assert_allclose(np.asarray(f(jnp.arange(4.0))),
                               [0.0, 2.0, 4.0, 6.0])


def test_tree_shims_resolve_and_run():
    assert compat.TREE_SOURCE in ("jax.tree", "jax.tree_util")
    t = {"a": [1, 2], "b": 3}
    assert compat.tree_map(lambda x: x * 2, t) == {"a": [2, 4], "b": 6}
    leaves, treedef = compat.tree_flatten(t)
    assert compat.tree_leaves(t) == leaves == [1, 2, 3]
    assert compat.tree_structure(t) == treedef
    assert compat.tree_unflatten(treedef, leaves) == t
    assert compat.tree_reduce(lambda a, b: a + b, t) == 6
    # is_leaf threads through (the Param-boxing pattern in models.layers)
    pairs = compat.tree_map(lambda p: p[0], {"w": (1, "x")},
                            is_leaf=lambda x: isinstance(x, tuple))
    assert pairs == {"w": 1}


def test_named_sharding_shim_constructs():
    from jax.sharding import PartitionSpec as P
    assert compat.NAMED_SHARDING_SOURCE.startswith("jax")
    mesh = compat.make_mesh((1,), ("data",))
    s = compat.named_sharding(mesh, P("data"))
    assert s.spec == P("data")
    assert compat.named_sharding(mesh).spec == P()          # replicated
    assert compat.named_sharding(mesh, ("data", None)).spec == P("data", None)
    # it is a real sharding: jax accepts it as a device_put target
    x = jax.device_put(jnp.arange(4.0), compat.named_sharding(mesh))
    np.testing.assert_allclose(np.asarray(x), [0.0, 1.0, 2.0, 3.0])


def test_cost_analysis_always_a_dict():
    compiled = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    ca = compat.cost_analysis(compiled)
    assert isinstance(ca, dict)
    assert float(ca.get("flops", 0.0)) > 0.0


def test_capabilities_probe_is_cached_and_sane():
    caps = compat.capabilities()
    assert caps is compat.capabilities()          # one snapshot per process
    assert caps.jax_version == jax.__version__
    assert caps.backend in ("cpu", "gpu", "tpu")
    assert caps.device_count >= 1
    assert caps.cost_analysis_shape in ("dict", "list", "unavailable")
    assert caps.shard_map_source in ("jax", "jax.experimental.shard_map")
    # on a non-TPU host Pallas must resolve to interpret mode
    if caps.backend != "tpu":
        assert not caps.pallas_native and caps.pallas_interpret


# ---------------------------------------------------------------------------
# Grep-clean policy: version-sensitive surfaces only inside repro/compat.
# ---------------------------------------------------------------------------
_FORBIDDEN = (
    ("from jax import shard_map", "shard_map must come from repro.compat"),
    ("from jax.experimental.shard_map", "shard_map must come from repro.compat"),
    ("from jax.experimental import shard_map", "shard_map must come from repro.compat"),
    (".cost_analysis()", "use compat.cost_analysis(compiled)"),
    ("jax.make_mesh(", "use compat.make_mesh"),
    ("default_backend()", "use compat.backend()/pallas_interpret()"),
    # pytree namespace: jax.tree.* vs jax.tree_util.tree_* differs by version
    ("jax.tree.", "use compat.tree_map/tree_leaves/... aliases"),
    ("jax.tree_util", "use compat.tree_map/tree_leaves/... aliases"),
    # NamedSharding construction differs pre-0.4.30
    ("NamedSharding(", "use compat.named_sharding(mesh, spec)"),
)


def test_version_sensitive_surfaces_centralized():
    offenders = []
    for root, _, files in os.walk(SRC):
        if os.path.basename(root) == "compat":
            continue
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(root, name)
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    if "``" in line or line.lstrip().startswith("#"):
                        continue                      # doc mention, not a call
                    for pat, why in _FORBIDDEN:
                        if pat in line:
                            offenders.append(
                                f"{os.path.relpath(path, REPO)}:{lineno} "
                                f"[{pat!r} → {why}]")
    assert not offenders, "\n".join(offenders)


# Block-table CONSTRUCTION is the exclusive business of serving/paged.py:
# the allocator, the prefix index, and table row assembly must have exactly
# one home, or refcount bookkeeping and the sharing invariants fragment.
# Kernels, dispatch, and the engine only CONSUME tables they are handed.
_PAGED_ONLY = (
    ("BlockAllocator(", "allocate blocks via serving.paged.PagedPool"),
    ("PrefixIndex(", "prefix sharing lives in serving.paged"),
    ("PagedSeq(", "sequence block bookkeeping lives in serving.paged"),
)


def test_block_table_construction_centralized():
    offenders = []
    paged_home = os.path.join(SRC, "serving", "paged.py")
    for root, _, files in os.walk(SRC):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(root, name)
            if os.path.abspath(path) == paged_home:
                continue
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    if "``" in line or line.lstrip().startswith("#"):
                        continue
                    for pat, why in _PAGED_ONLY:
                        if pat in line:
                            offenders.append(
                                f"{os.path.relpath(path, REPO)}:{lineno} "
                                f"[{pat!r} → {why}]")
    assert not offenders, "\n".join(offenders)


# The serving loop is owned by the engine layer: outside src/repro/serving/
# nobody constructs a ContinuousScheduler or drives its ticks — the CLI,
# benchmarks, and examples all hold an Engine (or a ReplicaRouter over
# several), so the loop, its wedge guard, and its report construction exist
# exactly once.
_ENGINE_ONLY = (
    ("ContinuousScheduler(",
     "engines are built by serving.engine_api.Engine / serving.router"),
    (".tick(", "the step loop lives in serving.engine_api.Engine"),
    ("sched.run(", "batch serving is Engine.serve / ReplicaRouter.serve"),
)


def test_engine_loop_centralized():
    offenders = []
    serving_home = os.path.join(SRC, "serving")
    roots = [SRC, os.path.join(REPO, "benchmarks"),
             os.path.join(REPO, "examples")]
    for top in roots:
        for root, _, files in os.walk(top):
            if os.path.abspath(root).startswith(
                    os.path.abspath(serving_home)):
                continue
            for name in files:
                if not name.endswith(".py"):
                    continue
                path = os.path.join(root, name)
                with open(path) as f:
                    for lineno, line in enumerate(f, 1):
                        if "``" in line or line.lstrip().startswith("#"):
                            continue
                        for pat, why in _ENGINE_ONLY:
                            if pat in line:
                                offenders.append(
                                    f"{os.path.relpath(path, REPO)}:{lineno}"
                                    f" [{pat!r} → {why}]")
    assert not offenders, "\n".join(offenders)


# Cache layout is owned by repro.serving.cache_family: pool/slot tensor
# construction and kv-cache-dtype policy checks anywhere else would fork the
# layout contract the paged substrate (block axis at leaf position 1) and
# the jitted steps are built on.  models/layers.py keys the quantized path
# off the cache payload ("k_scale" in cache), not the config string.
_CACHE_FAMILY_ONLY = (
    ("kv_cache_dtype ==", "dtype policy lives in serving.cache_family"),
    ("kv_cache_dtype !=", "dtype policy lives in serving.cache_family"),
    ("jnp.zeros((n, batch", "slot-cache layout lives in serving.cache_family"),
    ("jnp.zeros((count, num_blocks",
     "pool-cache layout lives in serving.cache_family"),
)


def test_cache_family_centralized():
    offenders = []
    allowed = {os.path.join(SRC, "serving", "cache_family.py"),
               os.path.join(SRC, "serving", "engine.py")}
    for root, _, files in os.walk(SRC):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(root, name)
            if os.path.abspath(path) in {os.path.abspath(a) for a in allowed}:
                continue
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    if "``" in line or line.lstrip().startswith("#"):
                        continue
                    for pat, why in _CACHE_FAMILY_ONLY:
                        if pat in line:
                            offenders.append(
                                f"{os.path.relpath(path, REPO)}:{lineno}"
                                f" [{pat!r} → {why}]")
    assert not offenders, "\n".join(offenders)


# Wall-clock access is owned by repro.obs.clock: every timestamp the serving
# stack takes must go through the injectable clock, or the virtual-clock
# tests (deterministic latencies) and the trace epoch silently diverge from
# what the scheduler actually measured.
_CLOCK_ONLY = (
    ("time.monotonic(", "use repro.obs.clock.monotonic()"),
    ("time.perf_counter(", "use repro.obs.clock.perf_counter()"),
    ("time.time(", "use repro.obs.clock.wall_time()"),
)


def test_wall_clock_access_centralized():
    offenders = []
    obs_home = os.path.join(SRC, "obs")
    for root, _, files in os.walk(SRC):
        if os.path.abspath(root).startswith(os.path.abspath(obs_home)):
            continue                          # the clock's own home
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(root, name)
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    if "``" in line or line.lstrip().startswith("#"):
                        continue
                    for pat, why in _CLOCK_ONLY:
                        if pat in line:
                            offenders.append(
                                f"{os.path.relpath(path, REPO)}:{lineno} "
                                f"[{pat!r} → {why}]")
    assert not offenders, "\n".join(offenders)


# ---------------------------------------------------------------------------
# Dispatch registry: path selection on this backend.
# ---------------------------------------------------------------------------
def test_registry_paths_registered():
    for op in ("online_softmax", "softmax_topk", "attention"):
        paths = dispatch.available(op)
        assert dispatch.PATH_XLA in paths, (op, paths)
        assert dispatch.PATH_PALLAS in paths, (op, paths)


def test_path_selection_matches_backend():
    caps = compat.capabilities()
    for op in ("online_softmax", "softmax_topk"):
        path = dispatch.select_path(op)
        if caps.pallas_native:
            assert path == dispatch.PATH_PALLAS
        else:
            assert path == dispatch.PATH_XLA
    # a Pallas preference on a non-native backend degrades to interpret mode
    path = dispatch.select_path("attention", prefer_pallas=True)
    if caps.pallas_native:
        assert path == dispatch.PATH_PALLAS
    else:
        assert path == dispatch.PATH_PALLAS_INTERPRET


def test_differentiable_softmax_topk_has_grad_path():
    """The MoE router differentiates through softmax_topk; the registry must
    never route it to the Pallas kernel (no custom VJP), even on TPU."""
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 64))
    g = jax.grad(lambda x: dispatch.softmax_topk(
        x, 4, differentiable=True).logsumexp.sum())(x)
    assert np.isfinite(np.asarray(g)).all()


def test_dispatched_ops_match_reference():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 512)) * 5
    np.testing.assert_allclose(np.asarray(dispatch.online_softmax(x)),
                               np.asarray(core.safe_softmax(x)),
                               rtol=1e-5, atol=1e-7)
    got = dispatch.softmax_topk(x, 5)
    want = core.softmax_topk(x, 5)
    np.testing.assert_allclose(np.asarray(got.values),
                               np.asarray(want.values), rtol=1e-5, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.indices))


# ---------------------------------------------------------------------------
# Autotune: sweep once, cache-hit thereafter.
# ---------------------------------------------------------------------------
def test_autotune_caches_block_decision():
    dispatch.reset_autotune_cache()
    d1 = dispatch.block_decision(1024, jnp.float32)
    assert dispatch.autotune_stats() == {"sweeps": 1, "entries": 1}
    d2 = dispatch.block_decision(1024, jnp.float32)
    assert d2 is d1                              # second call: pure cache hit
    assert dispatch.autotune_stats() == {"sweeps": 1, "entries": 1}
    assert 1 <= d1.block <= 1024
    assert d1.block in [b for b, _ in d1.timings_us]
    # a different (vocab, dtype) key sweeps again — the cache is per-key
    dispatch.block_decision(1024, jnp.bfloat16)
    dispatch.block_decision(512, jnp.float32)
    assert dispatch.autotune_stats() == {"sweeps": 3, "entries": 3}


def test_autotune_sweep_inside_jit_trace_measures_execution():
    """The serving step jits decode, so the first sweep can fire during an
    outer trace; ensure_compile_time_eval must keep the sweep concrete (a
    traced sweep would time per-candidate tracing overhead instead)."""
    dispatch.reset_autotune_cache()
    cap = {}

    def f(x):
        cap["d"] = dispatch.block_decision(x.shape[-1], jnp.float32)
        return x * 1.0

    jax.jit(f)(jnp.ones((2, 777)))
    d = cap["d"]
    assert dispatch.autotune_stats() == {"sweeps": 1, "entries": 1}
    assert all(us > 0 for _, us in d.timings_us)
    # the in-trace sweep populated the process-wide cache: eager callers
    # reuse the same decision object
    assert dispatch.block_decision(777, jnp.float32) is d


def test_ops_pick_up_tuned_block():
    """ops.* with v_blk unset consults the autotune cache (no hard-coding)."""
    dispatch.reset_autotune_cache()
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512))
    y = ops.online_softmax(x)                    # v_blk=None → tuned
    np.testing.assert_allclose(np.asarray(y), np.asarray(core.safe_softmax(x)),
                               rtol=1e-5, atol=1e-7)
    assert dispatch.autotune_stats()["entries"] >= 1


# ---------------------------------------------------------------------------
# Autotune persistence: decisions survive the process (ROADMAP item).
# ---------------------------------------------------------------------------
def test_block_decisions_persist_and_reload(tmp_path, monkeypatch):
    """A restart (simulated: reset + load) must skip the sweep entirely."""
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV, path)
    dispatch.reset_autotune_cache()
    d1 = dispatch.block_decision(384, jnp.float32)
    assert os.path.exists(path)
    dispatch.reset_autotune_cache()
    assert dispatch.load_persisted_decisions() >= 1
    d2 = dispatch.block_decision(384, jnp.float32)
    assert dispatch.autotune_stats()["sweeps"] == 0      # disk hit, no sweep
    assert (d2.block, d2.backend, d2.dtype) == (d1.block, d1.backend, d1.dtype)
    dispatch.reset_autotune_cache()


def test_autotune_cache_env_empty_disables_persistence(monkeypatch):
    monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV, "")
    assert dispatch.autotune_cache_path() is None
    assert dispatch.load_persisted_decisions() == 0
    assert not dispatch.save_persisted_decisions()


def test_corrupt_autotune_cache_never_breaks_dispatch(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    path.write_text("{this is not json")
    monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV, str(path))
    assert dispatch.load_persisted_decisions() == 0
    dispatch.reset_autotune_cache()
    dispatch.block_decision(320, jnp.float32)            # sweeps, then saves
    import json as _json
    with open(path) as f:
        saved = _json.load(f)
    assert any(int(b["vocab"]) == 320 for b in saved["blocks"])
    dispatch.reset_autotune_cache()


def test_schema_mismatched_autotune_cache_warns_once_and_resweeps(
        tmp_path, monkeypatch):
    """A version stamp from another schema era must not be trusted: the load
    warns (once), returns nothing, and the next sweep rewrites the file with
    the current stamp."""
    import json as _json
    path = tmp_path / "autotune.json"
    path.write_text(_json.dumps({"version": 99, "blocks": [
        {"backend": "cpu", "vocab": 352, "dtype": "float32", "block": 7,
         "timings_us": [[7, 1.0]]}]}))
    monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV, str(path))
    dispatch.reset_autotune_cache()
    with pytest.warns(UserWarning, match="schema version"):
        assert dispatch.load_persisted_decisions() == 0
    import warnings as _warnings
    with _warnings.catch_warnings(record=True) as rec:
        _warnings.simplefilter("always")
        assert dispatch.load_persisted_decisions() == 0
    assert not rec                                        # warned once only
    d = dispatch.block_decision(352, jnp.float32)         # re-sweeps
    assert dispatch.autotune_stats()["sweeps"] == 1
    assert d.block != 7 or d.timings_us != ((7, 1.0),)    # not the stale row
    saved = _json.loads(path.read_text())
    assert saved["version"] == dispatch.CACHE_SCHEMA_VERSION
    assert any(int(b["vocab"]) == 352 for b in saved["blocks"])
    dispatch.reset_autotune_cache()


def test_non_object_autotune_cache_ignored(tmp_path, monkeypatch):
    """A top-level JSON list (valid JSON, wrong shape) used to crash the
    import-time load with AttributeError; it must be ignored instead."""
    path = tmp_path / "autotune.json"
    path.write_text('[{"version": 1}]')
    monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV, str(path))
    dispatch.reset_autotune_cache()
    with pytest.warns(UserWarning, match="top-level list"):
        assert dispatch.load_persisted_decisions() == 0
    dispatch.reset_autotune_cache()


def test_corrupt_autotune_cache_does_not_break_import(tmp_path):
    """The real failure mode: dispatch loads the cache at import, so a bad
    file must not take down a fresh interpreter."""
    bad = tmp_path / "autotune.json"
    bad.write_text("]]] definitely not json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    env[dispatch.AUTOTUNE_CACHE_ENV] = str(bad)
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.kernels import dispatch as d; "
         "print(d.autotune_stats()['entries'])"],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert int(out.stdout.strip()) == 0


def test_fresh_process_loads_persisted_decisions(tmp_path, monkeypatch):
    """The import-time load: a new interpreter sees the saved decisions."""
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV, path)
    dispatch.reset_autotune_cache()
    dispatch.block_decision(448, jnp.float32)
    dispatch.reset_autotune_cache()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    env[dispatch.AUTOTUNE_CACHE_ENV] = path
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.kernels import dispatch as d; "
         "print(d.autotune_stats()['entries'])"],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert int(out.stdout.strip()) >= 1


# ---------------------------------------------------------------------------
# Attention tile seam: no hard-coded bq/bk in ops.py (ROADMAP item).
# ---------------------------------------------------------------------------
def test_attention_tiles_resolve_through_registry(tmp_path, monkeypatch):
    monkeypatch.setenv(dispatch.AUTOTUNE_CACHE_ENV, str(tmp_path / "t.json"))
    dispatch.reset_autotune_cache()
    tiles = dispatch.attention_tiles("flash_attention", kv_len=64, head_dim=16)
    assert set(tiles) == {"bq", "bk"} and all(v > 0 for v in tiles.values())
    td = dispatch.attention_tiles("flash_decode", kv_len=64, head_dim=16)
    assert td["bk"] > 0
    assert dispatch.tile_stats()["entries"] == 2
    assert dispatch.attention_tiles(
        "flash_decode", kv_len=64, head_dim=16) == td   # cache hit
    assert dispatch.tile_stats()["entries"] == 2
    dispatch.reset_autotune_cache()


def test_ops_attention_defaults_come_from_registry():
    """flash_attention / flash_decode with tiles unset must run through the
    dispatch seam (and still compute correct attention)."""
    from repro.kernels import ops
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 8))
    out = ops.flash_attention(q, q, q, causal=True)      # bq/bk unset
    want = core.naive_attention(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    kc = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 1, 8))
    vc = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 1, 8))
    qd = jax.random.normal(jax.random.PRNGKey(3), (2, 2, 8))
    vlen = jnp.asarray([5, 16], jnp.int32)
    od = ops.flash_decode(qd, kc, vc, vlen)              # bk unset
    want_d = core.naive_attention(qd[:, None], kc, vc, causal=False,
                                  kv_valid_len=vlen)[:, 0]
    np.testing.assert_allclose(np.asarray(od), np.asarray(want_d),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Benchmark harness smoke mode (CI tooling).
# ---------------------------------------------------------------------------
def test_benchmarks_smoke_mode():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--smoke", "softmax", "topk_sweep"],
        env=env, capture_output=True, text=True, timeout=300, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    lines = [l for l in out.stdout.splitlines() if l.strip()]
    assert lines[0] == "name,us_per_call,derived"
    assert any(l.startswith("softmax/") for l in lines[1:])
    assert any(l.startswith("topk_sweep/") for l in lines[1:])
    for row in lines[1:]:
        name, us, _ = row.split(",", 2)
        assert float(us) > 0, row


def test_benchmarks_attention_smoke_records_prefill_comparison():
    """The prefill Pallas-vs-XLA comparison rides the attention bench."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--smoke", "attention"],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    lines = out.stdout.splitlines()
    assert any("/pallas_fwd" in l and "prefill" in l for l in lines)
    assert any("/xla_chunked_fwd" in l for l in lines)


def test_benchmarks_report_diffs_two_result_files(tmp_path):
    """`run.py report A.json B.json` renders the EXPERIMENTS.md-style diff
    table, flags one-sided rows and env mismatches."""
    import json as _json
    a = {"smoke": True, "env": {"backend": "cpu", "jax_version": "x"},
         "rows": [
             {"name": "softmax/a", "us_per_call": 10.0, "derived": "d1"},
             {"name": "only/base", "us_per_call": 5.0, "derived": ""}]}
    b = {"smoke": True, "env": {"backend": "tpu", "jax_version": "x"},
         "rows": [
             {"name": "softmax/a", "us_per_call": 8.0, "derived": "d1"},
             {"name": "only/cand", "us_per_call": 2.0, "derived": ""}]}
    pa, pb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    with open(pa, "w") as f:
        _json.dump(a, f)
    with open(pb, "w") as f:
        _json.dump(b, f)
    md_out = str(tmp_path / "diff.md")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "report", pa, pb, "--out", md_out],
        env=env, capture_output=True, text=True, timeout=300, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    text = out.stdout
    assert "| softmax/a | 10.00 | 8.00 | -20.0% | d1 |" in text
    assert "backend ⚠" in text                 # env mismatch flagged
    assert "Rows only in baseline: only/base" in text
    assert "Rows only in candidate: only/cand" in text
    with open(md_out) as f:
        assert f.read() == text


def test_benchmarks_serving_smoke_records_json(tmp_path):
    """The serving benchmark smoke path: tokens/s + latency percentiles land
    in a results JSON (first step toward the EXPERIMENTS.md diffing report)."""
    import json as _json
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    json_path = str(tmp_path / "results.json")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--smoke", "serving", "--json", json_path],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    with open(json_path) as f:
        data = _json.load(f)
    names = {r["name"] for r in data["rows"]}
    assert {"serving/smoke/per_token", "serving/smoke/p50_latency",
            "serving/smoke/p95_latency",
            "serving/smoke/occupancy_pct"} <= names
    assert data["smoke"] is True
    assert data["env"]["backend"] in ("cpu", "gpu", "tpu")
    for r in data["rows"]:
        assert r["us_per_call"] > 0, r
