"""End-to-end training behaviour: loss decreases, checkpoint/restart is
bit-exact, stragglers are detected, microbatching matches full batch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import OptimizerConfig, ParallelConfig, RunConfig
from repro.data.synthetic import SyntheticConfig, SyntheticDataset
from repro.training import loop
from repro.training.train_step import init_state, make_train_step


def _run_cfg(tmp_path, arch="smollm_360m", **opt_kw):
    cfg = configs.get_smoke(arch)
    return RunConfig(
        model=cfg,
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=100,
                                  schedule="constant", **opt_kw),
        checkpoint_dir=str(tmp_path), checkpoint_every=10, log_every=1000)


def _dataset(cfg, gb=8):
    return SyntheticDataset(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=gb, seed=3))


def test_loss_decreases(tmp_path):
    run = _run_cfg(tmp_path)
    params, opt_state, _ = init_state(run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(run), donate_argnums=(0, 1))
    params, opt_state, hist = loop.run(
        run, steps=30, train_step=step, params=params, opt_state=opt_state,
        dataset=_dataset(run.model), log=lambda *_: None)
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])
    assert all(np.isfinite(l) for l in losses)


def test_crash_restart_is_exact(tmp_path):
    """Kill the loop at step 17, restart, and verify the final params are
    bit-identical to an uninterrupted run (checkpointing + counter-based
    data = exact recovery)."""
    run = _run_cfg(tmp_path / "a")
    params0, opt0, _ = init_state(run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(run))

    # uninterrupted reference: 20 steps
    p_ref, o_ref, _ = loop.run(
        run, steps=20, train_step=step,
        params=jax.tree.map(jnp.copy, params0),
        opt_state=jax.tree.map(jnp.copy, opt0),
        dataset=_dataset(run.model), log=lambda *_: None)

    # crash at step 17 (after the step-10 checkpoint), then restart
    run_b = _run_cfg(tmp_path / "b")

    class Boom(RuntimeError):
        pass

    def bomb(step_i):
        if step_i == 17:
            raise Boom()

    with pytest.raises(Boom):
        loop.run(run_b, steps=20, train_step=step,
                 params=jax.tree.map(jnp.copy, params0),
                 opt_state=jax.tree.map(jnp.copy, opt0),
                 dataset=_dataset(run_b.model), inject_failure=bomb,
                 log=lambda *_: None)
    # restart: loop restores from the last committed checkpoint (step 10)
    p_re, o_re, _ = loop.run(
        run_b, steps=20, train_step=step,
        params=jax.tree.map(jnp.copy, params0),
        opt_state=jax.tree.map(jnp.copy, opt0),
        dataset=_dataset(run_b.model), log=lambda *_: None)

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_re)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_microbatching_matches_full_batch(tmp_path):
    cfg = configs.get_smoke("smollm_360m")
    base = RunConfig(model=cfg, optimizer=OptimizerConfig(
        lr=1e-3, warmup_steps=0, schedule="constant", grad_clip=0.0),
        parallel=ParallelConfig(microbatches=1,
                                grad_reduce_dtype="float32"))
    micro = RunConfig(model=cfg, optimizer=base.optimizer,
                      parallel=ParallelConfig(microbatches=4,
                                              grad_reduce_dtype="float32"))
    params, opt, _ = init_state(base, jax.random.PRNGKey(0))
    batch = _dataset(cfg, gb=8).batch(0)
    batch = jax.tree.map(jnp.asarray, batch)
    p1, _, m1 = make_train_step(base)(jax.tree.map(jnp.copy, params),
                                      jax.tree.map(jnp.copy, opt), batch)
    p2, _, m2 = make_train_step(micro)(jax.tree.map(jnp.copy, params),
                                       jax.tree.map(jnp.copy, opt), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_straggler_watchdog():
    w = loop.StragglerWatchdog(factor=3.0)
    for s in range(10):
        assert not w.observe(s, 0.1)
    assert w.observe(10, 1.0)            # 10× median
    assert w.events and w.events[0]["step"] == 10
