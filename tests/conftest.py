"""Suite-wide setup: import paths + environment report.

The env report prints the exact portability surface the compat layer probes
(JAX version, backend, host device count, shard_map source, cost_analysis
shape, Pallas mode) at the top of every pytest run, so a red CI log starts
with the facts that usually explain it.
"""
from __future__ import annotations

import os
import sys

_TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_TESTS_DIR)
_SRC = os.path.join(_REPO, "src")

# Make `import repro` and `import _hypothesis_compat` work even when the
# caller forgot PYTHONPATH=src (plain `pytest` from the repo root).
for p in (_SRC, _TESTS_DIR):
    if p not in sys.path:
        sys.path.insert(0, p)

# Hermetic autotune persistence: the dispatch registry reads this env var at
# import and writes to it on every new decision.  Point it at a per-run temp
# file (unless the caller pinned one) so the suite neither pollutes nor reads
# the developer's real ~/.cache/repro/autotune.json.
if "REPRO_AUTOTUNE_CACHE" not in os.environ:
    import tempfile
    os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
        tempfile.gettempdir(), f"repro_autotune_test_{os.getpid()}.json")


def pytest_report_header(config):
    try:
        from repro import compat
        caps = compat.capabilities()
    except Exception as e:                  # never break collection over this
        return f"repro env: unavailable ({type(e).__name__}: {e})"
    try:
        import hypothesis
        hyp = f"hypothesis {hypothesis.__version__}"
    except ImportError:
        hyp = "hypothesis absent (fixed-seed fallback)"
    return (
        f"repro env: jax {caps.jax_version} | backend {caps.backend} | "
        f"host devices {caps.device_count} | "
        f"shard_map from {caps.shard_map_source} | "
        f"cost_analysis returns {caps.cost_analysis_shape} | "
        f"pallas {'native' if caps.pallas_native else 'interpret'} | {hyp}"
    )
