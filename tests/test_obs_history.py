"""Performance regression sentry (ISSUE 9): history store + noise-aware gate.

The load-bearing claims:

* **History is append-only and env-keyed**: every record lands as one JSONL
  line carrying the env fingerprint; a record from a different fingerprint
  is invisible to a row's baseline window, and a corrupt line is skipped,
  never fatal.
* **The gate is noise-aware**: the baseline is the fastest-half mean of the
  last K same-env samples (contention noise is additive, so the fastest
  half approaches the uncontended cost), judged against per-row relative
  thresholds — serving rows get a wider band than kernel microbenches.
* **The CLI actually gates**: ``run.py check`` exits nonzero iff a row
  regressed, names the offending row on a grep-able ``REGRESSION:`` line,
  stays green on same-noise reruns, and ``--update-baseline`` records the
  candidate and exits 0 — proven end-to-end on synthetic history below,
  and on a real ``--smoke`` bench run at the bottom.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.obs import history, regress

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUN_PY = os.path.join(REPO, "benchmarks", "run.py")

ENV_A = {"backend": "cpu", "jax_version": "0.4.0",
         "device_count": 1, "pallas_native": False}
ENV_B = {"backend": "tpu", "jax_version": "0.4.0",
         "device_count": 8, "pallas_native": True}


def _rows(us_map):
    return [{"name": n, "us_per_call": us, "derived": ""}
            for n, us in us_map.items()]


def _results_file(path, us_map, env=ENV_A, smoke=True):
    with open(path, "w") as fh:
        json.dump({"smoke": smoke, "env": env, "rows": _rows(us_map)}, fh)
    return str(path)


def _check(args, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.pop(history.HISTORY_ENV, None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, RUN_PY, "check"] + args,
                          capture_output=True, text=True, timeout=600,
                          env=env)


# ---------------------------------------------------------------------------
# Store semantics.
# ---------------------------------------------------------------------------
def test_history_append_reload_roundtrip(tmp_path):
    store = history.HistoryStore(str(tmp_path / "h.jsonl"))
    assert store.records() == []               # missing file: fresh checkout
    store.append(ENV_A, _rows({"softmax/online": 100.0}), smoke=True,
                 label="gen1")
    store.append(ENV_A, [("softmax/online", 104.0, "x1.5")], smoke=True)
    recs = store.records()
    assert [r["schema"] for r in recs] == [history.SCHEMA_VERSION] * 2
    assert recs[0]["label"] == "gen1" and "label" not in recs[1]
    assert recs[0]["fingerprint"] == history.fingerprint(ENV_A, smoke=True)
    assert recs[1]["rows"] == [{"name": "softmax/online",
                                "us_per_call": 104.0, "derived": "x1.5"}]
    # appends accumulate: the file is longitudinal, not a snapshot
    store.append(ENV_A, _rows({"softmax/online": 99.0}), smoke=True)
    assert len(store.records()) == 3


def test_history_samples_isolate_fingerprints_and_window(tmp_path):
    store = history.HistoryStore(str(tmp_path / "h.jsonl"))
    for us in (100.0, 101.0, 102.0, 103.0):
        store.append(ENV_A, _rows({"r": us}), smoke=True)
    store.append(ENV_B, _rows({"r": 5.0}), smoke=True)     # other machine
    store.append(ENV_A, _rows({"r": 9.0}), smoke=False)    # full, not smoke
    fp = history.fingerprint(ENV_A, smoke=True)
    assert store.samples("r", fp) == [100.0, 101.0, 102.0, 103.0]
    assert store.samples("r", fp, k=2) == [102.0, 103.0]   # most recent k
    assert store.samples("missing", fp) == []
    assert store.samples(
        "r", history.fingerprint(ENV_B, smoke=True)) == [5.0]


def test_history_skips_corrupt_lines(tmp_path):
    path = tmp_path / "h.jsonl"
    store = history.HistoryStore(str(path))
    store.append(ENV_A, _rows({"r": 100.0}), smoke=True)
    with open(path, "a") as fh:
        fh.write("{truncated by a crashed wr\n")
        fh.write('{"valid_json": "but not a record"}\n')
        fh.write("\n")
    store.append(ENV_A, _rows({"r": 101.0}), smoke=True)
    recs = store.records()
    assert len(recs) == 2 and store.skipped == 2
    fp = history.fingerprint(ENV_A, smoke=True)
    assert store.samples("r", fp) == [100.0, 101.0]


def test_history_path_resolution(monkeypatch):
    monkeypatch.delenv(history.HISTORY_ENV, raising=False)
    assert history.history_path(None) is None              # opt-in default
    assert history.history_path(None, default="d.jsonl") == "d.jsonl"
    monkeypatch.setenv(history.HISTORY_ENV, "env.jsonl")
    assert history.history_path(None, default="d.jsonl") == "env.jsonl"
    assert history.history_path("cli.jsonl") == "cli.jsonl"  # explicit wins


# ---------------------------------------------------------------------------
# Estimators and thresholds.
# ---------------------------------------------------------------------------
def test_fastest_half_mean_and_median():
    # additive noise: the slow half (contended runs) must not drag the gate
    assert regress.fastest_half_mean([100.0, 102.0, 150.0, 180.0]) == 101.0
    assert regress.fastest_half_mean([7.0]) == 7.0
    assert regress.fastest_half_mean(
        [50.0, 52.0, 30.0, 31.0], bigger_is_faster=True) == 51.0
    assert regress.median([1.0, 9.0, 2.0]) == 2.0
    assert regress.median([1.0, 2.0, 3.0, 10.0]) == 2.5
    with pytest.raises(ValueError):
        regress.fastest_half_mean([])
    with pytest.raises(ValueError):
        regress.median([])


def test_threshold_longest_prefix_wins():
    assert regress.threshold_for("softmax/online") == regress.DEFAULT_THRESHOLD
    assert regress.threshold_for("serving/tok_s") == 0.50
    over = (("serving/", 0.50), ("serving/smoke/", 0.80))
    assert regress.threshold_for("serving/smoke/tok_s", over) == 0.80
    assert regress.threshold_for("serving/full/tok_s", over) == 0.50


def test_check_rows_verdict_matrix(tmp_path):
    store = history.HistoryStore(str(tmp_path / "h.jsonl"))
    for us in (100.0, 104.0, 140.0):         # one contended outlier
        store.append(ENV_A, _rows({"k/row": us, "serving/row": us}),
                     smoke=True)
    # gate baseline = mean of fastest half {100} = 100 (the 140 outlier and
    # the window median 104 are reported, not gated on)
    def one(name, us, **kw):
        vs = regress.check_rows([(name, us, "")], store, ENV_A, smoke=True,
                                **kw)
        assert len(vs) == 1
        return vs[0]

    v = one("k/row", 103.0)
    assert (v.verdict, v.baseline_us, v.median_us) == (regress.OK, 100.0,
                                                       104.0)
    assert v.delta_pct == pytest.approx(3.0)
    assert v.window == 3
    assert one("k/row", 126.0).verdict == regress.REGRESSED   # > +25%
    assert one("k/row", 74.0).verdict == regress.IMPROVED     # < -25%
    # serving rows get the wider band: +40% is still ok there
    assert one("serving/row", 140.0).verdict == regress.OK
    # global override beats the prefix table
    assert one("serving/row", 140.0, threshold=0.25).verdict == \
        regress.REGRESSED
    # unseen row, and a seen row under a too-short window: no-baseline
    assert one("k/new", 1.0).verdict == regress.NO_BASELINE
    v = one("k/row", 100.0, min_records=5)
    assert v.verdict == regress.NO_BASELINE and v.baseline_us is None
    assert regress.regressions(
        regress.check_rows([("k/row", 500.0, "")], store, ENV_A,
                           smoke=True))[0].name == "k/row"


def test_render_names_offending_rows(tmp_path):
    store = history.HistoryStore(str(tmp_path / "h.jsonl"))
    for us in (100.0, 100.0):
        store.append(ENV_A, _rows({"k/row": us}), smoke=True)
    vs = regress.check_rows([("k/row", 200.0, "")], store, ENV_A, smoke=True)
    text = regress.render(vs, fp=history.fingerprint(ENV_A, smoke=True))
    assert "| k/row | 100.00 |" in text
    assert "REGRESSION: k/row +100.0% over baseline 100.00µs" in text
    assert "1 regressed" in text


# ---------------------------------------------------------------------------
# The CLI gate, end-to-end on synthetic history (the acceptance pin).
# ---------------------------------------------------------------------------
def test_check_cli_gates_on_injected_slowdown(tmp_path):
    hist = str(tmp_path / "h.jsonl")
    gen1 = _results_file(tmp_path / "gen1.json",
                         {"softmax/online": 100.0, "serving/tok_s": 50.0})
    gen2 = _results_file(tmp_path / "gen2.json",
                         {"softmax/online": 104.0, "serving/tok_s": 52.0})
    # two generations seed the baseline; each --update-baseline passes CI
    for gen in (gen1, gen2):
        out = _check(["--from", gen, "--history", hist, "--update-baseline"])
        assert out.returncode == 0, out.stderr[-2000:]
        assert "baseline updated" in out.stdout
    # same-noise rerun: green
    ok = _results_file(tmp_path / "ok.json",
                       {"softmax/online": 103.0, "serving/tok_s": 51.0})
    out = _check(["--from", ok, "--history", hist])
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert "0 regressed" in out.stdout
    # injected 1.6× slowdown on one row: gate fails and names the row
    bad = _results_file(tmp_path / "bad.json",
                        {"softmax/online": 160.0, "serving/tok_s": 51.0})
    out = _check(["--from", bad, "--history", hist])
    assert out.returncode == 1, out.stdout
    assert "REGRESSION: softmax/online" in out.stdout
    assert "| serving/tok_s" in out.stdout and "| ok |" in out.stdout
    # an improvement is not a failure
    imp = _results_file(tmp_path / "imp.json",
                        {"softmax/online": 60.0, "serving/tok_s": 51.0})
    out = _check(["--from", imp, "--history", hist])
    assert out.returncode == 0, out.stdout
    assert "1 improved" in out.stdout
    # --update-baseline accepts even a regressed candidate (and records it)
    n_before = len(history.HistoryStore(hist).records())
    out = _check(["--from", bad, "--history", hist, "--update-baseline"])
    assert out.returncode == 0, out.stdout
    assert len(history.HistoryStore(hist).records()) == n_before + 1


def test_check_cli_other_env_is_no_baseline(tmp_path):
    """History from a different machine must not gate this one."""
    hist = str(tmp_path / "h.jsonl")
    store = history.HistoryStore(hist)
    for us in (10.0, 10.0, 10.0):
        store.append(ENV_B, _rows({"softmax/online": us}), smoke=True)
    cand = _results_file(tmp_path / "cand.json", {"softmax/online": 160.0})
    out = _check(["--from", cand, "--history", hist])
    assert out.returncode == 0, out.stdout
    assert "no-baseline" in out.stdout and "0 regressed" in out.stdout


def test_check_cli_honours_history_env_var(tmp_path):
    hist = str(tmp_path / "env.jsonl")
    for us in (100.0, 100.0):
        history.HistoryStore(hist).append(
            ENV_A, _rows({"softmax/online": us}), smoke=True)
    bad = _results_file(tmp_path / "bad.json", {"softmax/online": 200.0})
    out = _check(["--from", bad], env_extra={history.HISTORY_ENV: hist})
    assert out.returncode == 1, out.stdout
    assert "REGRESSION: softmax/online" in out.stdout


# ---------------------------------------------------------------------------
# The real-bench path: --history recording + check on a live smoke run.
# ---------------------------------------------------------------------------
def test_run_smoke_records_history_and_check_stays_green(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.pop(history.HISTORY_ENV, None)
    hist = str(tmp_path / "h.jsonl")
    results = str(tmp_path / "out.json")
    out = subprocess.run(
        [sys.executable, RUN_PY, "softmax", "--smoke", "--json", results,
         "--history", hist],
        capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "history: recorded" in out.stderr
    store = history.HistoryStore(hist)
    recs = store.records()
    assert len(recs) == 1 and recs[0]["label"] == "run:softmax"
    with open(results) as fh:
        data = json.load(fh)
    assert recs[0]["fingerprint"] == history.fingerprint(
        data["env"], smoke=True)
    # duplicate the record so the window is deep enough, then gate the very
    # same measurements: identical numbers must come back ok
    store.append(data["env"], data["rows"], smoke=True)
    out = _check(["--from", results, "--history", hist])
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert "0 no-baseline, 0 regressed" in out.stdout
