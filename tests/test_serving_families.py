"""Cache families (ISSUE 8): every cache shape through one paged substrate.

The load-bearing claims:

* **Fixed-state serves paged**: zamba2 (hybrid SSM) and xLSTM requests run
  under ``--continuous --paged`` with the whole recurrent state as a single
  refcounted block, and every token stream is bit-identical to the request
  decoded alone — block indirection is a layout change, not a numerics
  change (the same guarantee ISSUE 4 pinned for dense KV).
* **Enc-dec shares encoder output**: repeated same-audio whisper requests
  adopt the SAME physical encoder blocks (allocator refcount > 1 while both
  are live, ``prefix_cache_hits`` when the LRU cache revives a finished
  chain), skip the encoder entirely on a hit, and still stream bit-identical
  to solo decodes.
* **Family policy is enforced at the boundary**: state prompts must respect
  the chunked scan's quantum, enc-dec prompts must be the whole audio, and
  enc-dec refuses to serve unpaged (the shared encoder chain IS the paged
  pool).
* **Allocator invariants hold with fixed-state blocks in the mix**: random
  admit/release churn over a fixed-state pool never aliases live state rows,
  never hands out the sentinel, and keeps free+live partitioning the pool
  (property test — real hypothesis where installed, the fixed-seed fallback
  elsewhere).
* **dense_int8 serves paged EXACTLY like unpaged** (ISSUE 10): the same
  quantized bits land in the pool either way and the gather dequantizes with
  the same arithmetic and chunk split, so token streams are identical across
  burst/staggered/reversed arrivals; preempt-and-swap round-trips the int8
  payload AND its scale pages bit-exactly; and the family's no-share policy
  holds — identical prompts never share blocks, the prefix index stays empty.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                                    # offline fallback
    from _hypothesis_compat import given, settings, st

import repro.configs as configs
from repro.models import encdec, layers as L, transformer
from repro.serving import cache_family, engine, paged, scheduler

SLOT_LEN = 48
BLOCK = 8
CHUNK = 8
TOP_K = 5
BASE_RNG = jax.random.PRNGKey(7)


def _key(rid, step):
    return jax.random.fold_in(jax.random.fold_in(BASE_RNG, rid), step)


def _params(cfg):
    init_fn = encdec.init if cfg.family == "encdec" else transformer.init
    params, _ = L.split_params(init_fn(jax.random.PRNGKey(0), cfg))
    return params


# ---------------------------------------------------------------------------
# Fixed-state (SSM / xLSTM): paged == unpaged == solo.
# ---------------------------------------------------------------------------
def _solo_state_decode(params, cfg, req):
    """The request alone: chunked prefill + batch-1 decode — the reference
    both the slot pool and the block pool must reproduce token-for-token."""
    last, caches, ln = engine.chunked_prefill(
        params, jnp.asarray(req.prompt)[None], cfg, max_len=SLOT_LEN)
    logits = engine.logits_from_hidden(params, last, cfg)
    tok = engine.sample_per_slot(_key(req.rid, 0)[None], logits, TOP_K)
    tokens = [int(tok[0])]
    lens = jnp.asarray([int(ln)], jnp.int32)
    for step in range(1, req.max_new_tokens):
        tok, caches, lens = engine.decode_step_slots(
            params, caches, lens, tok[:, None], cfg,
            rngs=_key(req.rid, step)[None], top_k=TOP_K)
        tokens.append(int(tok[0]))
    return tokens


def _state_workload(cfg, quantum):
    rng = np.random.default_rng(11)
    # quantum-compliant lengths: ≤ q and a multiple of q
    lens = [quantum // 2, quantum, quantum // 4 * 3]
    return [scheduler.Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab_size, n),
                              max_new_tokens=d, arrival_tick=i)
            for i, (n, d) in enumerate(zip(lens, (5, 4, 6)))]


@pytest.mark.parametrize("arch", ["zamba2_1p2b", "xlstm_125m"])
@pytest.mark.parametrize("use_paged", [True, False])
def test_fixed_state_serving_matches_solo(arch, use_paged):
    cfg = configs.get_smoke(arch)
    family = cache_family.resolve(cfg)
    assert family.kind == "state" and family.continuous_serveable
    params = _params(cfg)
    requests = _state_workload(cfg, family.prompt_quantum())
    expect = {r.rid: _solo_state_decode(params, cfg, r) for r in requests}

    paged_kw = dict(paged=True, block_size=BLOCK) if use_paged else {}
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, **paged_kw)
    report = sched.run(requests)
    got = {r.rid: r.tokens for r in report.results}
    for rid, toks in expect.items():
        assert got[rid] == toks, (
            f"request {rid} diverged under {'paged' if use_paged else 'slot'}"
            f" fixed-state serving")
    if use_paged:
        # one block per sequence, never shared, all returned
        p = report.paged
        assert p["blocks_shared"] == 0 and p["cow_copies"] == 0
        assert p["free_blocks"] + p["cached_blocks"] == p["num_blocks"]


def test_fixed_state_prompt_quantum_enforced():
    cfg = configs.get_smoke("zamba2_1p2b")
    family = cache_family.resolve(cfg)
    q = family.prompt_quantum()
    sched = scheduler.ContinuousScheduler(
        _params(cfg), cfg, num_slots=2, slot_len=SLOT_LEN,
        prefill_chunk=CHUNK, top_k=TOP_K, base_rng=BASE_RNG,
        paged=True, block_size=BLOCK)
    with pytest.raises(ValueError, match=f"multiple of {q}"):
        sched.submit(scheduler.Request(
            rid=0, prompt=np.zeros(q + 1, np.int64), max_new_tokens=2))


# ---------------------------------------------------------------------------
# Enc-dec (whisper): encoder-output sharing + bit-identity.
# ---------------------------------------------------------------------------
def _solo_encdec_decode(params, cfg, req):
    frames = engine.encdec_frames_from_ids(np.asarray(req.prompt), cfg)
    bos = jnp.full((1, 1), engine.ENCDEC_BOS, jnp.int32)
    last, caches, ln = engine.encdec_prefill(params, frames, bos, cfg,
                                             max_len=SLOT_LEN)
    logits = engine.logits_from_hidden(params, last, cfg)
    tok = engine.sample_per_slot(_key(req.rid, 0)[None], logits, TOP_K)
    tokens = [int(tok[0])]
    lens = jnp.asarray([int(ln)], jnp.int32)
    for step in range(1, req.max_new_tokens):
        tok, caches, lens = engine.encdec_decode_step_slots(
            params, caches, lens, tok[:, None], cfg,
            rngs=_key(req.rid, step)[None], top_k=TOP_K)
        tokens.append(int(tok[0]))
    return tokens


@pytest.fixture(scope="module")
def whisper():
    cfg = configs.get_smoke("whisper_small")
    return _params(cfg), cfg


def _audio_requests(cfg):
    """Four requests over two distinct audios: 0 and 1 share audio A and
    arrive together (live sharing), 3 repeats audio B long after 2 finished
    (LRU-cache revival)."""
    rng = np.random.default_rng(3)
    a = rng.integers(0, cfg.vocab_size, cfg.encoder_seq_len)
    b = rng.integers(0, cfg.vocab_size, cfg.encoder_seq_len)
    spec = [(0, a, 5, 0), (1, a, 4, 0), (2, b, 6, 1), (3, b, 3, 40)]
    return [scheduler.Request(rid=r, prompt=audio.copy(), max_new_tokens=n,
                              arrival_tick=t) for r, audio, n, t in spec]


def test_encdec_paged_shares_encoder_blocks_bit_identically(whisper):
    """The acceptance scenario: repeated same-audio requests share encoder
    blocks (refcount > 1 while both are live), skip the encoder entirely,
    and every stream still equals the request running alone."""
    params, cfg = whisper
    requests = _audio_requests(cfg)
    expect = {r.rid: _solo_encdec_decode(params, cfg, r) for r in requests}

    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=BLOCK,
        num_blocks=4 * (cfg.encoder_seq_len // BLOCK + 1))
    # count encoder invocations through the two prefill paths: a prefix hit
    # must take the cached path (zero encoder recompute)
    calls = {"fresh": 0, "cached": 0}
    fresh_fn, cached_fn = sched._encdec_prefill, sched._encdec_prefill_cached

    def counting_fresh(*a, **kw):
        calls["fresh"] += 1
        return fresh_fn(*a, **kw)

    def counting_cached(*a, **kw):
        calls["cached"] += 1
        return cached_fn(*a, **kw)

    sched._encdec_prefill = counting_fresh
    sched._encdec_prefill_cached = counting_cached

    for r in requests:
        sched.submit(r)
    nc = cfg.encoder_seq_len // BLOCK
    saw_live_sharing = False
    for _ in range(10_000):
        if not sched.busy:
            break
        sched.tick()
        live = list(sched.pool.seqs.values())
        if len(live) == 2 and live[0].blocks[:nc] == live[1].blocks[:nc]:
            # both same-audio sequences hold the same physical chain
            assert all(sched.pool.alloc.refcount(bid) > 1
                       for bid in live[0].blocks[:nc])
            saw_live_sharing = True
    assert not sched.busy, "serve did not drain"
    assert saw_live_sharing, "same-audio requests never shared live blocks"

    got = {r.rid: r.tokens for r in sched.finished}
    for rid, toks in expect.items():
        assert got[rid] == toks, f"request {rid} diverged under paged enc-dec"

    # two distinct audios → exactly two encoder runs; the two repeats took
    # the cached path (one via live sharing, one via LRU revival)
    assert calls["fresh"] == 2 and calls["cached"] == 2
    st = sched.pool.stats()
    assert st["blocks_shared"] == 2 * nc
    assert st["tokens_reused"] == 2 * cfg.encoder_seq_len
    assert st["prefix_cache_hits"] >= nc        # rid 3 revived B's chain


def test_encdec_refuses_unpaged(whisper):
    params, cfg = whisper
    with pytest.raises(ValueError, match="paged"):
        scheduler.ContinuousScheduler(
            params, cfg, num_slots=2, slot_len=SLOT_LEN,
            prefill_chunk=CHUNK, top_k=TOP_K, base_rng=BASE_RNG)


def test_encdec_prompt_must_be_whole_audio(whisper):
    params, cfg = whisper
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=BLOCK)
    with pytest.raises(ValueError, match=str(cfg.encoder_seq_len)):
        sched.submit(scheduler.Request(
            rid=0, prompt=np.zeros(cfg.encoder_seq_len - 1, np.int64),
            max_new_tokens=2))


# ---------------------------------------------------------------------------
# dense_int8: paged serving == unpaged serving, bit-for-bit (ISSUE 10).
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def int8_model():
    cfg = configs.get_smoke("smollm_360m").replace(kv_cache_dtype="int8")
    return _params(cfg), cfg


def _int8_sched(params, cfg, **kw):
    base = dict(num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
                top_k=TOP_K, base_rng=BASE_RNG)
    base.update(kw)
    return scheduler.ContinuousScheduler(params, cfg, **base)


def _int8_workload(pattern):
    """Four requests, rid 3 an exact repeat of rid 0's prompt (the no-share
    probe).  ``pattern`` permutes arrival order, not identity."""
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, 512, n) for n in (11, 19, 7)]
    prompts.append(prompts[0].copy())
    decode = (6, 5, 7, 4)
    ticks = {"burst": (0, 0, 0, 0), "staggered": (0, 2, 4, 6),
             "reversed": (6, 4, 2, 0)}[pattern]
    return [scheduler.Request(rid=i, prompt=p, max_new_tokens=d,
                              arrival_tick=t)
            for i, (p, d, t) in enumerate(zip(prompts, decode, ticks))]


@pytest.mark.parametrize("pattern", ["burst", "staggered", "reversed"])
def test_int8_paged_matches_unpaged_exactly(int8_model, pattern):
    """The acceptance pin: paged int8 token streams equal unpaged int8
    token streams request-for-request — the block pool is a layout change
    even when the payload is quantized — and the no-share policy holds."""
    params, cfg = int8_model
    family = cache_family.resolve(cfg)
    assert family.quantized and family.paged_serveable
    assert family.single_shot_prefill and not family.shareable

    rep_un = _int8_sched(params, cfg).run(_int8_workload(pattern))
    rep_pg = _int8_sched(params, cfg, paged=True,
                         block_size=BLOCK).run(_int8_workload(pattern))
    un = {r.rid: r.tokens for r in rep_un.results}
    pg = {r.rid: r.tokens for r in rep_pg.results}
    for rid in un:
        assert pg[rid] == un[rid], (
            f"request {rid} diverged paged-vs-unpaged ({pattern})")

    # rid 3 repeated rid 0's prompt verbatim, yet nothing shared: scales are
    # per-sequence write-time artifacts, so the family opts out of the index
    p = rep_pg.paged
    assert p["blocks_shared"] == 0 and p["cow_copies"] == 0
    assert p["prefix_cache_hits"] == 0 and p["cached_blocks"] == 0
    assert p["free_blocks"] == p["num_blocks"]


def test_int8_preempt_swap_restores_bit_exactly(int8_model):
    """Swap-out parks int8 payload + bfloat16 scale pages on the host;
    swap-in restores both — the resumed stream must equal the request
    serving alone (which equals its never-preempted run)."""
    params, cfg = int8_model
    rng = np.random.default_rng(17)
    lo = [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 9 + 2 * i),
                            max_new_tokens=12, arrival_tick=0, priority=1)
          for i in range(2)]
    hi = [scheduler.Request(rid=2, prompt=rng.integers(0, 512, 8),
                            max_new_tokens=4, arrival_tick=5, priority=0)]
    requests = lo + hi
    sched = _int8_sched(params, cfg, paged=True, block_size=BLOCK)
    report = sched.run(requests)
    assert report.preemptions >= 1, "workload must actually preempt"
    stats = report.paged
    assert stats["swapped_blocks_out"] >= 1
    assert stats["swapped_blocks_in"] == stats["swapped_blocks_out"]

    by_rid = {r.rid: r for r in report.results}
    for req in requests:
        solo = _int8_sched(params, cfg).run(
            [scheduler.Request(rid=req.rid, prompt=req.prompt.copy(),
                               max_new_tokens=req.max_new_tokens)])
        assert by_rid[req.rid].tokens == solo.results[0].tokens, (
            f"request {req.rid} diverged after preempt-and-swap "
            f"(preempted={by_rid[req.rid].preempted})")


def test_int8_single_shot_prefill_under_paging(int8_model):
    """A prompt longer than prefill_chunk must prefill in ONE shot under
    paging (the chunk schedule would silently drop the quantized prefix) —
    observable as exactly one prefill chunk for the request."""
    params, cfg = int8_model
    rng = np.random.default_rng(19)
    long_prompt = rng.integers(0, 512, 3 * CHUNK + 5)
    req = scheduler.Request(rid=0, prompt=long_prompt, max_new_tokens=3)
    report = _int8_sched(params, cfg, paged=True,
                         block_size=BLOCK).run([req])
    assert report.prefill_chunks == 1
    solo_un = _int8_sched(params, cfg).run(
        [scheduler.Request(rid=0, prompt=long_prompt.copy(),
                           max_new_tokens=3)])
    assert report.results[0].tokens == solo_un.results[0].tokens


# ---------------------------------------------------------------------------
# Allocator invariants with fixed-state blocks in the mix (property test).
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=2, max_value=6),
       st.lists(st.integers(min_value=0, max_value=2 ** 31 - 1),
                min_size=0, max_size=60))
def test_fixed_state_pool_invariants_under_churn(num_slots, actions):
    """Random admit/release churn over a fixed-state pool: every live
    sequence holds exactly one unshared non-sentinel block, no two live
    sequences alias a block, and free+live always partitions the pool."""
    cfg = configs.get_smoke("zamba2_1p2b")
    pool = paged.PagedPool(cfg, num_slots=num_slots, slot_len=SLOT_LEN,
                           block_size=BLOCK, num_blocks=num_slots)
    rng = np.random.default_rng(7)
    for a in actions:
        if a % 2 == 0:
            seq = pool.admit(rng.integers(0, cfg.vocab_size, 8))
            if seq is None:
                assert pool.free_slots == 0 or pool.free_blocks == 0
            else:
                pool.finalize_prefill(seq)
        elif pool.seqs:
            slots = sorted(pool.seqs)
            pool.release(slots[(a // 2) % len(slots)])
        pool.alloc.check_invariants()
        held = [s.blocks[0] for s in pool.seqs.values()]
        assert len(held) == len(set(held)), "live state rows alias"
        assert all(bid != 0 for bid in held), "sentinel handed out"
        for bid in held:
            assert pool.alloc.refcount(bid) == 1, "state blocks never share"
        # fixed-state registers nothing in the prefix index → no cached
        # blocks; free + one-per-live-seq covers the usable pool exactly
        assert pool.cached_blocks == 0
        assert pool.free_blocks + len(held) == pool.alloc.num_blocks - 1
