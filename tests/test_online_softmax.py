"""Core online-softmax properties — unit + hypothesis property tests.

When hypothesis is unavailable (offline container), the tests degrade to
fixed-seed parametrized sampling via ``_hypothesis_compat`` — same
properties, deterministic examples — so collection never aborts the suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp
except ImportError:                                    # offline fallback
    from _hypothesis_compat import given, hnp, settings, st

from repro import core

jax.config.update("jax_enable_x64", False)

FINITE = dict(allow_nan=False, allow_infinity=False, min_value=-60,
              max_value=60, allow_subnormal=False)  # XLA flushes denormals


def arrays(min_len=1, max_len=257):
    return hnp.arrays(np.float32, st.integers(min_len, max_len),
                      elements=st.floats(width=32, **FINITE))


class TestAlgorithmEquivalence:
    """Algorithm 3 == Algorithm 2 == Algorithm 1 (where safe)."""

    @settings(deadline=None, max_examples=25)
    @given(arrays())
    def test_online_equals_safe(self, x):
        y1 = np.asarray(core.online_softmax(x))
        y2 = np.asarray(core.safe_softmax(x))
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-7)

    @settings(deadline=None, max_examples=20)
    @given(arrays(max_len=129))
    def test_scan_form_equals_parallel_form(self, x):
        m1, d1 = core.online_normalizer_scan(x)
        m2, d2 = core.online_normalizer(x)
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-4, atol=1e-6)

    def test_naive_overflows_where_online_does_not(self):
        x = jnp.array([500.0, 1.0, 2.0])
        assert not np.isfinite(np.asarray(core.naive_softmax(x))).all()
        y = np.asarray(core.online_softmax(x))
        assert np.isfinite(y).all() and abs(y.sum() - 1) < 1e-5


class TestCombineOperator:
    """Eq. (4): ⊕ is associative, commutative, with identity (-inf, 0)."""

    @settings(deadline=None, max_examples=25)
    @given(st.lists(st.tuples(st.floats(width=32, **FINITE),
                              st.floats(width=32, min_value=0, max_value=1e6,
                                        allow_nan=False)),
                    min_size=3, max_size=3))
    def test_associative(self, mds):
        a, b, c = [(jnp.float32(m), jnp.float32(d)) for m, d in mds]
        left = core.combine(core.combine(a, b), c)
        right = core.combine(a, core.combine(b, c))
        np.testing.assert_allclose(left[0], right[0], rtol=1e-6)
        np.testing.assert_allclose(left[1], right[1], rtol=1e-5, atol=1e-6)

    @settings(deadline=None, max_examples=25)
    @given(st.tuples(st.floats(width=32, **FINITE),
                     st.floats(width=32, min_value=0, max_value=1e6,
                               allow_nan=False)),
           st.tuples(st.floats(width=32, **FINITE),
                     st.floats(width=32, min_value=0, max_value=1e6,
                               allow_nan=False)))
    def test_commutative(self, a, b):
        a = (jnp.float32(a[0]), jnp.float32(a[1]))
        b = (jnp.float32(b[0]), jnp.float32(b[1]))
        ab, ba = core.combine(a, b), core.combine(b, a)
        np.testing.assert_allclose(ab[0], ba[0])
        np.testing.assert_allclose(ab[1], ba[1], rtol=1e-6)

    @settings(deadline=None, max_examples=15)
    @given(st.floats(width=32, **FINITE),
           st.floats(width=32, min_value=0, max_value=1e6,
                     allow_nan=False, allow_subnormal=False))
    def test_identity(self, m, d):
        ident = core.identity_like(())
        out = core.combine(ident, (jnp.float32(m), jnp.float32(d)))
        np.testing.assert_allclose(out[0], m, rtol=1e-6)
        np.testing.assert_allclose(out[1], d, rtol=1e-6)

    @settings(deadline=None, max_examples=15)
    @given(arrays(min_len=8, max_len=200), st.integers(1, 64))
    def test_blocked_reduction_any_block(self, x, block):
        """§3.1: any ⊕ tree gives the same (m, d)."""
        m1, d1 = core.online_normalizer(x)
        m2, d2 = core.online_normalizer_blocked(x, block=block)
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-4, atol=1e-6)


class TestPaperInvariants:
    """The paper's §3 safety guarantees."""

    @settings(deadline=None, max_examples=25)
    @given(arrays())
    def test_d_bounds(self, x):
        """1 ≤ d_V ≤ V (paper: overflow-safe for V up to 1.7e37)."""
        _, d = core.online_normalizer(x)
        v = x.shape[-1]
        assert float(d) >= 1.0 - 1e-5
        assert float(d) <= v * (1 + 1e-5)

    @settings(deadline=None, max_examples=25)
    @given(arrays(), st.floats(min_value=-30, max_value=30, width=32,
                               allow_nan=False))
    def test_shift_invariance(self, x, c):
        y1 = np.asarray(core.online_softmax(x))
        y2 = np.asarray(core.online_softmax(x + np.float32(c)))
        np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-6)

    @settings(deadline=None, max_examples=25)
    @given(arrays())
    def test_normalization(self, x):
        y = np.asarray(core.online_softmax(x))
        np.testing.assert_allclose(y.sum(), 1.0, rtol=1e-4)
        assert (y >= 0).all()

    def test_masked_rows_are_zero_not_nan(self):
        x = jnp.ones((2, 8))
        where = jnp.zeros((2, 8), bool).at[0].set(True)
        y = np.asarray(core.online_softmax(x, where=where))
        assert np.isfinite(y).all()
        np.testing.assert_allclose(y[1], 0.0)
        np.testing.assert_allclose(y[0].sum(), 1.0, rtol=1e-5)


class TestLogsumexp:
    def test_matches_scipy_style(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 333)) * 20
        lse = core.online_logsumexp(x)
        ref = jax.scipy.special.logsumexp(x, axis=-1)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(ref),
                                   rtol=1e-6)
