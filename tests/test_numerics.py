"""Exactness bounds for the quantized / reduced-precision numerics (ISSUE 10).

Every approximate path in the serving stack is pinned against the fp32
reference within an ANALYTIC error bound — a number computed from the input's
shape and dynamic range by ``repro.core.softmax_forms``, never a tolerance
tuned to make the test pass.  The load-bearing claims:

* **Reduced softmax forms stay inside their derived bounds**: the
  bf16-accumulator and exp2-exponential online forms deviate from the fp32
  two-pass reference (``core.safe_softmax``) by at most the rounding budget
  their derivations count — across adversarial inputs (huge dynamic range,
  constant rows, −inf masks), and the bounds themselves stay non-vacuous.
* **int8 KV roundtrip obeys the half-ulp + bf16-scale bound** (property
  test): quantize→dequantize error per element never exceeds
  ``s·(½ + 127·u_bf16 + slack)`` with the fp32 per-position scale recomputed
  in-test — including denormal rows (scale clamp), constant rows, and
  mixed-magnitude rows.
* **The family dequant hook IS the kernel arithmetic**:
  ``DenseInt8Family.dequantize_block`` reproduces ``int8·scale`` bit-for-bit,
  so the serving-layer hook cannot drift from the lowered gather.
* **Quantized attention error composes**: int8 K/V attention deviates from
  fp32 attention by at most the propagated bound
  ``2·Δ·max|v̂| + b_v`` with ``Δ = scale·max‖q‖₁·b_k`` (softmax L1
  perturbation ≤ 2·score L∞ perturbation).
* **Paged int8 gather is EXACT**: the block-table gather + dequant route
  produces bit-identical output to the contiguous int8 cache — paging is a
  layout change even when the payload is quantized.
* **Form preference routes through dispatch**: ``set_softmax_form`` swaps the
  registry op ``online_softmax`` resolves to, and rejects unknown forms.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp
except ImportError:                                    # offline fallback
    from _hypothesis_compat import given, hnp, settings, st

import repro.configs as configs
from repro.core import naive_attention, safe_softmax
from repro.core import softmax_forms as sf
from repro.kernels import dispatch
from repro.models.layers import _quantize_kv
from repro.serving import cache_family

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Reduced-precision softmax forms vs the fp32 two-pass reference.
# ---------------------------------------------------------------------------
def _gaussian(rng):
    return rng.normal(scale=4.0, size=(6, 300)).astype(np.float32)


def _wide_range(rng):
    # scores spanning ~60 — the regime where a naive (max-free) softmax
    # overflows and where the exp2 bound's R term dominates
    return (rng.normal(scale=20.0, size=(4, 257))).astype(np.float32)


def _shifted(rng):
    # large common offset: the online max-subtraction must absorb it
    return (rng.normal(size=(3, 128)) + 1.0e4).astype(np.float32)


def _constant_rows(rng):
    return np.full((5, 200), 3.25, np.float32)


def _masked(rng):
    # −inf tail (padding mask): dead entries must contribute exactly zero
    x = rng.normal(scale=3.0, size=(4, 192)).astype(np.float32)
    x[:, 150:] = -np.inf
    return x


def _short_rows(rng):
    return rng.normal(size=(8, 3)).astype(np.float32)


def _long_rows(rng):
    return rng.normal(scale=2.0, size=(2, 4096)).astype(np.float32)


_INPUTS = [_gaussian, _wide_range, _shifted, _constant_rows, _masked,
           _short_rows, _long_rows]


@pytest.mark.parametrize("form", sorted(sf.FORMS))
@pytest.mark.parametrize("maker", _INPUTS, ids=lambda f: f.__name__[1:])
def test_form_within_analytic_bound(form, maker):
    """max-abs deviation from safe_softmax ≤ the form's derived bound, and
    the bound is non-vacuous (≪ 1, the trivial bound for probabilities)."""
    x = maker(np.random.default_rng(zlib_seed(form, maker)))
    apply_fn, bound_fn = sf.FORMS[form]
    got = np.asarray(apply_fn(jnp.asarray(x)))
    ref = np.asarray(safe_softmax(jnp.asarray(x)))
    try:
        bound = bound_fn(x)
    except ValueError:
        # only bf16 over very long rows refuses (bound would exceed 1 —
        # vacuous for probabilities); every other combination must price
        assert form == "bf16" and x.shape[-1] >= 2048
        pytest.skip("bound vacuous by design in this regime")
    err = np.abs(got - ref).max()
    assert err <= bound, (
        f"{form} form exceeded its analytic bound: err={err:.3e} "
        f"bound={bound:.3e}")
    assert bound < 1.0, f"{form} bound is vacuous ({bound:.3e})"
    # still a distribution: rows sum to 1 within the same budget
    live = ~np.isneginf(x).all(axis=-1)
    sums = got.sum(axis=-1)[live]
    assert np.abs(sums - 1.0).max() <= x.shape[-1] * bound


def zlib_seed(*parts):
    import zlib
    return zlib.crc32("|".join(str(p) for p in parts).encode())


def test_exp2_bound_tracks_dynamic_range():
    """The exp2 derivation charges 4·R·u₃₂ for the exponent product — a
    wider row range must produce a strictly larger bound."""
    rng = np.random.default_rng(0)
    narrow = rng.normal(scale=1.0, size=(4, 256)).astype(np.float32)
    wide = narrow * 50.0
    assert sf.exp2_error_bound(wide) > sf.exp2_error_bound(narrow)


def test_bounds_order_by_precision():
    """bf16 admits more error than exp2, which admits more than exact — the
    bounds must reflect the precision ladder on the same input."""
    x = np.random.default_rng(1).normal(size=(4, 512)).astype(np.float32)
    assert (sf.bf16_error_bound(x) > sf.exp2_error_bound(x)
            > sf.exact_error_bound(x))


def test_bf16_bound_refuses_vacuous_regimes():
    """Past ~16k blocks the bf16 accumulator budget exceeds 1 — the bound
    must refuse loudly instead of returning a number nothing can violate."""
    with pytest.raises(ValueError, match="vacuous"):
        sf.bf16_error_bound(np.zeros((1, 4096)), block=1)


# ---------------------------------------------------------------------------
# Form preference: dispatch routing.
# ---------------------------------------------------------------------------
def test_dispatch_softmax_form_preference():
    x = jnp.asarray(np.random.default_rng(2)
                    .normal(size=(3, 200)).astype(np.float32))
    exact = np.asarray(dispatch.online_softmax(x))
    prev = dispatch.set_softmax_form("bf16")
    try:
        assert prev == "exact" and dispatch.softmax_form() == "bf16"
        got = np.asarray(dispatch.online_softmax(x))
        np.testing.assert_array_equal(
            got, np.asarray(sf.softmax_bf16(x)))
        assert np.abs(got - exact).max() <= sf.bf16_error_bound(np.asarray(x))
        dispatch.set_softmax_form("exp2")
        np.testing.assert_array_equal(
            np.asarray(dispatch.online_softmax(x)),
            np.asarray(sf.softmax_exp2(x)))
    finally:
        dispatch.set_softmax_form("exact")
    np.testing.assert_array_equal(np.asarray(dispatch.online_softmax(x)),
                                  exact)


def test_dispatch_rejects_unknown_form():
    with pytest.raises(ValueError, match="exp2"):
        dispatch.set_softmax_form("fp8")
    assert dispatch.softmax_form() == "exact"


def test_env_var_selects_form_at_import():
    """REPRO_SOFTMAX_FORM is read once at dispatch import — the deployment
    knob must take effect without any code calling set_softmax_form."""
    code = ("import repro.kernels.dispatch as d; "
            "print(d.softmax_form())")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "REPRO_SOFTMAX_FORM": "exp2",
             "PYTHONPATH": os.path.join(REPO, "src")})
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "exp2"


def test_registry_lists_reduced_forms():
    assert dispatch.PATH_XLA in dispatch.available("online_softmax_bf16")
    assert dispatch.PATH_XLA in dispatch.available("online_softmax_exp2")


# ---------------------------------------------------------------------------
# int8 quantize→dequantize roundtrip (property test, satellite 1).
# ---------------------------------------------------------------------------
def _roundtrip_check(x):
    """x [T, D] fp32 → quantize per row → dequantize → per-row analytic
    bound, with the fp32 scale recomputed here (the cache stores bf16)."""
    x4 = jnp.asarray(x)[None, :, None, :]              # [1, T, 1, D]
    q, s_bf16 = _quantize_kv(x4)
    deq = np.asarray(q.astype(jnp.float32)
                     * s_bf16.astype(jnp.float32)[..., None])[0, :, 0]
    scale = np.abs(x).max(axis=-1) / 127.0             # fp32, pre-clamp
    bound = sf.int8_roundtrip_bound(scale)             # clamps internally
    err = np.abs(deq - np.asarray(x)).max(axis=-1)
    assert (err <= bound).all(), (
        f"roundtrip exceeded bound: worst err={err.max():.3e} at bound="
        f"{bound[err.argmax()]:.3e}")


@settings(deadline=None, max_examples=20)
@given(hnp.arrays(np.float32, (7, 24),
                  elements=st.floats(width=32, min_value=-1e4,
                                     max_value=1e4)))
def test_int8_roundtrip_within_bound(x):
    _roundtrip_check(x)


@pytest.mark.parametrize("maker", [
    lambda rng: np.zeros((3, 16), np.float32),
    lambda rng: np.full((3, 16), 1e-38, np.float32),     # denormal-ish: clamp
    lambda rng: np.full((2, 8), 7.5, np.float32),        # constant rows
    lambda rng: np.where(rng.random((4, 32)) < 0.5,      # 12 decades of range
                         rng.normal(scale=1e-8, size=(4, 32)),
                         rng.normal(scale=1e4, size=(4, 32))
                         ).astype(np.float32),
    lambda rng: rng.normal(scale=3e4, size=(4, 64)).astype(np.float32),
], ids=["zeros", "denormal", "constant", "mixed-decades", "large"])
def test_int8_roundtrip_adversarial(maker):
    _roundtrip_check(maker(np.random.default_rng(9)))


def test_scale_clamp_floors_dead_rows():
    """An all-zeros position must quantize to q=0 with the clamped scale —
    dequantizing dead pool regions yields exact zeros, not NaNs."""
    q, s = _quantize_kv(jnp.zeros((1, 4, 2, 8)))
    assert np.asarray(q).max() == 0
    np.testing.assert_array_equal(
        np.asarray(s.astype(jnp.float32)),
        np.float32(jnp.bfloat16(1e-8)))     # the clamp, bf16-rounded


# ---------------------------------------------------------------------------
# The family hook is the kernel arithmetic.
# ---------------------------------------------------------------------------
def _int8_cfg():
    return configs.get_smoke("smollm_360m").replace(kv_cache_dtype="int8")


def test_dequantize_block_matches_kernel_arithmetic():
    cfg = _int8_cfg()
    family = cache_family.resolve(cfg)
    assert family.quantized and family.paged_serveable
    rng = np.random.default_rng(3)
    hkv, bs, hd = 2, 8, 16
    k = rng.normal(size=(1, bs, hkv, hd)).astype(np.float32)
    v = rng.normal(size=(1, bs, hkv, hd)).astype(np.float32)
    k8, ks = _quantize_kv(jnp.asarray(k))
    v8, vs = _quantize_kv(jnp.asarray(v))
    # one physical block's payload, block layout [Hkv, BS, ·]
    block = {"attn": {
        "k": jnp.swapaxes(k8[0], 0, 1), "k_scale": jnp.swapaxes(ks[0], 0, 1),
        "v": jnp.swapaxes(v8[0], 0, 1), "v_scale": jnp.swapaxes(vs[0], 0, 1)}}
    deq = family.dequantize_block(block)["attn"]
    want_k = (np.asarray(k8[0], np.float32).swapaxes(0, 1)
              * np.asarray(ks[0], np.float32).swapaxes(0, 1)[..., None])
    np.testing.assert_array_equal(np.asarray(deq["k"]), want_k)
    # and the hook's output obeys the roundtrip bound vs the original fp
    bound = sf.int8_roundtrip_bound(np.abs(k).max(axis=-1) / 127.0)
    err = np.abs(np.asarray(deq["k"]).swapaxes(0, 1) - k[0]).max(axis=-1)
    assert (err <= bound[0]).all()


def test_fp_family_dequantize_block_is_identity():
    cfg = configs.get_smoke("smollm_360m")
    family = cache_family.resolve(cfg)
    block = {"attn": {"k": jnp.ones((2, 8, 4)), "v": jnp.ones((2, 8, 4))}}
    assert family.dequantize_block(block) is block


# ---------------------------------------------------------------------------
# Quantized attention: composed error bound.
# ---------------------------------------------------------------------------
def test_int8_attention_within_propagated_bound():
    """Attention over dequantized int8 K/V vs fp32 K/V: output error ≤
    2·Δ·max|v̂| + b_v with Δ = scale·max‖q‖₁·b_k — the score perturbation
    pushed through softmax's L1 stability (‖σ(a)−σ(b)‖₁ ≤ 2‖a−b‖∞)."""
    rng = np.random.default_rng(4)
    b, t, h, d = 2, 24, 2, 16
    q = rng.normal(size=(b, t, h, d)).astype(np.float32)
    k = rng.normal(size=(b, t, h, d)).astype(np.float32)
    v = rng.normal(size=(b, t, h, d)).astype(np.float32)
    k8, ks = _quantize_kv(jnp.asarray(k))
    v8, vs = _quantize_kv(jnp.asarray(v))
    khat = np.asarray(k8.astype(jnp.float32)
                      * ks.astype(jnp.float32)[..., None])
    vhat = np.asarray(v8.astype(jnp.float32)
                      * vs.astype(jnp.float32)[..., None])

    ref = np.asarray(naive_attention(jnp.asarray(q), jnp.asarray(k),
                                     jnp.asarray(v), causal=True))
    got = np.asarray(naive_attention(jnp.asarray(q), jnp.asarray(khat),
                                     jnp.asarray(vhat), causal=True))

    sm_scale = d ** -0.5
    bk = sf.int8_roundtrip_bound(np.abs(k).max(axis=-1) / 127.0).max()
    bv = sf.int8_roundtrip_bound(np.abs(v).max(axis=-1) / 127.0).max()
    delta = sm_scale * np.abs(q).sum(axis=-1).max() * bk
    bound = 2.0 * delta * np.abs(vhat).max() + bv
    err = np.abs(got - ref).max()
    # 5% cushion for fp32 evaluation slop in the two oracles themselves
    assert err <= 1.05 * bound, f"err={err:.4e} bound={bound:.4e}"
    # non-vacuous: the bound undercuts the trivial |out| ≤ max|v| by a lot
    assert bound < 0.5 * np.abs(v).max()


# ---------------------------------------------------------------------------
# Paged int8 gather: EXACT vs the contiguous quantized cache.
# ---------------------------------------------------------------------------
def _scatter_to_pools(k8, ks, tables, bs):
    """Contiguous [B, S, Hkv, ·] → pool [P, Hkv, BS, ·] through the table."""
    b, s, hkv = k8.shape[:3]
    m = s // bs
    p = int(np.asarray(tables).max()) + 1
    pool = np.zeros((p, hkv) + (bs,) + k8.shape[3:], k8.dtype)
    spool = np.zeros((p, hkv, bs), ks.dtype)
    for bi in range(b):
        for mi in range(m):
            seg = slice(mi * bs, (mi + 1) * bs)
            pool[np.asarray(tables)[bi, mi]] = \
                np.asarray(k8[bi, seg]).swapaxes(0, 1)
            spool[np.asarray(tables)[bi, mi]] = \
                np.asarray(ks[bi, seg]).swapaxes(0, 1)
    return jnp.asarray(pool), jnp.asarray(spool)


def test_paged_int8_decode_bit_exact_vs_contiguous():
    """The acceptance pin: gather-then-dequantize through a scattered block
    table equals the contiguous int8 decode BIT-FOR-BIT (same chunk split,
    same dequant arithmetic, same masking)."""
    cfg = _int8_cfg()
    rng = np.random.default_rng(5)
    b, hkv, hd, bs, m = 3, 2, 16, 8, 4
    s = bs * m                                          # gathered == slot_len
    k = rng.normal(size=(b, s, hkv, hd)).astype(np.float32)
    v = rng.normal(size=(b, s, hkv, hd)).astype(np.float32)
    k8, ks = _quantize_kv(jnp.asarray(k))
    v8, vs = _quantize_kv(jnp.asarray(v))
    tables = jnp.asarray(
        rng.permutation(b * m)[: b * m].reshape(b, m) + 1, jnp.int32)
    k_pool, ks_pool = _scatter_to_pools(k8, ks, tables, bs)
    v_pool, vs_pool = _scatter_to_pools(v8, vs, tables, bs)

    q = jnp.asarray(rng.normal(size=(b, 1, hkv, hd)).astype(np.float32))
    vlen = jnp.asarray([5, 17, 32], jnp.int32)
    contiguous = dispatch.sdpa(
        cfg, q, k8, v8, causal=False, q_offset=vlen - 1, kv_valid_len=vlen,
        decode=True, k_scale=ks, v_scale=vs)
    paged = dispatch.sdpa(
        cfg, q, k_pool, v_pool, causal=False, q_offset=vlen - 1,
        kv_valid_len=vlen, decode=True, block_tables=tables,
        k_scale=ks_pool, v_scale=vs_pool)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(contiguous))


def test_paged_int8_gather_roundtrip_bound_end_to_end():
    """And vs the ORIGINAL fp K/V, the paged-int8 output obeys the same
    propagated bound as the contiguous quantized form — paging adds zero
    extra error on top of quantization."""
    cfg = _int8_cfg()
    rng = np.random.default_rng(6)
    b, hkv, hd, bs, m = 2, 2, 16, 8, 3
    s = bs * m
    k = rng.normal(size=(b, s, hkv, hd)).astype(np.float32)
    v = rng.normal(size=(b, s, hkv, hd)).astype(np.float32)
    k8, ks = _quantize_kv(jnp.asarray(k))
    v8, vs = _quantize_kv(jnp.asarray(v))
    tables = jnp.asarray(
        rng.permutation(b * m).reshape(b, m) + 1, jnp.int32)
    k_pool, ks_pool = _scatter_to_pools(k8, ks, tables, bs)
    v_pool, vs_pool = _scatter_to_pools(v8, vs, tables, bs)
    q = jnp.asarray(rng.normal(size=(b, 1, hkv, hd)).astype(np.float32))
    vlen = jnp.full((b,), s, jnp.int32)
    paged = np.asarray(dispatch.sdpa(
        cfg, q, k_pool, v_pool, causal=False, q_offset=vlen - 1,
        kv_valid_len=vlen, decode=True, block_tables=tables,
        k_scale=ks_pool, v_scale=vs_pool))
    ref = np.asarray(naive_attention(q, jnp.asarray(k), jnp.asarray(v),
                                     causal=False, kv_valid_len=vlen))
    sm_scale = hd ** -0.5
    bk = sf.int8_roundtrip_bound(np.abs(k).max(axis=-1) / 127.0).max()
    bv = sf.int8_roundtrip_bound(np.abs(v).max(axis=-1) / 127.0).max()
    vhat_max = np.abs(np.asarray(v8.astype(jnp.float32)
                                 * vs.astype(jnp.float32)[..., None])).max()
    delta = sm_scale * np.abs(np.asarray(q)).sum(axis=-1).max() * bk
    bound = 2.0 * delta * vhat_max + bv
    assert np.abs(paged - ref).max() <= 1.05 * bound
