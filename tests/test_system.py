"""End-to-end system behaviour for the paper's pipeline:
train a small LM with every paper-technique switched on, then serve it with
fused top-k sampling — the full §4 scenario."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.configs.base import OptimizerConfig, RunConfig
from repro.data.synthetic import SyntheticConfig, SyntheticDataset
from repro.models import layers as L, transformer
from repro.serving import engine
from repro.training.train_step import init_state, make_train_step


def test_train_then_serve_end_to_end(tmp_path):
    cfg = configs.get_smoke("smollm_360m")
    assert cfg.use_chunked_ce and cfg.use_online_attention
    run = RunConfig(model=cfg,
                    optimizer=OptimizerConfig(lr=2e-3, warmup_steps=5,
                                              total_steps=50,
                                              schedule="constant"),
                    checkpoint_dir=str(tmp_path))
    params, opt, _ = init_state(run, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(run), donate_argnums=(0, 1))
    ds = SyntheticDataset(SyntheticConfig(vocab_size=cfg.vocab_size,
                                          seq_len=64, global_batch=8))
    losses = []
    for i in range(25):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(l) for l in losses)

    # serve: prefill a prompt, decode 8 tokens with fused softmax+topk
    prompt = ds.batch(100)["tokens"][:2, :16]
    prompt = jnp.asarray(prompt)
    last, caches, length = engine.prefill(params, prompt, cfg, max_len=32)
    tok = None
    for i in range(8):
        tokens = prompt[:, -1:] if tok is None else tok[:, None]
        tok, caches, length = engine.decode_step(
            params, caches, length, tokens, cfg,
            rng=jax.random.PRNGKey(i), top_k=5)
        assert tok.shape == (2,)
        assert (np.asarray(tok) < cfg.vocab_size).all()


def test_chunked_ce_equals_full_ce_in_model_loss():
    """Flipping the paper's chunked-CE switch must not change the loss."""
    cfg = configs.get_smoke("smollm_360m")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    ds = SyntheticDataset(SyntheticConfig(vocab_size=cfg.vocab_size,
                                          seq_len=32, global_batch=2))
    batch = jax.tree.map(jnp.asarray, ds.batch(0))
    l1, _ = transformer.loss_fn(params, batch, cfg)
    l2, _ = transformer.loss_fn(params, batch,
                                cfg.replace(use_chunked_ce=False))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


def test_online_vs_naive_attention_in_model():
    """Flipping the online-attention switch must not change the forward."""
    cfg = configs.get_smoke("mistral_nemo_12b")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                                cfg.vocab_size)
    h1, _, _ = transformer.forward(params, tokens, cfg)
    h2, _, _ = transformer.forward(params, tokens,
                                   cfg.replace(use_online_attention=False))
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=2e-3, atol=2e-3)
