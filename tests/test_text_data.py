"""Byte-corpus pipeline tests."""
import numpy as np
import pytest

from repro.data.text import BOS, ByteCorpus, TextConfig


@pytest.fixture
def corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(bytes(range(256)) * 64)
    return str(p)


def test_deterministic_and_shifted(corpus):
    ds = ByteCorpus(TextConfig(path=corpus, seq_len=32, global_batch=4))
    b1, b2 = ds.batch(7), ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], ds.batch(8)["tokens"])
    # next-byte prediction: labels[t] == tokens[t+1] (after the BOS shift)
    np.testing.assert_array_equal(b1["tokens"][:, 2:], b1["labels"][:, 1:-1])
    assert (b1["tokens"][:, 0] == BOS).all()


def test_fingerprint_stable(corpus):
    ds = ByteCorpus(TextConfig(path=corpus, seq_len=16, global_batch=2))
    assert ds.fingerprint() == ds.fingerprint()
    assert (ds.batch(0)["labels"] < 256).all()
