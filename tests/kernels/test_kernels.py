"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.online_softmax import (
    online_normalizer_pallas,
    online_softmax_pallas,
)
from repro.kernels.softmax_topk import softmax_topk_pallas


def _x(shape, dtype, scale=8.0, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale
    return x.astype(dtype)


SOFTMAX_CASES = [
    # (rows, vocab, r_blk, v_blk)
    (8, 128, 8, 128),
    (16, 1024, 4, 256),
    (32, 2048, 32, 512),
    (64, 1000, 16, 250),      # non-power-of-2 vocab
    (1, 4096, 1, 1024),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,v,rb,vb", SOFTMAX_CASES)
def test_online_softmax_kernel(r, v, rb, vb, dtype):
    x = _x((r, v), dtype)
    y = online_softmax_pallas(x, r_blk=rb, v_blk=vb, interpret=True)
    expect = ref.softmax_ref(x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("r,v,rb,vb", SOFTMAX_CASES[:3])
def test_online_normalizer_kernel(r, v, rb, vb):
    x = _x((r, v), jnp.float32)
    m, d = online_normalizer_pallas(x, r_blk=rb, v_blk=vb, interpret=True)
    mr, dr = ref.normalizer_ref(x)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), rtol=1e-5)


@pytest.mark.parametrize("k", [1, 5, 16])
@pytest.mark.parametrize("r,v,rb,vb", SOFTMAX_CASES[:4])
def test_softmax_topk_kernel(r, v, rb, vb, k):
    x = _x((r, v), jnp.float32, seed=3)
    vals, idx, lse = softmax_topk_pallas(x, k, r_blk=rb, v_blk=vb,
                                         interpret=True)
    vr, ir, lr = ref.softmax_topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vr),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lr), rtol=1e-5)


def test_softmax_topk_kernel_ties_break_low_index():
    x = jnp.zeros((4, 256))            # all equal: indices must be 0..k-1
    _, idx, _ = softmax_topk_pallas(x, 4, r_blk=4, v_blk=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.tile(np.arange(4), (4, 1)))


ATTN_CASES = [
    # (B, Tq, Tk, Hq, Hkv, Dh, bq, bk)
    (1, 64, 64, 4, 4, 32, 16, 16),     # MHA
    (2, 64, 64, 8, 2, 32, 32, 16),     # GQA
    (2, 128, 128, 4, 1, 64, 32, 64),   # MQA
    (1, 96, 96, 2, 2, 16, 32, 32),     # non-pow2 seq
]


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("B,Tq,Tk,Hq,Hkv,Dh,bq,bk", ATTN_CASES)
def test_flash_attention_kernel(B, Tq, Tk, Hq, Hkv, Dh, bq, bk, causal):
    q = _x((B, Hq, Tq, Dh), jnp.float32, 1.0, 1)
    k = _x((B, Hkv, Tk, Dh), jnp.float32, 1.0, 2)
    v = _x((B, Hkv, Tk, Dh), jnp.float32, 1.0, 3)
    out, lse = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                      interpret=True)
    qm = jnp.swapaxes(q, 1, 2)
    km = jnp.swapaxes(k, 1, 2)
    vm = jnp.swapaxes(v, 1, 2)
    expect = ref.attention_ref(qm, km, vm, causal=causal)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(out, 1, 2)),
                               np.asarray(expect), rtol=2e-5, atol=2e-5)
    assert np.isfinite(np.asarray(lse)).all()


def test_flash_attention_grads_vs_reference():
    B, T, Hq, Hkv, Dh = 2, 64, 4, 2, 16
    q = _x((B, T, Hq, Dh), jnp.float32, 1.0, 4)
    k = _x((B, T, Hkv, Dh), jnp.float32, 1.0, 5)
    v = _x((B, T, Hkv, Dh), jnp.float32, 1.0, 6)
    f1 = lambda q, k, v: (ops.flash_attention(q, k, v, causal=True,
                                              bq=16, bk=16) ** 2).mean()
    f2 = lambda q, k, v: (ref.attention_ref(q, k, v, causal=True) ** 2).mean()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)


@pytest.mark.parametrize("S,bk", [(128, 32), (256, 64), (64, 64)])
@pytest.mark.parametrize("Hq,Hkv", [(8, 2), (4, 4), (4, 1)])
def test_flash_decode_kernel(S, bk, Hq, Hkv):
    B, Dh = 3, 32
    q = _x((B, Hq, Dh), jnp.float32, 1.0, 7)
    kc = _x((B, Hkv, S, Dh), jnp.float32, 1.0, 8)
    vc = _x((B, Hkv, S, Dh), jnp.float32, 1.0, 9)
    vlen = jnp.array([S, S // 2, 1], jnp.int32)
    out = flash_decode_pallas(q, kc, vc, vlen, bk=bk, interpret=True)
    expect = ref.decode_attention_ref(q, jnp.swapaxes(kc, 1, 2),
                                      jnp.swapaxes(vc, 1, 2), vlen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_ops_wrappers_batch_shapes():
    x = _x((2, 3, 512), jnp.float32)
    y = ops.online_softmax(x, r_blk=2, v_blk=128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.softmax_ref(x)),
                               rtol=2e-5, atol=1e-7)
    vals, idx, lse = ops.softmax_topk(x, 3, r_blk=2, v_blk=128)
    assert vals.shape == (2, 3, 3) and idx.shape == (2, 3, 3)
