"""Pallas flash-attention BACKWARD kernels vs the jnp oracle's autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _x(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("B,T,Hq,Hkv,Dh,bq,bk", [
    (2, 64, 4, 4, 32, 16, 16),     # MHA
    (1, 64, 8, 2, 32, 32, 16),     # GQA (dk/dv group reduction)
    (2, 96, 4, 1, 16, 32, 32),     # MQA, non-pow2 seq
])
def test_flash_bwd_matches_reference(B, T, Hq, Hkv, Dh, bq, bk, causal):
    q = _x((B, T, Hq, Dh), 0)
    k = _x((B, T, Hkv, Dh), 1)
    v = _x((B, T, Hkv, Dh), 2)
    w = _x((B, T, Hq, Dh), 3)

    def f_kernel(q, k, v):
        return (ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
                * w).sum()

    def f_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal=causal) * w).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=2e-5,
                                   err_msg=f"d{name} mismatch")


def test_flash_bwd_in_training_step():
    """The kernel path trains: one grad step through a 2-layer toy model."""
    import repro.configs as configs
    from repro.models import layers as L, transformer
    cfg = configs.get_smoke("mistral_nemo_12b").replace(
        use_pallas=True, attn_chunk=32)
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          cfg.vocab_size)}
    (loss, _), grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
