"""Paged flash kernels: block-pool + block-table attention vs the
chunked-XLA gather fallback (interpret mode on non-TPU CI).

The contract: gathering K/V pages through a ``[B, max_blocks]`` block table
inside the kernel's index maps computes the same attention as materializing
the gather and running the contiguous forms — across ragged per-row valid
lengths, valid lengths straddling a block boundary, scattered physical
block placement, and tables where several rows share physical blocks
(prefix sharing).  Dead table entries hold the sentinel (0) and must never
influence the result.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.kernels import dispatch, ops

B, HQ, HKV, D = 3, 4, 2, 16
BS, M = 8, 4                       # block size, max blocks per row
P = B * M + 1                      # physical pool incl. sentinel block 0


@pytest.fixture(scope="module")
def pool():
    rng = np.random.default_rng(0)
    k_pool = jnp.asarray(rng.normal(size=(P, HKV, BS, D)).astype(np.float32))
    v_pool = jnp.asarray(rng.normal(size=(P, HKV, BS, D)).astype(np.float32))
    # scattered, non-contiguous physical placement (never the sentinel)
    tables = jnp.asarray(
        rng.permutation(P - 1)[:B * M].reshape(B, M) + 1, jnp.int32)
    return k_pool, v_pool, tables


def _gathered(pool_arr, tables):
    g = jnp.swapaxes(pool_arr[tables], 2, 3)
    return g.reshape(tables.shape[0], -1, HKV, D)


# ---------------------------------------------------------------------------
# Decode.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("vlens", [
    (5, 17, 32),          # mid-block, block-straddling, full
    (1, 8, 9),            # first position only / exact boundary / boundary+1
    (32, 32, 32),
])
def test_paged_decode_matches_gather_reference(pool, vlens):
    k_pool, v_pool, tables = pool
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, HQ, D)).astype(np.float32))
    vlen = jnp.asarray(vlens, jnp.int32)
    got = ops.paged_flash_decode(q, k_pool, v_pool, tables, vlen)
    want = core.naive_attention(q[:, None], _gathered(k_pool, tables),
                                _gathered(v_pool, tables), causal=False,
                                kv_valid_len=vlen)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_ignores_dead_table_entries(pool):
    """Entries at or past ceil(vlen/BS) are dead; sentinel vs garbage ids
    must not change the result (the index maps clamp, the mask erases)."""
    k_pool, v_pool, tables = pool
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(B, HQ, D)).astype(np.float32))
    vlen = jnp.asarray([7, 12, 3], jnp.int32)   # 1, 2, 1 live blocks
    live = [1, 2, 1]
    t_sentinel = np.asarray(tables).copy()
    t_other = np.asarray(tables).copy()
    for b, n in enumerate(live):
        t_sentinel[b, n:] = 0
        t_other[b, n:] = (b + 1) % (P - 1) + 1  # some other row's live block
    got_s = ops.paged_flash_decode(q, k_pool, v_pool,
                                   jnp.asarray(t_sentinel), vlen)
    got_o = ops.paged_flash_decode(q, k_pool, v_pool,
                                   jnp.asarray(t_other), vlen)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(got_o))


def test_paged_decode_shared_blocks(pool):
    """Two rows whose tables point at the same physical blocks (prefix
    sharing) read identical content: same q ⇒ same output."""
    k_pool, v_pool, tables = pool
    rng = np.random.default_rng(3)
    q_row = rng.normal(size=(1, HQ, D)).astype(np.float32)
    q = jnp.asarray(np.repeat(q_row, B, axis=0))
    shared = np.asarray(tables).copy()
    shared[1] = shared[0]                       # full sharing
    vlen = jnp.asarray([19, 19, 19], jnp.int32)
    out = ops.paged_flash_decode(q, k_pool, v_pool, jnp.asarray(shared), vlen)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))


# ---------------------------------------------------------------------------
# Prefill (offset form over pages).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qoffs,tq", [
    ((0, 0, 0), 8),               # fresh prefill through the table
    ((2, 9, 20), 6),              # ragged offsets, boundary-straddling vlen
    ((7, 15, 25), 1),             # single-row chunks
])
def test_paged_prefill_matches_chunked_xla(pool, qoffs, tq):
    k_pool, v_pool, tables = pool
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(B, tq, HQ, D)).astype(np.float32))
    qoff = jnp.asarray(qoffs, jnp.int32)
    vlen = qoff + tq
    got = ops.paged_flash_attention(q, k_pool, v_pool, qoff, vlen, tables,
                                    causal=True)
    want = core.online_attention(q, _gathered(k_pool, tables),
                                 _gathered(v_pool, tables), causal=True,
                                 q_offset=qoff, kv_valid_len=vlen,
                                 chunk_size=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_bq_tiling_consistent(pool):
    """Explicit bq values tile the q axis differently but must agree."""
    k_pool, v_pool, tables = pool
    rng = np.random.default_rng(5)
    tq = 8
    q = jnp.asarray(rng.normal(size=(B, tq, HQ, D)).astype(np.float32))
    qoff = jnp.asarray([0, 4, 16], jnp.int32)
    vlen = qoff + tq
    outs = [ops.paged_flash_attention(q, k_pool, v_pool, qoff, vlen, tables,
                                      causal=True, bq=bq) for bq in (2, 4, 8)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Quantized pools: in-kernel int8 dequant gather (ISSUE 10).
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def int8_pool():
    """int8 K/V pools + bfloat16 scale pages on the same block axis."""
    rng = np.random.default_rng(7)
    k8 = jnp.asarray(rng.integers(-127, 128, (P, HKV, BS, D)), jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 128, (P, HKV, BS, D)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.2, (P, HKV, BS)), jnp.bfloat16)
    vs = jnp.asarray(rng.uniform(0.01, 0.2, (P, HKV, BS)), jnp.bfloat16)
    tables = jnp.asarray(
        rng.permutation(P - 1)[:B * M].reshape(B, M) + 1, jnp.int32)
    return k8, v8, ks, vs, tables


def _dequant_gathered(pool8, spool, tables):
    """The reference: materialize the gather, THEN dequantize — the kernel
    must compute this while reading int8 + scales through the table."""
    return (_gathered(pool8.astype(jnp.float32), tables)
            * _gathered_scales(spool, tables).astype(jnp.float32)[..., None])


def _gathered_scales(spool, tables):
    g = jnp.swapaxes(spool[tables], 2, 3)
    return g.reshape(tables.shape[0], -1, HKV)


@pytest.mark.parametrize("vlens", [(5, 17, 32), (1, 8, 9)])
def test_paged_decode_int8_dequantizes_in_kernel(int8_pool, vlens):
    k8, v8, ks, vs, tables = int8_pool
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(B, HQ, D)).astype(np.float32))
    vlen = jnp.asarray(vlens, jnp.int32)
    got = ops.paged_flash_decode(q, k8, v8, tables, vlen,
                                 k_scale_pool=ks, v_scale_pool=vs)
    want = core.naive_attention(
        q[:, None], _dequant_gathered(k8, ks, tables),
        _dequant_gathered(v8, vs, tables), causal=False,
        kv_valid_len=vlen)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_int8_dequantizes_in_kernel(int8_pool):
    k8, v8, ks, vs, tables = int8_pool
    rng = np.random.default_rng(9)
    tq = 6
    q = jnp.asarray(rng.normal(size=(B, tq, HQ, D)).astype(np.float32))
    qoff = jnp.asarray([2, 9, 20], jnp.int32)
    vlen = qoff + tq
    got = ops.paged_flash_attention(q, k8, v8, qoff, vlen, tables,
                                    causal=True, k_scale_pool=ks,
                                    v_scale_pool=vs)
    want = core.online_attention(
        q, _dequant_gathered(k8, ks, tables),
        _dequant_gathered(v8, vs, tables), causal=True, q_offset=qoff,
        kv_valid_len=vlen, chunk_size=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_int8_dead_tiles_stay_dead(int8_pool):
    """Scale pages ride the SAME clamped page index as K/V — dead table
    entries (sentinel vs garbage) must not change the quantized result."""
    k8, v8, ks, vs, tables = int8_pool
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(B, HQ, D)).astype(np.float32))
    vlen = jnp.asarray([7, 12, 3], jnp.int32)
    live = [1, 2, 1]
    t_sentinel = np.asarray(tables).copy()
    t_other = np.asarray(tables).copy()
    for b, n in enumerate(live):
        t_sentinel[b, n:] = 0
        t_other[b, n:] = (b + 1) % (P - 1) + 1
    got_s = ops.paged_flash_decode(q, k8, v8, jnp.asarray(t_sentinel), vlen,
                                   k_scale_pool=ks, v_scale_pool=vs)
    got_o = ops.paged_flash_decode(q, k8, v8, jnp.asarray(t_other), vlen,
                                   k_scale_pool=ks, v_scale_pool=vs)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(got_o))


def test_sdpa_paged_int8_pallas_matches_xla_gather(int8_pool):
    """dispatch.sdpa with quantized pools: the Pallas preference (interpret
    here) and the XLA dequant-gather fallback must agree."""
    import repro.configs as configs
    cfg = configs.get_smoke("smollm_360m").replace(kv_cache_dtype="int8")
    k8, v8, ks, vs, tables = int8_pool
    rng = np.random.default_rng(11)
    tq = 4
    q = jnp.asarray(rng.normal(size=(B, tq, HQ, D)).astype(np.float32))
    qoff = jnp.asarray([0, 5, 11], jnp.int32)
    vlen = qoff + tq
    kw = dict(causal=True, q_offset=qoff, kv_valid_len=vlen,
              block_tables=tables, k_scale=ks, v_scale=vs)
    ref = dispatch.sdpa(cfg, q, k8, v8, **kw)
    got = dispatch.sdpa(cfg.replace(use_pallas=True), q, k8, v8, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Dispatch routing.
# ---------------------------------------------------------------------------
def test_paged_registry_paths_registered():
    assert dispatch.PATH_XLA in dispatch.available("paged_attention")
    assert dispatch.PATH_PALLAS in dispatch.available("paged_attention")
    assert dispatch.PATH_XLA in dispatch.available("paged_decode_attention")
    assert dispatch.PATH_PALLAS in dispatch.available("paged_decode_attention")


def test_sdpa_paged_routes_and_matches(pool):
    """dispatch.sdpa with block_tables set must agree between the Pallas
    preference (interpret here) and the XLA gather fallback — prefill and
    decode."""
    import repro.configs as configs
    cfg = configs.get_smoke("smollm_360m")
    k_pool, v_pool, tables = pool
    rng = np.random.default_rng(6)
    tq = 4
    q = jnp.asarray(rng.normal(size=(B, tq, HQ, D)).astype(np.float32))
    qoff = jnp.asarray([0, 5, 11], jnp.int32)
    vlen = qoff + tq
    kw = dict(causal=True, q_offset=qoff, kv_valid_len=vlen,
              block_tables=tables)
    ref = dispatch.sdpa(cfg, q, k_pool, v_pool, **kw)
    got = dispatch.sdpa(cfg.replace(use_pallas=True), q, k_pool, v_pool, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    qd = q[:, :1]
    kwd = dict(causal=False, q_offset=vlen, kv_valid_len=vlen + 1,
               decode=True, block_tables=tables)
    ref_d = dispatch.sdpa(cfg, qd, k_pool, v_pool, **kwd)
    got_d = dispatch.sdpa(cfg.replace(use_pallas=True), qd, k_pool, v_pool,
                          **kwd)
    # non-native backends route the Pallas decode preference to the XLA
    # gather form (same policy as the contiguous decode), so this is exact
    # there and allclose on TPU
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(ref_d),
                               rtol=2e-5, atol=2e-5)


def test_paged_tiles_resolve_through_registry():
    tiles = dispatch.attention_tiles("flash_attention_paged", kv_len=64,
                                     head_dim=16)
    assert set(tiles) == {"bq"} and tiles["bq"] > 0
    tiles_off = dispatch.attention_tiles("flash_attention_offset", kv_len=64,
                                         head_dim=16)
    assert set(tiles_off) == {"bq", "bk"}
