"""Regression suite: offset/valid-length-aware Pallas flash prefill.

PR 2 routed ALL cached prefill onto the chunked XLA form because the Pallas
kernel's chunk-local causal mask would silently drop the already-prefilled
prefix — the exact bug class pinned here.  These tests run the kernel in
interpret mode (non-TPU CI executes the same kernel body the TPU compiles)
and pin its outputs against the chunked XLA form across offsets, chunk
boundaries, and ragged per-row ``kv_valid_len``.

Also here: the ``softmax_topk`` custom-VJP satellite — the MoE router runs
the Pallas path under ``value_and_grad`` with its gradient checked against
the XLA form.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs.base import ModelConfig
from repro.kernels import dispatch, ops, ref
from repro.kernels.flash_attention import flash_attention_offset_pallas


def _x(shape, seed, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32) * scale


def _ref_rows(q, k, v, *, causal, q_offset, kv_valid_len):
    """Per-row oracle (ref.attention_ref takes a scalar q_offset)."""
    outs = []
    for b in range(q.shape[0]):
        outs.append(ref.attention_ref(
            q[b:b + 1], k[b:b + 1], v[b:b + 1], causal=causal,
            q_offset=int(q_offset[b]), kv_valid_len=kv_valid_len[b:b + 1]))
    return jnp.concatenate(outs, axis=0)


# ---------------------------------------------------------------------------
# Kernel level: absolute-coordinate masking on the raw pallas_call.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tq,bq,bk", [(8, 8, 16), (6, 2, 16), (16, 8, 64),
                                      (4, 4, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_offset_kernel_matches_oracle(tq, bq, bk, causal):
    B, Hq, Hkv, Dh, S = 3, 4, 2, 16, 64
    q = _x((B, Hq, tq, Dh), 0)
    k = _x((B, Hkv, S, Dh), 1)
    v = _x((B, Hkv, S, Dh), 2)
    qoff = jnp.asarray([0, 7, S - tq], jnp.int32)    # incl. cache-full row
    vlen = qoff + tq                                 # self-consistent prefill
    out, lse = flash_attention_offset_pallas(q, k, v, qoff, vlen,
                                             causal=causal, bq=bq, bk=bk,
                                             interpret=True)
    want = _ref_rows(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                     jnp.swapaxes(v, 1, 2), causal=causal, q_offset=qoff,
                     kv_valid_len=vlen)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(out, 1, 2)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)
    assert np.isfinite(np.asarray(lse)).all()


def test_offset_kernel_ragged_valid_len():
    """Ragged rows: every slot masks its own tail, including vlen=1 and a
    tile-boundary-straddling vlen."""
    B, Hq, Hkv, Dh, S = 4, 4, 1, 16, 64
    q = _x((B, Hq, 4, Dh), 3)
    k = _x((B, Hkv, S, Dh), 4)
    v = _x((B, Hkv, S, Dh), 5)
    vlen = jnp.asarray([1, 17, 33, 64], jnp.int32)   # straddle bk=16 tiles
    qoff = jnp.zeros((B,), jnp.int32)
    out, _ = flash_attention_offset_pallas(q, k, v, qoff, vlen, causal=False,
                                           bq=4, bk=16, interpret=True)
    want = _ref_rows(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                     jnp.swapaxes(v, 1, 2), causal=False, q_offset=qoff,
                     kv_valid_len=vlen)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(out, 1, 2)),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


def test_offset_zero_matches_offsetless_kernel():
    """q_offset=0 with a fully-valid KV must reproduce the legacy kernel —
    the single-shot prefill PR 2 regressed to XLA for no correctness reason."""
    from repro.kernels.flash_attention import flash_attention_pallas
    B, H, T, Dh = 2, 4, 32, 16
    q, k, v = _x((B, H, T, Dh), 6), _x((B, H, T, Dh), 7), _x((B, H, T, Dh), 8)
    legacy, lse_l = flash_attention_pallas(q, k, v, causal=True, bq=8, bk=8,
                                           interpret=True)
    off, lse_o = flash_attention_offset_pallas(
        q, k, v, jnp.zeros((B,), jnp.int32), jnp.full((B,), T, jnp.int32),
        causal=True, bq=8, bk=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(lse_l), np.asarray(lse_o))


# ---------------------------------------------------------------------------
# ops level: padding + chunked-XLA equivalence at q_offset > 0.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("offset", [0, 3, 11, 28])
@pytest.mark.parametrize("chunk_size", [4, 16, 64])
def test_ops_flash_offset_matches_chunked_xla(offset, chunk_size):
    """The acceptance pin: Pallas (interpret) vs the chunked XLA form for
    cached prefill, across chunk boundaries of BOTH implementations."""
    B, t, Hq, Hkv, Dh, S = 2, 4, 4, 2, 16, 48
    q = _x((B, t, Hq, Dh), 9)
    k = _x((B, S, Hkv, Dh), 10)
    v = _x((B, S, Hkv, Dh), 11)
    qoff = jnp.full((B,), offset, jnp.int32)
    vlen = qoff + t
    got = ops.flash_attention(q, k, v, causal=True, bq=t, bk=16,
                              q_offset=qoff, kv_valid_len=vlen)
    want = core.online_attention(q, k, v, causal=True, q_offset=qoff,
                                 kv_valid_len=vlen, chunk_size=chunk_size)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ops_flash_offset_pads_unaligned_kv():
    """S not a multiple of bk: the wrapper pads KV and the valid-length mask
    erases the padding."""
    B, t, H, Dh, S = 2, 4, 2, 16, 43                 # 43 % 16 != 0
    q = _x((B, t, H, Dh), 12)
    k = _x((B, S, H, Dh), 13)
    v = _x((B, S, H, Dh), 14)
    vlen = jnp.asarray([9, 43], jnp.int32)
    got = ops.flash_attention(q, k, v, causal=False, bq=t, bk=16,
                              q_offset=jnp.zeros((B,), jnp.int32),
                              kv_valid_len=vlen)
    want = core.online_attention(q, k, v, causal=False, kv_valid_len=vlen,
                                 chunk_size=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# dispatch level: routing + end-to-end equivalence of the two forms.
# ---------------------------------------------------------------------------
def _cfg(**kw):
    return ModelConfig(name="t", family="dense", d_model=32, num_layers=1,
                       num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                       max_seq_len=64, **kw)


@pytest.mark.parametrize("offset,ragged", [(0, False), (5, False), (5, True),
                                           (21, True)])
def test_dispatch_sdpa_cached_prefill_pallas_vs_xla(offset, ragged):
    """`dispatch.sdpa` serves cached chunked prefill on the Pallas form under
    a Pallas preference (interpret here; compiled on TPU) and the result
    matches the chunked XLA form within fp tolerance."""
    B, t, Hq, Hkv, Dh, S = 3, 6, 4, 2, 16, 64
    q = _x((B, t, Hq, Dh), 15)
    k = _x((B, S, Hkv, Dh), 16)
    v = _x((B, S, Hkv, Dh), 17)
    if ragged:       # per-row offsets: slots at different fill levels
        qoff = jnp.asarray([offset, offset + 2, offset + 9], jnp.int32)
    else:
        qoff = jnp.full((B,), offset, jnp.int32)
    vlen = qoff + t
    got = dispatch.sdpa(_cfg(use_pallas=True), q, k, v, causal=True,
                        q_offset=qoff, kv_valid_len=vlen)
    want = dispatch.sdpa(_cfg(use_online_attention=True), q, k, v,
                         causal=True, q_offset=qoff, kv_valid_len=vlen)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dispatch_routes_cached_prefill_to_pallas_under_preference():
    """The routing itself: a use_pallas cfg takes the pallas(-interpret) path
    for cached prefill, and the fresh train path stays differentiable."""
    path = dispatch.select_path("attention", prefer_pallas=True)
    caps_native = dispatch.compat.capabilities().pallas_native
    assert path == (dispatch.PATH_PALLAS if caps_native
                    else dispatch.PATH_PALLAS_INTERPRET)
    # MLA-shaped attention (custom scale, value dim != key dim) must not
    # reach the kernel: dv != dk would mis-shape the accumulator
    B, t, H, S = 2, 4, 2, 32
    q = _x((B, t, H, 24), 18)
    k = _x((B, S, 1, 24), 19)
    v = _x((B, S, 1, 16), 20)                        # value dim 16 != 24
    vlen = jnp.full((B,), t, jnp.int32)
    out = dispatch.sdpa(_cfg(use_pallas=True), q, k, v, causal=True,
                        q_offset=jnp.zeros((B,), jnp.int32),
                        kv_valid_len=vlen, scale=0.25)
    want = core.online_attention(q, k, v, causal=True,
                                 q_offset=jnp.zeros((B,), jnp.int32),
                                 kv_valid_len=vlen, scale=0.25,
                                 chunk_size=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_engine_chunked_prefill_pallas_equivalence_across_chunks():
    """End to end through the serving engine: chunked prefill with a Pallas
    preference equals the XLA form for several chunkings of one prompt."""
    import repro.configs as configs
    from repro.models import layers as L, transformer
    from repro.serving import engine
    cfg = configs.get_smoke("smollm_360m")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    prompt = jnp.asarray(np.arange(13)[None] % 256)
    ref_last, _, _ = engine.chunked_prefill(params, prompt, cfg, max_len=32,
                                            chunk=0)
    for chunk in (3, 5, 8):
        got_last, _, _ = engine.chunked_prefill(
            params, prompt, cfg.replace(use_pallas=True), max_len=32,
            chunk=chunk)
        np.testing.assert_allclose(np.asarray(got_last), np.asarray(ref_last),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"chunk={chunk}")


# ---------------------------------------------------------------------------
# softmax_topk custom VJP: the MoE router off the XLA pin.
# ---------------------------------------------------------------------------
def test_softmax_topk_kernel_grad_matches_xla_form():
    x = _x((6, 64), 21, scale=4.0)

    def f_pallas(x):
        vals, _, lse = ops.softmax_topk(x, 5, r_blk=2, v_blk=32)
        return (vals ** 2).sum() + 0.1 * (lse ** 2).sum()

    def f_xla(x):
        out = core.softmax_topk(x, 5)
        return (out.values ** 2).sum() + 0.1 * (out.logsumexp ** 2).sum()

    g_pallas = jax.grad(f_pallas)(x)
    g_xla = jax.grad(f_xla)(x)
    np.testing.assert_allclose(np.asarray(g_pallas), np.asarray(g_xla),
                               rtol=1e-4, atol=1e-6)


def test_moe_router_runs_pallas_topk_under_value_and_grad(monkeypatch):
    """Acceptance pin: the router through the Pallas softmax_topk path (its
    custom VJP) under value_and_grad, gradient checked against the XLA form.
    On this host the kernel runs in interpret mode; on TPU the same rule
    wraps the compiled kernel."""
    import repro.configs as configs
    from repro.models import layers as L, transformer

    cfg = configs.get_smoke("qwen2_moe_a2p7b")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(3), cfg))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                                          cfg.vocab_size)}

    def grads_with(path):
        monkeypatch.setattr(
            dispatch, "lookup",
            lambda op, prefer_pallas=False: (path, dispatch._REGISTRY[op][path]))
        (loss, _), g = jax.value_and_grad(
            lambda p: transformer.loss_fn(p, batch, cfg), has_aux=True)(params)
        return float(loss), g

    loss_p, g_pallas = grads_with(dispatch.PATH_PALLAS_INTERPRET)
    loss_x, g_xla = grads_with(dispatch.PATH_XLA)
    assert np.isfinite(loss_p) and abs(loss_p - loss_x) < 1e-4
    flat_p = jax.tree.leaves(g_pallas)
    flat_x = jax.tree.leaves(g_xla)
    for a, b in zip(flat_p, flat_x):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-4, atol=1e-5)
