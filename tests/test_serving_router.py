"""Engine API + replica router: determinism, affinity, backpressure, merge.

The router contract extends the scheduler's: WHERE a request lands — which
replica, next to which neighbours, behind which routing policy — never
changes WHAT it generates, because every replica shares the same base RNG
and sample streams are keyed (base_rng, request id, token index).  On top of
that the router must earn its keep: same-prefix requests converge on one
replica (so the persistent prefix cache pays across arrivals), N=4 affinity
routing beats hash-free round-robin on aggregate prefix reuse (the PR
acceptance bar), every-replica-starved admission rejects instead of
queueing, and merged reports compute percentiles over the union of raw
latencies — never an average of per-replica p95s.
"""
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.serving import engine, scheduler
from repro.serving.engine_api import Engine
from repro.serving.router import ReplicaRouter

SLOT_LEN = 48
CHUNK = 8
TOP_K = 5
BLOCK = 8
BASE_RNG = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("smollm_360m")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    return params, cfg


def _key(rid, step):
    return jax.random.fold_in(jax.random.fold_in(BASE_RNG, rid), step)


def _single_sequence_decode(params, cfg, req):
    """The request alone: chunked prefill + per-slot decode at batch size 1."""
    last, caches, ln = engine.chunked_prefill(
        params, jnp.asarray(req.prompt)[None], cfg, max_len=SLOT_LEN,
        chunk=CHUNK)
    logits = engine.logits_from_hidden(params, last, cfg)
    tok = engine.sample_per_slot(_key(req.rid, 0)[None], logits, TOP_K)
    tokens = [int(tok[0])]
    lens = jnp.asarray([int(ln)], jnp.int32)
    for step in range(1, req.max_new_tokens):
        tok, caches, lens = engine.decode_step_slots(
            params, caches, lens, tok[:, None], cfg,
            rngs=_key(req.rid, step)[None], top_k=TOP_K)
        tokens.append(int(tok[0]))
    return tokens


def _prefix_groups(groups=3, members=4, prefix_len=16, seed=3):
    """Prefix-heavy workload: ``groups`` system prompts, ``members``
    requests each.  Group members are spaced 8 ticks apart so earlier
    members finish prefill (and retire into the persistent prefix cache)
    before later ones arrive — the regime affinity routing pays in."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, 512, prefix_len) for _ in range(groups)]
    out = []
    for j in range(members):
        for g in range(groups):
            body = rng.integers(0, 512, 3 + g + j)
            out.append(scheduler.Request(
                rid=g * members + j,
                prompt=np.concatenate([prefixes[g], body]),
                max_new_tokens=3, arrival_tick=j * 8 + g * 2))
    return out


def _router(params, cfg, replicas, *, affinity=True, slots=2, **kw):
    return ReplicaRouter(
        params, cfg, replicas=replicas, affinity=affinity,
        num_slots=slots, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=BLOCK, **kw)


# ---------------------------------------------------------------------------
# Determinism: routing never changes any request's stream.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def solo_streams(model):
    """rid → tokens for the shared workload, each run alone (computed once;
    the references every replica count must reproduce bit-for-bit)."""
    params, cfg = model
    return {req.rid: _single_sequence_decode(params, cfg, req)
            for req in _prefix_groups()}


@pytest.mark.parametrize("replicas", [1, 2, 4])
def test_routed_streams_bit_identical_to_solo(model, solo_streams, replicas):
    params, cfg = model
    requests = _prefix_groups()
    report = _router(params, cfg, replicas).serve(requests)
    assert len(report.results) == len(requests)
    by_rid = {r.rid: r for r in report.results}
    for req in requests:
        want = solo_streams[req.rid]
        assert by_rid[req.rid].tokens == want, (
            f"request {req.rid} diverged under {replicas} replicas:"
            f" routed={by_rid[req.rid].tokens} alone={want}")


# ---------------------------------------------------------------------------
# Affinity: same prefix → same replica, and it beats round-robin.
# ---------------------------------------------------------------------------
def test_same_prefix_lands_on_same_replica(model):
    params, cfg = model
    requests = _prefix_groups()
    router = _router(params, cfg, 4)
    report = router.serve(requests)
    assign = report.router["assignments"]
    for g in range(3):
        group = [assign[g * 4 + j] for j in range(4)]
        assert len(set(group)) == 1, f"group {g} scattered: {group}"
    # later group members find the prefix minted by the first — real block
    # reuse (live or via the persistent cache), not just co-location
    assert report.paged["tokens_reused"] > 0
    assert (report.paged["blocks_shared"] > 0
            or report.paged["prefix_cache_hits"] > 0)
    assert report.router["affinity_routes"] > 0


def test_affinity_beats_round_robin_hit_rate(model):
    """PR acceptance bar: N=4 prefix-affinity routing shows a strictly
    higher aggregate prefix reuse rate than hash-free round-robin on the
    same prefix-heavy staggered workload."""
    params, cfg = model
    requests = _prefix_groups()
    prompt_tokens = sum(len(r.prompt) for r in requests)

    rep_aff = _router(params, cfg, 4, affinity=True).serve(requests)
    rep_rr = _router(params, cfg, 4, affinity=False).serve(requests)
    assert rep_rr.router["affinity"] is False

    hit_aff = rep_aff.paged["tokens_reused"] / prompt_tokens
    hit_rr = rep_rr.paged["tokens_reused"] / prompt_tokens
    assert hit_aff > hit_rr, (hit_aff, hit_rr)
    assert rep_aff.paged["tokens_reused"] > 0
    # and the detour cost nothing in correctness: identical streams
    toks_aff = {r.rid: r.tokens for r in rep_aff.results}
    toks_rr = {r.rid: r.tokens for r in rep_rr.results}
    assert toks_aff == toks_rr


# ---------------------------------------------------------------------------
# Backpressure: every-replica-starved admission rejects, not queues.
# ---------------------------------------------------------------------------
def test_backpressure_rejects_when_all_replicas_starved(model):
    params, cfg = model
    rng = np.random.default_rng(9)
    reqs = [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 8),
                              max_new_tokens=7,
                              arrival_tick=[1, 1, 2, 2, 4, 4][i])
            for i in range(6)]
    router = ReplicaRouter(
        params, cfg, replicas=2, num_slots=1, slot_len=16,
        prefill_chunk=CHUNK, top_k=TOP_K, base_rng=BASE_RNG,
        paged=True, block_size=BLOCK, num_blocks=2)
    report = router.serve(reqs)
    # wave 1 (rids 0,1) occupies both pools; wave 2 (2,3) queues; by wave 3
    # every replica has a full-pool-deep queue and zero placeable blocks
    assert report.router["backpressure_rejects"] == 2
    assert report.router["rejected"] == [4, 5]
    served = sorted(r.rid for r in report.results)
    assert served == [0, 1, 2, 3]
    for r in report.results:
        assert len(r.tokens) == 7


# ---------------------------------------------------------------------------
# ServeReport.merge: raw-latency percentiles, counter sums, SLO counts.
# ---------------------------------------------------------------------------
def _result(rid, lats, *, priority=0, slo_ms=None, finish=None):
    r = scheduler.RequestResult(rid=rid, prompt_len=4, priority=priority,
                                slo_ms=slo_ms)
    t = 10.0
    r.arrival_time = t
    for l in lats:
        t += l
        r.record_latency(l)
        r.tokens.append(0)
    r.finish_time = finish if finish is not None else t
    return r


def test_merge_percentiles_over_union_not_averaged():
    # one replica all-fast, one all-slow: the merged p95 must be the p95 of
    # the CONCATENATED raw latencies (≈ slow tail), which no averaging of
    # per-replica p95s produces
    fast = [0.010] * 19 + [0.020]
    slow = [0.100] * 20
    rep_a = scheduler.ServeReport(
        results=[_result(0, fast)], decode_steps=20, prefill_chunks=2,
        occupancy=1.0, wall_time=1.0)
    rep_b = scheduler.ServeReport(
        results=[_result(1, slow)], decode_steps=60, prefill_chunks=3,
        occupancy=0.5, wall_time=2.0,
        paged={"block_size": 8, "num_blocks": 4, "tokens_reused": 5})
    merged = scheduler.ServeReport.merge([rep_a, rep_b])

    want = float(np.percentile(fast + slow, 95))
    got = merged.latency_percentiles((95,))["p95"]
    assert got == pytest.approx(want)
    mean_of_p95s = (rep_a.latency_percentiles((95,))["p95"]
                    + rep_b.latency_percentiles((95,))["p95"]) / 2
    assert abs(got - mean_of_p95s) > 1e-6     # averaging would be wrong here

    assert merged.decode_steps == 80
    assert merged.prefill_chunks == 5
    assert merged.wall_time == 2.0            # concurrent replicas: max
    assert merged.occupancy == pytest.approx((1.0 * 20 + 0.5 * 60) / 80)
    assert merged.paged == {"block_size": 8, "num_blocks": 4,
                            "tokens_reused": 5}
    assert merged.total_tokens == 40

    single = scheduler.ServeReport.merge([rep_a])
    assert single.occupancy == rep_a.occupancy
    with pytest.raises(ValueError):
        scheduler.ServeReport.merge([])


def test_merge_wall_time_uses_overlapped_interval():
    """Replicas that serve concurrently but start/stop at different moments:
    merged throughput must be over the true overlapped wall interval
    (max end − min start), not the longest per-replica wall_time."""
    rep_a = scheduler.ServeReport(
        results=[_result(0, [0.01] * 10)], decode_steps=10, prefill_chunks=1,
        occupancy=1.0, wall_time=1.0, started_at=100.0, ended_at=101.0)
    rep_b = scheduler.ServeReport(
        results=[_result(1, [0.01] * 10)], decode_steps=10, prefill_chunks=1,
        occupancy=1.0, wall_time=1.5, started_at=100.5, ended_at=102.0)
    merged = scheduler.ServeReport.merge([rep_a, rep_b])
    assert merged.wall_time == pytest.approx(2.0)     # 100.0 → 102.0
    assert merged.started_at == 100.0 and merged.ended_at == 102.0
    assert merged.tokens_per_s == pytest.approx(20 / 2.0)

    # unstamped reports (hand-built, or pre-stamping files): the old
    # conservative max-of-walls fallback
    rep_c = scheduler.ServeReport(
        results=[_result(2, [0.01])], decode_steps=1, prefill_chunks=1,
        occupancy=1.0, wall_time=3.0)
    merged2 = scheduler.ServeReport.merge([rep_a, rep_c])
    assert merged2.wall_time == pytest.approx(3.0)


def test_merge_slo_counts_by_class():
    met = _result(0, [0.001], priority=0, slo_ms=1000.0)
    missed = _result(1, [0.002], priority=0, slo_ms=1.0,
                     finish=10.0 + 5.0)    # 5 s after arrival ≫ 1 ms SLO
    free = _result(2, [0.003], priority=1)
    rep_a = scheduler.ServeReport(results=[met, free], decode_steps=1,
                                  prefill_chunks=1, occupancy=1.0,
                                  wall_time=1.0)
    rep_b = scheduler.ServeReport(results=[missed], decode_steps=1,
                                  prefill_chunks=1, occupancy=1.0,
                                  wall_time=1.0)
    merged = scheduler.ServeReport.merge([rep_a, rep_b])
    assert merged.slo_counts_by_class() == {0: (1, 2)}
    assert merged.slo_attainment() == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Engine surface: the narrow API the router (and CLI) is built on.
# ---------------------------------------------------------------------------
def test_engine_surface(model):
    params, cfg = model
    eng = Engine(params, cfg, num_slots=2, slot_len=SLOT_LEN,
                 prefill_chunk=CHUNK, top_k=TOP_K, base_rng=BASE_RNG,
                 paged=True, block_size=BLOCK)
    prompt = np.arange(2 * BLOCK) % 512
    assert eng.cache_probe(prompt) == 0           # cold cache
    assert eng.load == 0 and not eng.busy

    eng.submit(scheduler.Request(rid=0, prompt=prompt, max_new_tokens=3))
    eng.submit(scheduler.Request(rid=1, prompt=np.arange(5) % 512,
                                 max_new_tokens=2))
    assert eng.load == 2
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 1000
    report = eng.drain()                          # idempotent when idle
    assert sorted(r.rid for r in report.results) == [0, 1]
    assert report.decode_steps > 0

    st = eng.stats()
    assert st["finished"] == 2 and st["queue_depth"] == 0
    assert st["free_slots"] == 2
    assert "free_blocks" in st                    # pool stats merged in
    # rid 0's full prompt blocks retired into the persistent prefix cache:
    # a probe for the same prompt sees them without touching the pool
    assert eng.cache_probe(prompt) >= BLOCK
    free_before = eng.stats()["free_blocks"]
    assert eng.cache_probe(prompt) >= BLOCK       # read-only: repeatable
    assert eng.stats()["free_blocks"] == free_before


def test_scheduler_run_delegates_to_engine(model):
    """Back-compat: ContinuousScheduler.run still serves (it wraps itself
    in an Engine) and reports exactly like Engine.serve."""
    params, cfg = model
    requests = _prefix_groups(groups=1, members=2)
    sched = scheduler.ContinuousScheduler(
        params, cfg, num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
        top_k=TOP_K, base_rng=BASE_RNG, paged=True, block_size=BLOCK)
    report = sched.run(requests)
    eng = Engine(params, cfg, num_slots=2, slot_len=SLOT_LEN,
                 prefill_chunk=CHUNK, top_k=TOP_K, base_rng=BASE_RNG,
                 paged=True, block_size=BLOCK)
    report2 = eng.serve(requests)
    assert ({r.rid: r.tokens for r in report.results}
            == {r.rid: r.tokens for r in report2.results})
    assert report.decode_steps == report2.decode_steps
    assert report.occupancy == report2.occupancy


# ---------------------------------------------------------------------------
# CLI regression: --replicas 1 (the default) is byte-identical to the
# pre-router CLI.
# ---------------------------------------------------------------------------
_GOLDEN_PLAIN = """\
continuous batching: 5 requests over 2 slots (slot_len=26, prefill_chunk=8)
tokens: 22 in <T>s → <R> tok/s
per-token latency: p50=<L>ms p95=<L>ms
decode steps: 11  prefill chunks: 7
batch occupancy: 0.773 (drain-and-refill baseline: 0.647)
"""

_GOLDEN_PAGED = """\
paged continuous batching: 5 requests over 2 slots (slot_len=32, \
prefill_chunk=8)
tokens: 26 in <T>s → <R> tok/s
per-token latency: p50=<L>ms p95=<L>ms
decode steps: 15  prefill chunks: 11
batch occupancy: 0.700 (drain-and-refill baseline: 0.650)
block pool: 8×8 blocks, free now 1, min free 0
blocks saved by sharing: 4 (prefill tokens reused: 32, copy-on-write \
copies: 0)
prefix cache: 7 blocks resident, 1 hits, 2 reclaimed under pressure
class 0: n=3 p50=<L>ms p95=<L>ms queued=<L>ms prefill=<L>ms \
decode=<L>ms preemptions=0
class 1: n=2 p50=<L>ms p95=<L>ms queued=<L>ms prefill=<L>ms \
decode=<L>ms preemptions=0
SLO attainment: 100.0% of 3 deadline-bearing requests
preemptions: 0 (blocks swapped out: 0, swapped back in: 0)
"""


def _normalize(text):
    text = re.sub(r"\d+\.\d+s\b", "<T>s", text)
    text = re.sub(r"\d+\.\d+ tok/s", "<R> tok/s", text)
    text = re.sub(r"\d+\.\d+ms", "<L>ms", text)
    return text


@pytest.mark.parametrize("extra,golden", [
    ([], _GOLDEN_PLAIN),
    (["--paged", "--block-size", "8", "--shared-prefix", "8",
      "--priority-classes", "2", "--slo-ms", "60000"], _GOLDEN_PAGED),
], ids=["plain", "paged_priorities"])
def test_serve_cli_single_replica_matches_prerouter_output(extra, golden):
    """Transcripts captured from the pre-router CLI (wall-clock fields
    normalized); the routered CLI with the default single replica must
    reproduce every line byte-for-byte."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--continuous", "--requests", "5", "--tokens", "8",
         "--prompt-len", "10", "--slots", "2", "--rate", "3.0",
         "--prefill-chunk", "8", "--replicas", "1"] + extra,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert _normalize(out.stdout) == _normalize(golden)
