#!/bin/sh
# Tier-1 suite in the split ROADMAP.md documents.
#
# A single `pytest -x -q` over the whole tree segfaults in XLA's
# backend_compile at ~test 230 on the CPU CI container — identically on the
# pristine seed tree, so it is cumulative-compile jaxlib flakiness, not a
# test bug.  Every test passes when the suite runs in groups; this script IS
# that split, so "run tier-1" stays one command and nothing after the crash
# point gets silently skipped.  (Three groups since PR 8: the cache-family
# suites compile enough fresh step functions that two halves re-crossed the
# threshold.)
#
# Usage: tests/run_tier1.sh  [extra pytest args appended to EVERY group]
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export PYTHONPATH

echo "== tier-1 group 1: kernels, core, models, compat, docs, obs =="
python -m pytest -x -q "$@" \
    tests/kernels \
    tests/test_attention_and_ce.py \
    tests/test_compat.py \
    tests/test_distributed.py \
    tests/test_docs.py \
    tests/test_models.py \
    tests/test_obs.py \
    tests/test_obs_history.py \
    tests/test_online_softmax.py

echo "== tier-1 group 2: serving caches (continuous, families, paged) =="
python -m pytest -x -q "$@" \
    tests/test_serving_continuous.py \
    tests/test_serving_families.py \
    tests/test_serving_paged.py

echo "== tier-1 group 3: router, slo, numerics, substrate, system, data, training =="
python -m pytest -x -q "$@" \
    tests/test_numerics.py \
    tests/test_serving_router.py \
    tests/test_serving_slo.py \
    tests/test_substrate.py \
    tests/test_system.py \
    tests/test_text_data.py \
    tests/test_training.py

echo "tier-1: all groups green"
