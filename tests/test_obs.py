"""Observability layer (ISSUE 7): injectable clock, request-lifecycle
tracing, metrics registry, kernel profiling hooks, and the trace report CLI.

The load-bearing claims:

* **The clock is a seam**: a ``VirtualClock`` injected into the scheduler
  makes every latency and phase duration an exact multiple of the advance
  step — no wall-clock noise in assertions, and the phase split
  (``queued_ms`` / ``prefill_ms`` / ``decode_ms``) tiles the request's
  lifetime exactly.
* **Traces are structurally sound**: every opened span is closed, spans on
  each track nest, and a preempted-then-resumed request's track reconstructs
  its exact token timeline (the ``token`` instants ARE the result stream).
* **Observability is free when off**: serving without a tracer produces
  bit-identical token streams to serving with one, and a disabled metrics
  registry records nothing.
* **Latency recording is bounded**: ``RequestResult`` keeps at most
  ``MAX_RECORDED_LATENCIES`` samples and counts the overflow instead of
  growing without bound.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.models import layers as L, transformer
from repro.obs import clock as obs_clock
from repro.obs import kernels as obs_kernels
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.serving import scheduler
from repro.serving.engine_api import Engine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SLOT_LEN = 48
BLOCK = 8
CHUNK = 8
TOP_K = 5
BASE_RNG = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("smollm_360m")
    params, _ = L.split_params(transformer.init(jax.random.PRNGKey(0), cfg))
    return params, cfg


def _workload(n=3, seed=2, max_new=4):
    rng = np.random.default_rng(seed)
    return [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 5 + 2 * i),
                              max_new_tokens=max_new, arrival_tick=i)
            for i in range(n)]


def _priority_workload():
    """Low-priority long decodes + an urgent mid-flight arrival over an
    undersized pool — the known-preempting recipe from test_serving_slo."""
    rng = np.random.default_rng(11)
    lo = [scheduler.Request(rid=i, prompt=rng.integers(0, 512, 9 + 2 * i),
                            max_new_tokens=12, arrival_tick=0, priority=1)
          for i in range(2)]
    hi = [scheduler.Request(rid=2, prompt=rng.integers(0, 512, 8),
                            max_new_tokens=4, arrival_tick=5, priority=0)]
    return lo + hi


def _engine(params, cfg, **kw):
    base = dict(num_slots=2, slot_len=SLOT_LEN, prefill_chunk=CHUNK,
                top_k=TOP_K, base_rng=BASE_RNG)
    base.update(kw)
    return Engine(params, cfg, **base)


def _serve_stepwise(eng, requests, clock, dt=0.010):
    """Drive the engine one tick per ``advance``: tick k runs at k*dt."""
    eng.begin()
    for r in requests:
        eng.submit(r)
    while eng.step():
        clock.advance(dt)
    return eng.report()


# ---------------------------------------------------------------------------
# Clock seam.
# ---------------------------------------------------------------------------
def test_virtual_clock_semantics():
    vc = obs_clock.VirtualClock(start=5.0)
    assert vc.monotonic() == 5.0
    assert vc.perf_counter() == 5.0 and vc.wall_time() == 5.0
    vc.advance(1.5)
    assert vc.monotonic() == 6.5
    with pytest.raises(ValueError):
        vc.advance(-0.1)


def test_set_clock_swaps_module_default():
    vc = obs_clock.VirtualClock()
    prev = obs_clock.set_clock(vc)
    try:
        assert obs_clock.get() is vc
        vc.advance(2.0)
        assert obs_clock.monotonic() == 2.0
    finally:
        obs_clock.set_clock(prev)
    assert obs_clock.get() is prev


def test_virtual_clock_latencies_exact_and_phases_tile(model):
    """Every recorded latency is an exact multiple of the tick advance, the
    phase split tiles arrival→finish exactly, and a re-run under the same
    virtual schedule reproduces the latencies bit-for-bit."""
    params, cfg = model
    dt = 0.010

    def once():
        vc = obs_clock.VirtualClock()
        rep = _serve_stepwise(_engine(params, cfg, clock=vc),
                              _workload(), vc, dt)
        return rep

    report = once()
    assert len(report.results) == 3
    for r in report.results:
        assert r.latencies, f"rid {r.rid}: no latencies recorded"
        for lat in r.latencies:
            ticks = lat / dt
            assert ticks == pytest.approx(round(ticks), abs=1e-9), (
                f"rid {r.rid}: latency {lat} is not a whole tick")
        assert r.queued_ms is not None and r.queued_ms >= 0.0
        # single-chunk prompts prefill inside one tick: exactly 0.0 virtual ms
        assert r.prefill_ms is not None and r.prefill_ms >= 0.0
        assert r.decode_ms is not None and r.decode_ms >= 0.0
        total = (r.finish_time - r.arrival_time) * 1e3
        assert r.queued_ms + r.prefill_ms + r.decode_ms == pytest.approx(
            total, abs=1e-6)
    again = once()
    assert ([r.latencies for r in report.results]
            == [r.latencies for r in again.results])
    assert report.wall_time == pytest.approx(again.wall_time)


def test_latency_recording_bounded(monkeypatch):
    monkeypatch.setattr(scheduler.RequestResult, "MAX_RECORDED_LATENCIES", 10)
    r = scheduler.RequestResult(rid=0, prompt_len=1)
    for i in range(100):
        r.record_latency(0.001)
    assert len(r.latencies) == 10
    assert r.dropped_latencies == 90
    assert r.dropped_sum == pytest.approx(0.090)


# ---------------------------------------------------------------------------
# Trace integrity.
# ---------------------------------------------------------------------------
def test_trace_closed_nested_and_perfetto_loadable(model, tmp_path):
    params, cfg = model
    path = tmp_path / "trace.json"
    vc = obs_clock.VirtualClock()
    tracer = obs_trace.Tracer(str(path), clock=vc)
    rep = _serve_stepwise(_engine(params, cfg, clock=vc, tracer=tracer),
                          _workload(), vc)
    events = tracer.close()

    with open(path) as f:
        loaded = json.load(f)          # a real JSON array: Perfetto-ready
    assert isinstance(loaded, list) and len(loaded) == len(events)
    assert obs_report.validate(loaded) == []
    phases = {e["ph"] for e in loaded}
    assert {"X", "i", "C", "M"} <= phases
    names = {e["name"] for e in loaded}
    assert {"tick", "admit", "prefill", "decode", "queued",
            "token", "retire", "sched", "thread_name"} <= names
    # one token instant per generated token, one retire per request
    tokens = [e for e in loaded if e["ph"] == "i" and e["name"] == "token"]
    assert len(tokens) == sum(len(r.tokens) for r in rep.results)
    retires = [e for e in loaded if e["ph"] == "i" and e["name"] == "retire"]
    assert len(retires) == len(rep.results)


def test_preempted_request_trace_reconstructs_token_timeline(model, tmp_path):
    """The acceptance pin: a preempted-then-resumed request's track replays
    its exact token stream, shows the suspension, and stays structurally
    sound."""
    params, cfg = model
    path = tmp_path / "preempt_trace.json"
    tracer = obs_trace.Tracer(str(path))
    eng = _engine(params, cfg, paged=True, block_size=BLOCK, num_blocks=8,
                  tracer=tracer)
    rep = eng.serve(_priority_workload())
    events = tracer.close()
    assert rep.preemptions >= 1, "workload must actually preempt"
    assert obs_report.validate(events) == []

    by_rid = {r.rid: r for r in rep.results}
    preempted = [r.rid for r in rep.results if r.preempted]
    assert preempted
    for rid, res in by_rid.items():
        tid = rid + 1
        track = [e for e in events if e.get("tid") == tid]
        toks = [e["args"]["token"] for e in track
                if e["ph"] == "i" and e["name"] == "token"]
        assert toks == res.tokens, f"rid {rid}: trace/result stream mismatch"
        # token instants are time-ordered: the timeline is reconstructible
        ts = [e["ts"] for e in track
              if e["ph"] == "i" and e["name"] == "token"]
        assert ts == sorted(ts)
    for rid in preempted:
        track = [e for e in events if e.get("tid") == rid + 1]
        assert any(e["ph"] == "i" and e["name"] == "preempt" for e in track)
        assert any(e["ph"] == "X" and e["name"] == "suspended"
                   for e in track), "swap-out must appear as a suspended span"
    total_preempts = sum(1 for e in events
                         if e["ph"] == "i" and e["name"] == "preempt")
    assert total_preempts == rep.preemptions


def test_tracing_off_streams_bit_identical(model):
    params, cfg = model
    rep_off = _engine(params, cfg).serve(_workload())
    tracer = obs_trace.Tracer(None)            # buffer-only, no file
    rep_on = _engine(params, cfg, tracer=tracer).serve(_workload())
    events = tracer.close()
    assert events, "traced run must have produced events"
    assert ({r.rid: r.tokens for r in rep_off.results}
            == {r.rid: r.tokens for r in rep_on.results})
    assert rep_off.decode_steps == rep_on.decode_steps
    assert rep_off.prefill_chunks == rep_on.prefill_chunks


def test_tracer_incremental_flush_bounds_buffer(model, tmp_path):
    """``flush_every=N`` keeps at most N events in memory over a real serve
    while the file stays one valid, complete JSON array."""
    params, cfg = model
    path = tmp_path / "flushed_trace.json"
    N = 16
    tracer = obs_trace.Tracer(str(path), flush_every=N)
    peak = 0
    emit = tracer._emit

    def spying_emit(entry):
        nonlocal peak
        emit(entry)
        peak = max(peak, len(tracer._buf))

    tracer._emit = spying_emit
    rep = _engine(params, cfg, tracer=tracer).serve(_workload())
    tail = tracer.close()
    assert peak <= N, f"buffer peaked at {peak} events (bound {N})"
    assert tracer.total_events > N, "workload too small to force a flush"
    assert len(tail) < N                   # close returns only the remainder
    with open(path) as f:
        loaded = json.load(f)
    assert len(loaded) == tracer.total_events
    assert obs_report.validate(loaded) == []
    tokens = [e for e in loaded if e["ph"] == "i" and e["name"] == "token"]
    assert len(tokens) == sum(len(r.tokens) for r in rep.results)


def test_tracer_flush_every_needs_path():
    with pytest.raises(ValueError, match="path"):
        obs_trace.Tracer(None, flush_every=4)
    with pytest.raises(ValueError, match="flush_every"):
        obs_trace.Tracer("/tmp/x.json", flush_every=0)


def test_tracer_streaming_survives_preempt_and_swap(model, tmp_path):
    """A preempt-and-swap lifecycle traced through ``flush_every=N``:
    suspend/resume spans and preempt instants land intact even though most
    of the trace left the buffer mid-run, and ``close()`` is idempotent."""
    params, cfg = model
    path = tmp_path / "stream_preempt.json"
    tracer = obs_trace.Tracer(str(path), flush_every=8)
    eng = _engine(params, cfg, paged=True, block_size=BLOCK, num_blocks=8,
                  tracer=tracer)
    rep = eng.serve(_priority_workload())
    tracer.close()
    assert rep.preemptions >= 1, "workload must actually preempt"
    assert tracer.total_events > 8, "workload too small to force a flush"

    with open(path) as f:
        loaded = json.load(f)
    assert len(loaded) == tracer.total_events
    assert obs_report.validate(loaded) == []
    preempts = [e for e in loaded
                if e["ph"] == "i" and e["name"] == "preempt"]
    assert len(preempts) == rep.preemptions
    assert any(e["ph"] == "X" and e["name"] == "suspended" for e in loaded)
    for res in rep.results:
        toks = [e["args"]["token"] for e in loaded
                if e.get("tid") == res.rid + 1
                and e["ph"] == "i" and e["name"] == "token"]
        assert toks == res.tokens, f"rid {res.rid}: stream mismatch"
    # second close: no new events, file bytes untouched
    before = path.read_bytes()
    assert tracer.close() == []
    assert path.read_bytes() == before


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------
@pytest.fixture
def registry():
    """Clean registry, restored (disabled + cleared) afterwards."""
    was = obs_metrics.enabled()
    obs_metrics.reset()
    yield obs_metrics
    obs_metrics.reset()
    (obs_metrics.enable if was else obs_metrics.disable)()


def test_metrics_disabled_records_nothing(registry):
    registry.disable()
    registry.counter("c").inc()
    registry.gauge("g").set(3.0)
    registry.histogram("h").observe(1.0)
    assert registry.snapshot() == {}


def test_metrics_enabled_counts_and_snapshots(registry):
    registry.enable()
    registry.counter("c").inc()
    registry.counter("c").inc(4)
    registry.gauge("g").set(3.0)
    registry.gauge("g").set(1.0)
    for v in (0.001, 0.002, 0.004):
        registry.histogram("h").observe(v)
    snap = registry.snapshot()
    assert snap["c"]["value"] == 5 and snap["c"]["type"] == "counter"
    assert snap["g"]["value"] == 1.0
    assert snap["g"]["min"] == 1.0 and snap["g"]["max"] == 3.0
    assert snap["h"]["count"] == 3
    assert snap["h"]["mean"] == pytest.approx(7.0 / 3000.0)
    with pytest.raises(TypeError):
        registry.gauge("c")                   # name already a counter


def test_histogram_percentiles_estimated_within_bounds(registry):
    registry.enable()
    h = registry.histogram("h")
    assert h.percentile(50) is None              # empty: no estimate
    h.observe(0.25)
    assert h.percentile(50) == pytest.approx(0.25)   # single value: exact
    assert h.percentile(95) == pytest.approx(0.25)
    for v in (0.001, 0.002, 0.004, 0.008, 0.016, 0.512):
        registry.histogram("spread").observe(v)
    s = registry.histogram("spread")
    p50, p95 = s.percentile(50), s.percentile(95)
    assert s.min <= p50 <= p95 <= s.max          # clamped, monotone in q
    assert p50 < s.mean < p95                    # the outlier skews the mean
    snap = registry.snapshot()
    assert snap["spread"]["p50"] == pytest.approx(p50)
    assert snap["spread"]["p95"] == pytest.approx(p95)
    assert "p50" not in snap.get("h_missing", {})


def test_engine_stats_metrics_include_percentiles(model, registry):
    params, cfg = model
    registry.enable()
    eng = _engine(params, cfg)
    eng.serve(_workload(n=2))
    m = eng.stats()["metrics"]
    occ = m["serving.occupancy"]
    assert occ["type"] == "histogram"
    assert occ["min"] <= occ["p50"] <= occ["p95"] <= occ["max"]


def test_engine_stats_attach_metrics_snapshot(model, registry):
    params, cfg = model
    registry.enable()
    eng = _engine(params, cfg, paged=True, block_size=BLOCK)
    rep = eng.serve(_workload(n=2))
    st = eng.stats()
    assert len(rep.results) == 2
    m = st["metrics"]
    assert m["serving.tokens"]["value"] == rep.total_tokens
    assert m["serving.occupancy"]["count"] == rep.decode_steps
    assert "serving.free_blocks" in m         # low-water via gauge min
    registry.disable()
    assert "metrics" not in eng.stats()


# ---------------------------------------------------------------------------
# Kernel profiling hooks.
# ---------------------------------------------------------------------------
def test_kernel_profile_paths_and_costs(model):
    from repro.kernels import dispatch
    params, cfg = model
    obs_kernels.reset()
    obs_kernels.enable_profiling()
    try:
        # record_path fires at jit-trace time; the shared decode steps were
        # compiled by earlier tests, so resolve an op explicitly too
        path, _ = dispatch.lookup("softmax_topk")
        eng = _engine(params, cfg)
        eng.serve(_workload(n=2))
        prof = eng.kernel_profile()
    finally:
        obs_kernels.disable_profiling()
    assert prof["paths"], "dispatch must have recorded resolved paths"
    assert prof["paths"]["softmax_topk"]["path"] == path
    for entry in prof["paths"].values():
        assert entry["path"] in ("pallas", "interpret", "xla")
        assert entry["count"] >= 1
    cost = prof["costs"]["decode_step"]
    assert cost["flops"] > 0
    assert cost["bytes_accessed"] > 0
    obs_kernels.reset()
    assert eng.kernel_profile() == {"paths": {}, "autotune": {}, "costs": {}}


# ---------------------------------------------------------------------------
# Report CLI (tier-1 smoke): a generated trace summarizes cleanly.
# ---------------------------------------------------------------------------
def test_report_cli_runs_on_generated_trace(model, tmp_path):
    params, cfg = model
    path = tmp_path / "trace.json"
    tracer = obs_trace.Tracer(str(path))
    _engine(params, cfg, tracer=tracer).serve(_workload(n=2))
    tracer.close()

    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", str(path)],
        capture_output=True, text=True, timeout=120, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tick timeline" in out.stdout
    assert "request waterfall" in out.stdout
    assert "retire causes:" in out.stdout
    assert "trace OK: all spans closed and nested" in out.stdout

    out2 = subprocess.run([sys.executable, "-m", "repro.obs.report"],
                          capture_output=True, text=True, timeout=60, env=env)
    assert out2.returncode == 2                # usage error


def test_report_cli_nonzero_on_broken_trace(tmp_path):
    """Satellite pin: the trace smoke can gate CI because a structurally
    broken trace exits 1 and names the problem."""
    path = tmp_path / "broken.json"
    path.write_text(json.dumps([
        {"name": "decode", "ph": "X", "ts": 0.0, "dur": 5.0,
         "pid": 0, "tid": 1, "args": {"unclosed": True}},
    ]))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", str(path)],
        capture_output=True, text=True, timeout=120, env=env)
    assert out.returncode == 1, out.stdout
    assert "TRACE PROBLEM" in out.stderr
    assert "unclosed" in out.stderr


# ---------------------------------------------------------------------------
# Trace diff + multi-replica merge.
# ---------------------------------------------------------------------------
def _tick(pid, ts, dur, tick, **extra):
    args = {"tick": tick, "active": 0, "queue": 0, "free_slots": 2}
    args.update(extra)
    return {"name": "tick", "ph": "X", "ts": ts, "dur": dur,
            "pid": pid, "tid": 0, "args": args}


def test_merge_aligns_first_ticks_and_renumbers_colliding_pids():
    from repro.obs import merge as obs_merge
    # both files claim pid 0 and start their clocks at different epochs
    a = [_tick(0, 1000.0, 5.0, 1), _tick(0, 1100.0, 5.0, 2)]
    b = [_tick(0, 9000.0, 7.0, 1), _tick(0, 9100.0, 7.0, 2)]
    merged = obs_merge.merge_events([a, b], labels=["a.json", "b.json"])
    ticks = [e for e in merged if e["ph"] == "X"]
    # first tick of each file lands at t=0: the common fiducial
    assert sorted(e["ts"] for e in ticks) == [0.0, 0.0, 100.0, 100.0]
    assert {e["pid"] for e in ticks} == {0, 1}   # collision → renumbered
    names = [e for e in merged if e["ph"] == "M"]
    assert {n["args"]["name"] for n in names} == {
        "replica 0 (a.json)", "replica 1 (b.json)"}
    assert obs_report.validate(merged) == []
    # distinct pids merge untouched — no renumbering, no name metadata
    c = [_tick(1, 500.0, 5.0, 1)]
    merged2 = obs_merge.merge_events([a, c])
    assert {e.get("pid") for e in merged2} == {0, 1}
    assert not any(e["ph"] == "M" for e in merged2)


def test_diff_reports_aligned_ticks_and_class_latency():
    a = [_tick(0, 0.0, 1000.0, 1), _tick(0, 2000.0, 1000.0, 2)]
    b = [_tick(0, 0.0, 2000.0, 1), _tick(0, 3000.0, 2000.0, 2)]
    text = obs_report.diff(a, b, label_a="a.json", label_b="b.json")
    assert "## Trace diff — a.json → b.json" in text
    assert "| ticks | 2 | 2 | +0.0% |" in text
    assert "| 0 | 1.000 | 2.000 | +100.0% |" in text   # aligned by index
    assert "Aligned tick timeline" in text


def test_serve_trace_dir_writes_per_replica_and_merged(model, tmp_path):
    """Acceptance pin: ``--replicas 2 --trace dir/`` produces per-replica
    traces plus a merged view that load-validates, and ``report --diff``
    runs clean on the pair."""
    tdir = tmp_path / "traces"
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--continuous", "--paged", "--replicas", "2", "--requests", "6",
         "--tokens", "6", "--no-affinity", "--trace", str(tdir) + os.sep],
        capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode in (0, 1), out.stderr[-2000:]  # 1 = occupancy warn
    paths = [tdir / f"replica{i}.json" for i in range(2)]
    merged_path = tdir / "merged.json"
    assert all(p.exists() for p in paths) and merged_path.exists()
    assert "merged view" in out.stdout

    merged = obs_report.load_trace(str(merged_path))
    assert obs_report.validate(merged) == []
    assert {e.get("pid") for e in merged} == {0, 1}
    per_replica = [obs_report.load_trace(str(p)) for p in paths]
    # the merged stream is exactly the per-replica events, clock-aligned
    assert len(merged) == sum(len(t) for t in per_replica)
    for t in per_replica:
        assert obs_report.validate(t) == []
        assert any(e["ph"] == "X" and e["name"] == "tick" for e in t)

    rep = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", str(merged_path)],
        capture_output=True, text=True, timeout=120, env=env)
    assert rep.returncode == 0, rep.stderr[-2000:]
    dif = subprocess.run(
        [sys.executable, "-m", "repro.obs.report", "--diff",
         str(paths[0]), str(paths[1])],
        capture_output=True, text=True, timeout=120, env=env)
    assert dif.returncode == 0, dif.stderr[-2000:]
    assert "## Trace diff" in dif.stdout
    assert "Aligned tick timeline" in dif.stdout
    mrg = subprocess.run(
        [sys.executable, "-m", "repro.obs.merge", str(paths[0]),
         str(paths[1]), "--out", str(tmp_path / "re_merged.json")],
        capture_output=True, text=True, timeout=120, env=env)
    assert mrg.returncode == 0, mrg.stderr[-2000:]
    assert "merged 2 traces" in mrg.stdout
