"""Per-architecture smoke tests (reduced same-family configs) + serving
consistency: prefill+decode must reproduce the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import encdec, layers as L, transformer
from repro.serving import engine

ARCHS = list(configs.ARCHS)


def _init(cfg, seed=0):
    init_fn = encdec.init if cfg.family == "encdec" else transformer.init
    params, axes = L.split_params(init_fn(jax.random.PRNGKey(seed), cfg))
    return params, axes


def _batch(cfg, B=2, T=64, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    batch = {"tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size)}
    if cfg.num_patches:
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.num_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[3], (B, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    """One forward + one grad step on CPU: shapes OK, no NaNs."""
    cfg = configs.get_smoke(arch)
    params, _ = _init(cfg)
    batch = _batch(cfg)
    loss_fn = encdec.loss_fn if cfg.family == "encdec" else transformer.loss_fn
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    if cfg.family != "encdec":
        hidden, _, _ = transformer.forward(params, batch["tokens"], cfg,
                                           patch_embeds=batch.get("patch_embeds"))
        t_expect = 64 + (cfg.num_patches or 0)
        assert hidden.shape == (2, t_expect, cfg.d_model)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get_smoke(a).family != "encdec"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode after prefill == full forward on the same seq.

    This is the strongest integration test of the cache machinery: attention
    caches, MLA latent caches, SSM/conv states, and xLSTM (m, C, n) states
    must all carry exactly the information the full forward recomputes.
    """
    cfg = configs.get_smoke(arch)
    if cfg.moe is not None:
        # capacity-based token dropping differs between grouped prefill and
        # per-token decode by construction; raise capacity so nothing drops
        # and the cache math is exactly comparable.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = _init(cfg)
    B, T, P = 2, 16, 8                  # prefill P, decode T-P more
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0,
                                cfg.vocab_size)
    patch = None
    if cfg.num_patches:
        patch = jax.random.normal(jax.random.PRNGKey(8),
                                  (B, cfg.num_patches, cfg.d_model))
    # full forward
    full, _, _ = transformer.forward(params, tokens, cfg, patch_embeds=patch)
    # prefill on the first P tokens
    max_len = T + (cfg.num_patches or 0)
    caches = engine.init_cache(cfg, B, max_len)
    hidden_p, caches, _ = transformer.forward(
        params, tokens[:, :P], cfg, patch_embeds=patch, caches=caches,
        cache_len=jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(hidden_p, np.float32), np.asarray(full[:, :hidden_p.shape[1]], np.float32),
        rtol=5e-3, atol=5e-3)
    # decode the rest one token at a time (teacher forcing)
    base = P + (cfg.num_patches or 0)
    for i in range(P, T):
        h1, caches, _ = transformer.forward(
            params, tokens[:, i:i + 1], cfg, caches=caches,
            cache_len=jnp.asarray(base + (i - P), jnp.int32))
        np.testing.assert_allclose(
            np.asarray(h1[:, 0], np.float32),
            np.asarray(full[:, (cfg.num_patches or 0) + i], np.float32),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{arch}: decode step {i} diverged from full forward")


def test_encdec_prefill_decode_consistency():
    cfg = configs.get_smoke("whisper_small")
    params, _ = _init(cfg)
    B, T, P = 2, 10, 4
    tokens = jax.random.randint(jax.random.PRNGKey(9), (B, T), 0,
                                cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(10),
                               (B, cfg.encoder_seq_len, cfg.d_model))
    enc = encdec.encode(params, frames, cfg)
    full, _ = encdec.decode_hidden(params, tokens, enc, cfg)
    _, caches, ln = engine.encdec_prefill(params, frames, tokens[:, :P], cfg,
                                          max_len=T)
    for i in range(P, T):
        h1, caches = encdec.decode_hidden(
            params, tokens[:, i:i + 1], None, cfg, caches=caches,
            cache_len=jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(np.asarray(h1[:, 0], np.float32),
                                   np.asarray(full[:, i], np.float32),
                                   rtol=5e-3, atol=5e-3,
                                   err_msg=f"whisper decode step {i}")


@pytest.mark.parametrize("arch", ["qwen2_moe_a2p7b", "llama4_scout_17b_a16e"])
def test_moe_router_uses_fused_topk_and_balances(arch):
    cfg = configs.get_smoke(arch)
    params, _ = _init(cfg)
    batch = _batch(cfg)
    loss, metrics = transformer.loss_fn(params, batch, cfg)
    assert "moe_lb_loss" in metrics and np.isfinite(float(metrics["moe_lb_loss"]))
    assert "moe_z_loss" in metrics


def test_decode_step_samples_valid_tokens():
    cfg = configs.get_smoke("smollm_360m")
    params, _ = _init(cfg)
    B = 2
    caches = engine.init_cache(cfg, B, 16)
    tok = jax.random.randint(jax.random.PRNGKey(0), (B, 1), 0, cfg.vocab_size)
    tok2, caches, ln = engine.decode_step(
        params, caches, jnp.asarray(0, jnp.int32), tok, cfg,
        rng=jax.random.PRNGKey(1), top_k=5)
    assert tok2.shape == (B,)
    assert (np.asarray(tok2) >= 0).all()
    assert (np.asarray(tok2) < cfg.vocab_size).all()
    assert int(ln) == 1
