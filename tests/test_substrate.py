"""Optimizer, data pipeline, compression, and checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import OptimizerConfig
from repro.data.synthetic import SyntheticConfig, SyntheticDataset
from repro.distributed import compression
from repro.optim import adamw


class TestAdamW:
    def test_matches_numpy_reference(self):
        cfg = OptimizerConfig(lr=1e-2, beta1=0.9, beta2=0.99, eps=1e-8,
                              weight_decay=0.0, grad_clip=0.0,
                              warmup_steps=0, total_steps=100,
                              schedule="constant")
        p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]])}
        g = {"w": jnp.array([[0.1, -0.2], [0.3, 0.4]])}
        state = adamw.init(p)
        p1, state, _ = adamw.update(g, state, p, cfg)
        # numpy reference (bias-corrected adam)
        m = 0.1 * np.asarray(g["w"])
        v = 0.01 * np.asarray(g["w"]) ** 2
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.99)
        expect = np.asarray(p["w"]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-6)

    def test_weight_decay_only_on_matrices(self):
        cfg = OptimizerConfig(lr=1e-2, weight_decay=0.1, grad_clip=0.0,
                              warmup_steps=0, schedule="constant")
        p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        state = adamw.init(p)
        p1, _, _ = adamw.update(g, state, p, cfg)
        assert (np.asarray(p1["w"]) < 1.0).all()      # decayed
        np.testing.assert_allclose(np.asarray(p1["b"]), 1.0)  # not decayed

    def test_grad_clipping(self):
        g = {"w": jnp.full((10,), 100.0)}
        clipped, gnorm = adamw.clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                                   rtol=1e-5)

    def test_schedule_shapes(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                              schedule="cosine")
        lrs = [float(adamw.schedule(jnp.asarray(s), cfg))
               for s in (0, 5, 10, 60, 110)]
        assert lrs[0] == 0.0
        assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
        assert abs(lrs[2] - 1.0) < 1e-6          # warmup done
        assert 0 < lrs[3] < 1.0                  # decaying
        assert lrs[4] < 1e-6                     # fully decayed


class TestSyntheticData:
    def test_deterministic_per_step(self):
        ds = SyntheticDataset(SyntheticConfig(vocab_size=1000, seq_len=32,
                                              global_batch=4, seed=7))
        b1, b2 = ds.batch(13), ds.batch(13)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = ds.batch(14)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_next_tokens(self):
        ds = SyntheticDataset(SyntheticConfig(vocab_size=100, seq_len=16,
                                              global_batch=2))
        b = ds.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions_batch(self):
        from repro.data.synthetic import HostShardedLoader
        ds = SyntheticDataset(SyntheticConfig(vocab_size=100, seq_len=8,
                                              global_batch=8))
        full = ds.batch(3)
        parts = [HostShardedLoader(ds, host_id=i, num_hosts=4).local_batch(3)
                 for i in range(4)]
        got = np.concatenate([p["tokens"] for p in parts])
        np.testing.assert_array_equal(got, full["tokens"])


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        """With EF, the accumulated quantization error stays bounded and the
        mean dequantized signal converges to the mean true signal."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        errors = {"g": jnp.zeros((256,))}
        acc_deq = np.zeros((256,))
        n = 50
        for _ in range(n):
            deq, errors = compression.ef_roundtrip({"g": g_true}, errors)
            acc_deq += np.asarray(deq["g"])
        np.testing.assert_allclose(acc_deq / n, np.asarray(g_true),
                                   atol=2e-2)

    def test_quantize_roundtrip_error_bounded(self):
        x = jnp.linspace(-3, 3, 1000)
        q, s = compression._quantize(x)
        err = np.abs(np.asarray(compression._dequantize(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-7

    def test_cast_grads(self):
        g = {"a": jnp.ones((4,), jnp.float32)}
        out = compression.cast_grads(g, "bfloat16")
        assert out["a"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        for step in (10, 20, 30):
            mgr.save(step, tree, blocking=True)
        assert mgr.committed_steps() == [20, 30]       # retention keep=2
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            tree)
        restored = mgr.restore(30, like)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = {"w": jnp.ones((128, 128))}
        mgr.save(1, tree, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_uncommitted_checkpoints_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = {"w": jnp.ones((4,))}
        mgr.save(5, tree, blocking=True)
        # simulate a torn write: directory without COMMITTED marker
        os.makedirs(tmp_path / "step_9")
        with open(tmp_path / "step_9" / "arrays.npz", "wb") as f:
            f.write(b"garbage")
        assert mgr.latest_step() == 5
