"""Fixed-seed stand-in for ``hypothesis`` when it is not installed.

The online-softmax property tests are written against the hypothesis API
(``@given`` over strategies).  This container has no network access and no
hypothesis wheel, and a hard import aborts collection of the whole module —
which under ``pytest -x`` kills the entire suite.  This shim supplies just
the API surface those tests use (``given``, ``settings``, ``st.integers/
floats/lists/tuples``, ``hnp.arrays``) backed by deterministic seeded
sampling, so offline runs still exercise every property on ``max_examples``
diverse inputs (boundary values included) instead of skipping.

With hypothesis installed the real library is used and this module is inert.
Not a general replacement: no shrinking, no database, no coverage-guided
generation.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class _Strategy:
    def __init__(self, sample_fn):
        self._sample = sample_fn

    def sample(self, rng: np.random.Generator):
        return self._sample(rng)


def _boundary_or(rng, lo, hi, draw):
    """Mostly ``draw``, sometimes an exact boundary — property tests live on
    the edges (hypothesis's own heuristic, minus the search)."""
    r = rng.random()
    if r < 0.08:
        return lo
    if r < 0.16:
        return hi
    if r < 0.24 and lo <= 0.0 <= hi:
        return type(lo)(0.0)
    return draw()


class _St:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        def sample(rng):
            return int(_boundary_or(
                rng, min_value, max_value,
                lambda: int(rng.integers(min_value, max_value + 1))))
        return _Strategy(sample)

    @staticmethod
    def floats(*, width: int = 64, min_value=None, max_value=None,
               allow_nan: bool = False, allow_infinity: bool = False,
               allow_subnormal: bool = True) -> _Strategy:
        lo = -1e6 if min_value is None else float(min_value)
        hi = 1e6 if max_value is None else float(max_value)
        dt = np.float32 if width == 32 else np.float64

        def sample(rng):
            v = _boundary_or(rng, lo, hi, lambda: rng.uniform(lo, hi))
            return float(dt(v))
        return _Strategy(sample)

    @staticmethod
    def lists(elements: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(n)]
        return _Strategy(sample)

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        def sample(rng):
            return tuple(e.sample(rng) for e in elements)
        return _Strategy(sample)


class _Hnp:
    @staticmethod
    def arrays(dtype, shape, *, elements: _Strategy) -> _Strategy:
        def sample(rng):
            sh = shape.sample(rng) if isinstance(shape, _Strategy) else shape
            sh = (sh,) if isinstance(sh, int) else tuple(sh)
            flat = np.array([elements.sample(rng)
                             for _ in range(int(np.prod(sh)))], dtype=dtype)
            return flat.reshape(sh)
        return _Strategy(sample)


st = _St()
hnp = _Hnp()

_DEFAULT_EXAMPLES = 10


def given(*strategies: _Strategy):
    """Run the test once per generated example, seeded by the test's name."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                fn(*args, *[s.sample(rng) for s in strategies], **kwargs)
        # Hide the strategy-bound parameters from pytest's fixture resolver:
        # only what's left (``self``) is a collectable signature.
        params = list(inspect.signature(fn).parameters.values())
        wrapper.__signature__ = inspect.Signature(
            params[:len(params) - len(strategies)])
        del wrapper.__wrapped__
        wrapper._hypothesis_fallback = True
        return wrapper
    return deco


def settings(*, deadline=None, max_examples: int = _DEFAULT_EXAMPLES,
             **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
